//! Property tests (via the from-scratch harness in testing::prop) on the
//! coordinator's invariants, randomized over problem instances — the
//! proptest-style coverage DESIGN.md calls out.

use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::linalg::dense;
use cocoa::prelude::*;
use cocoa::subproblem::{subproblem_value, LocalBlock, SubproblemSpec};
use cocoa::testing::prop::{forall, Gen};

fn random_problem(g: &mut Gen) -> (Problem, usize) {
    let n = g.usize_in(40, 160);
    let d = g.usize_in(4, 24);
    let density = g.f64_in(0.2, 1.0);
    let lambda = g.f64_log(1e-3, 1e-1);
    let loss = *g.choose(&[
        Loss::Hinge,
        Loss::SmoothedHinge { mu: 0.5 },
        Loss::Logistic,
        Loss::Squared,
    ]);
    let seed = g.case_seed;
    let data = generate(&SynthConfig::new("prop", n, d).density(density).seed(seed));
    let k = g.usize_in(2, 8.min(n / 8));
    (Problem::new(data, loss, lambda), k)
}

#[test]
fn prop_w_invariant_maintained_across_rounds() {
    forall("w == Aα/(λn) after any round", 25, |g| {
        let (problem, k) = random_problem(g);
        let n = problem.n();
        let part = random_balanced(n, k, g.case_seed);
        let cfg = CocoaConfig::cocoa_plus(
            k,
            problem.loss,
            problem.lambda,
            SolverSpec::Sdca {
                h: g.usize_in(5, 80),
            },
        )
        .with_rounds(3)
        .with_gap_tol(0.0)
        .with_seed(g.case_seed)
        .with_parallel(false);
        let mut t = Trainer::new(problem, part, cfg);
        for _ in 0..3 {
            t.round();
            let err = t.primal_consistency_error();
            assert!(err < 1e-9, "w drift {err}");
        }
    });
}

#[test]
fn prop_w_invariant_under_pooled_runtime() {
    // The pooled executor must preserve the coordinator's central
    // invariant w = Aα/(λn) for randomized round counts, worker counts
    // K ∈ {1, 2, 4, 8} (K = 1 degenerates to the sequential path), and
    // losses — i.e. scratch reuse and channel plumbing never corrupt the
    // reduce.
    forall("w == Aα/(λn) under the worker pool", 12, |g| {
        let k = *g.choose(&[1usize, 2, 4, 8]);
        let loss = *g.choose(&[
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Squared,
        ]);
        let rounds = g.usize_in(1, 7);
        let n = g.usize_in(40, 120);
        let d = g.usize_in(4, 16);
        let lambda = g.f64_log(1e-3, 1e-1);
        let data = generate(&SynthConfig::new("pool", n, d).seed(g.case_seed));
        let part = random_balanced(n, k, g.case_seed ^ 7);
        let problem = Problem::new(data, loss, lambda);
        let cfg = CocoaConfig::cocoa_plus(
            k,
            loss,
            lambda,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(rounds)
        .with_gap_tol(0.0)
        .with_seed(g.case_seed)
        .with_parallel(true);
        let mut t = Trainer::new(problem, part, cfg);
        assert_eq!(
            t.executor_kind(),
            if k > 1 { "pooled" } else { "sequential" }
        );
        for _ in 0..rounds {
            t.round();
        }
        let err = t.primal_consistency_error();
        assert!(err <= 1e-9, "pooled w drift {err} (K={k}, rounds={rounds})");
    });
}

#[test]
fn prop_gap_nonnegative_and_dual_monotone_safe_sigma() {
    forall("gap ≥ 0 and dual non-decreasing under σ'=γK", 20, |g| {
        let (problem, k) = random_problem(g);
        let n = problem.n();
        let part = random_balanced(n, k, g.case_seed ^ 1);
        let gamma = g.f64_in(0.2, 1.0);
        let cfg = CocoaConfig::cocoa_plus(
            k,
            problem.loss,
            problem.lambda,
            SolverSpec::SdcaEpochs { epochs: 0.5 },
        )
        .with_rounds(4)
        .with_gap_tol(0.0)
        .with_seed(g.case_seed)
        .with_parallel(false);
        let cfg = CocoaConfig {
            aggregation: cocoa::coordinator::Aggregation::Gamma(gamma),
            sigma_prime: None, // safe bound γK
            ..cfg
        };
        let mut t = Trainer::new(problem, part, cfg);
        let mut prev_dual = f64::NEG_INFINITY;
        for _ in 0..4 {
            t.round();
            let certs = t.problem.certificates(&t.alpha, &t.w);
            assert!(certs.gap >= -1e-9, "negative gap {}", certs.gap);
            assert!(
                certs.dual >= prev_dual - 1e-9,
                "dual decreased {} -> {}",
                prev_dual,
                certs.dual
            );
            prev_dual = certs.dual;
        }
    });
}

#[test]
fn prop_lemma3_inequality_on_solver_outputs() {
    // D(α + γΣΔ) ≥ (1−γ)D(α) + γΣ G_k(Δ_[k]) for solver-produced Δ.
    forall("Lemma 3 on SDCA outputs", 15, |g| {
        let (problem, k) = random_problem(g);
        let n = problem.n();
        let part = random_balanced(n, k, g.case_seed ^ 2);
        let gamma = g.f64_in(0.3, 1.0);
        let sigma_prime = gamma * k as f64;
        let blocks = LocalBlock::split(&problem.data, &part);
        let spec = SubproblemSpec {
            loss: problem.loss,
            lambda: problem.lambda,
            n_global: n,
            sigma_prime,
            k,
        };
        let alpha = vec![0.0; n];
        let w = vec![0.0; problem.d()];
        let d_before = problem.dual_value(&alpha, &w);

        let mut new_alpha = alpha.clone();
        let mut gains = 0.0;
        for (kid, block) in blocks.iter().enumerate() {
            let alpha_local = vec![0.0; block.n_local()];
            let mut solver = cocoa::solver::sdca::SdcaSolver::new(
                g.usize_in(10, 120),
                g.case_seed ^ kid as u64,
            );
            use cocoa::solver::{LocalSolveCtx, LocalSolver};
            let out = solver.solve(&LocalSolveCtx {
                block,
                spec: &spec,
                w: &w,
                alpha_local: &alpha_local,
            });
            gains += subproblem_value(block, &spec, &w, &alpha_local, &out.delta_alpha);
            for (li, &gi) in part.parts[kid].iter().enumerate() {
                new_alpha[gi] += gamma * out.delta_alpha[li];
            }
        }
        let mut w_new = vec![0.0; problem.d()];
        problem.primal_from_dual(&new_alpha, &mut w_new);
        let d_after = problem.dual_value(&new_alpha, &w_new);
        let rhs = (1.0 - gamma) * d_before + gamma * gains;
        assert!(
            d_after + 1e-8 >= rhs,
            "Lemma 3 violated: D_after={d_after} rhs={rhs} (γ={gamma}, K={k})"
        );
    });
}

#[test]
fn prop_partition_scatter_gather_roundtrip() {
    forall("blocks scatter back to the exact dataset", 30, |g| {
        let n = g.usize_in(10, 200);
        let d = g.usize_in(2, 30);
        let k = g.usize_in(1, n.min(9));
        let data = std::sync::Arc::new(generate(
            &SynthConfig::new("p", n, d).density(0.5).seed(g.case_seed),
        ));
        let part = random_balanced(n, k, g.case_seed);
        assert!(part.is_exact_cover());
        let blocks = LocalBlock::split(&data, &part);
        let mut seen = vec![false; n];
        for (k, b) in blocks.iter().enumerate() {
            for (li, &gi) in part.parts[k].iter().enumerate() {
                assert!(!seen[gi]);
                seen[gi] = true;
                assert_eq!(b.y()[li], data.y[gi]);
                assert_eq!(b.x().row(li), data.x.row(gi));
                assert!((b.norms_sq()[li] - data.row_norms_sq[gi]).abs() < 1e-15);
            }
        }
        assert!(seen.iter().all(|&s| s));
        // shared data plane: all K views alias one dataset copy
        for b in &blocks[1..] {
            assert!(std::sync::Arc::ptr_eq(b.shared_data(), blocks[0].shared_data()));
        }
    });
}

#[test]
fn prop_pool_distributed_certificates_match_central() {
    // The tentpole invariant of the distributed-evaluation refactor: the
    // K-way shard-partial reduction (Method::eval through the worker
    // pool) must equal the central single-pass Problem::certificates to
    // within float-regrouping noise, for every loss and random problems.
    forall("pooled certificates == central certificates", 15, |g| {
        let n = g.usize_in(40, 160);
        let d = g.usize_in(4, 24);
        let density = g.f64_in(0.2, 1.0);
        let lambda = g.f64_log(1e-3, 1e-1);
        let loss = *g.choose(&[
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
            Loss::Absolute,
        ]);
        let data = generate(
            &SynthConfig::new("cert", n, d)
                .density(density)
                .seed(g.case_seed),
        );
        let k = g.usize_in(2, 8.min(n / 8));
        let part = random_balanced(n, k, g.case_seed ^ 5);
        let problem = Problem::new(data, loss, lambda);
        let parallel = g.case_seed % 2 == 0;
        let cfg = CocoaConfig::cocoa_plus(
            k,
            loss,
            lambda,
            SolverSpec::SdcaEpochs { epochs: 0.5 },
        )
        .with_rounds(3)
        .with_gap_tol(0.0)
        .with_seed(g.case_seed)
        .with_parallel(parallel);
        let mut t = Trainer::new(problem, part, cfg);
        for _ in 0..g.usize_in(1, 3) {
            t.round();
        }
        let dist = t.eval();
        let central = t.problem.certificates(&t.alpha, &t.w);
        let scale = 1.0 + central.primal.abs() + central.dual.abs();
        assert!(
            (dist.primal - central.primal).abs() <= 1e-12 * scale,
            "{}: primal {} vs {} (K={k})",
            loss.name(),
            dist.primal,
            central.primal
        );
        assert!(
            (dist.dual - central.dual).abs() <= 1e-12 * scale,
            "{}: dual {} vs {} (K={k})",
            loss.name(),
            dist.dual,
            central.dual
        );
        assert!(
            (dist.gap - central.gap).abs() <= 1e-12 * scale,
            "{}: gap {} vs {} (K={k})",
            loss.name(),
            dist.gap,
            central.gap
        );
    });
}

#[test]
fn prop_delta_w_matches_a_delta_alpha() {
    forall("solver Δw == A Δα/(λn)", 20, |g| {
        let (problem, k) = random_problem(g);
        let part = random_balanced(problem.n(), k, g.case_seed ^ 3);
        let blocks = LocalBlock::split(&problem.data, &part);
        let spec = SubproblemSpec {
            loss: problem.loss,
            lambda: problem.lambda,
            n_global: problem.n(),
            sigma_prime: k as f64,
            k,
        };
        let block = &blocks[0];
        let w: Vec<f64> = g.gaussian_vec(problem.d()).iter().map(|v| v * 0.05).collect();
        let alpha_local = vec![0.0; block.n_local()];
        use cocoa::solver::{LocalSolveCtx, LocalSolver};
        let mut solver = cocoa::solver::sdca::SdcaSolver::new(50, g.case_seed);
        let out = solver.solve(&LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha_local,
        });
        let mut a_delta = vec![0.0; problem.d()];
        block.x().matvec_t(&out.delta_alpha, &mut a_delta);
        dense::scale(1.0 / (problem.lambda * problem.n() as f64), &mut a_delta);
        let err = a_delta
            .iter()
            .zip(&out.delta_w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-10, "Δw mismatch {err}");
    });
}
