//! Integration: end-to-end convergence of the framework across losses,
//! solvers, partitions, and aggregation regimes.

use cocoa::baselines::serial_sdca;
use cocoa::coordinator::StopReason;
use cocoa::data::partition::{by_label, contiguous, random_balanced};
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;

fn data(n: usize, d: usize, seed: u64) -> Dataset {
    generate(&SynthConfig::new("it", n, d).density(0.4).seed(seed))
}

#[test]
fn cocoa_plus_converges_all_losses() {
    for loss in [
        Loss::Hinge,
        Loss::SmoothedHinge { mu: 0.5 },
        Loss::Logistic,
        Loss::Squared,
    ] {
        let ds = data(300, 20, 1);
        let part = random_balanced(300, 4, 2);
        let problem = Problem::new(ds, loss, 1e-2);
        let cfg = CocoaConfig::cocoa_plus(4, loss, 1e-2, SolverSpec::SdcaEpochs { epochs: 1.0 })
            .with_rounds(250)
            .with_gap_tol(1e-4);
        let mut t = Trainer::new(problem, part, cfg);
        let h = t.run();
        assert_eq!(
            h.stop,
            StopReason::GapReached,
            "{}: final gap {}",
            loss.name(),
            h.final_gap()
        );
    }
}

#[test]
fn cocoa_plus_converges_all_solvers() {
    for solver in [
        SolverSpec::Sdca { h: 150 },
        SolverSpec::SdcaEpochs { epochs: 2.0 },
        SolverSpec::Cyclic {
            epochs: 2,
            shuffle: true,
        },
        SolverSpec::Jacobi {
            sweeps: 6,
            beta: 0.5,
        },
    ] {
        let ds = data(240, 16, 3);
        let part = random_balanced(240, 4, 4);
        let problem = Problem::new(ds, Loss::Hinge, 1e-2);
        let cfg = CocoaConfig::cocoa_plus(4, Loss::Hinge, 1e-2, solver.clone())
            .with_rounds(300)
            .with_gap_tol(1e-3);
        let mut t = Trainer::new(problem, part, cfg);
        let h = t.run();
        assert_eq!(
            h.stop,
            StopReason::GapReached,
            "{solver:?}: final gap {}",
            h.final_gap()
        );
    }
}

#[test]
fn adversarial_partitions_still_converge_with_safe_sigma() {
    let ds = data(200, 12, 5);
    let labels = ds.y.clone();
    for (name, part) in [
        ("contiguous", contiguous(200, 5)),
        ("by_label", by_label(&labels, 5)),
    ] {
        let problem = Problem::new(ds.clone(), Loss::Hinge, 1e-2);
        let cfg = CocoaConfig::cocoa_plus(
            5,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(400)
        .with_gap_tol(1e-3);
        let mut t = Trainer::new(problem, part, cfg);
        let h = t.run();
        assert_eq!(
            h.stop,
            StopReason::GapReached,
            "{name}: gap {}",
            h.final_gap()
        );
    }
}

#[test]
fn distributed_matches_serial_optimum() {
    // The distributed solution must agree with serial SDCA on the same
    // problem: same optimal dual value within tolerance.
    let ds = data(200, 10, 7);
    let problem = Problem::new(ds, Loss::Hinge, 1e-2);
    let serial = serial_sdca::solve(&problem, &Default::default());
    let part = random_balanced(200, 8, 8);
    let cfg = CocoaConfig::cocoa_plus(
        8,
        Loss::Hinge,
        1e-2,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(400)
    .with_gap_tol(1e-6);
    let mut t = Trainer::new(problem.clone(), part, cfg);
    t.run();
    let d_dist = t.problem.dual_value(&t.alpha, &t.w);
    assert!(
        (serial.certs.dual - d_dist).abs() < 1e-3,
        "serial D={} vs distributed D={}",
        serial.certs.dual,
        d_dist
    );
}

#[test]
fn k_equals_one_matches_serial_sdca_family() {
    // K=1, γ=1, σ'=1 is just serial SDCA in rounds.
    let ds = data(150, 8, 9);
    let problem = Problem::new(ds, Loss::Hinge, 5e-2);
    let part = random_balanced(150, 1, 0);
    let cfg = CocoaConfig::cocoa_plus(1, Loss::Hinge, 5e-2, SolverSpec::SdcaEpochs { epochs: 1.0 })
        .with_sigma_prime(1.0)
        .with_rounds(200)
        .with_gap_tol(1e-6);
    let mut t = Trainer::new(problem, part, cfg);
    let h = t.run();
    assert_eq!(h.stop, StopReason::GapReached);
}

#[test]
fn gap_certificate_brackets_primal_suboptimality() {
    // For any iterate: P(w) − P(w*) ≤ gap. Train partially, then compare
    // against a near-optimal reference primal.
    let ds = data(200, 12, 11);
    let problem = Problem::new(ds, Loss::Hinge, 1e-2);
    let reference = serial_sdca::solve(&problem, &Default::default());
    let p_star_ub = reference.certs.primal; // ≈ P(w*)

    let part = random_balanced(200, 4, 1);
    let cfg = CocoaConfig::cocoa_plus(4, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 30 })
        .with_rounds(10)
        .with_gap_tol(0.0);
    let mut t = Trainer::new(problem.clone(), part, cfg);
    let h = t.run();
    for r in &h.records {
        let subopt = r.primal - p_star_ub;
        assert!(
            subopt <= r.gap + 1e-6,
            "round {}: primal subopt {} exceeds gap {}",
            r.round,
            subopt,
            r.gap
        );
    }
}

#[test]
fn history_is_monotone_in_counters() {
    let ds = data(120, 8, 13);
    let problem = Problem::new(ds, Loss::Hinge, 1e-2);
    let part = random_balanced(120, 3, 1);
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 50 })
        .with_rounds(12)
        .with_gap_tol(0.0);
    let mut t = Trainer::new(problem, part, cfg);
    let h = t.run();
    for pair in h.records.windows(2) {
        assert!(pair[1].comm_vectors > pair[0].comm_vectors);
        assert!(pair[1].sim_time_s >= pair[0].sim_time_s);
        assert!(pair[1].compute_s >= pair[0].compute_s);
        assert!(pair[1].dual >= pair[0].dual - 1e-10, "dual decreased");
    }
}
