//! Appendix C / Lemma 18: with SDCA as the local solver, a balanced
//! partition, σ' = K and γ = 1 (adding), the CoCoA+ framework reduces
//! *exactly* to the practical variant of DisDCA (Yang, 2013).
//!
//! We verify the reduction computationally: a direct transcription of
//! DisDCA-p (each worker runs single-coordinate updates against
//! u_local = w + (K/λn)·A Δα_prev, then updates are added) reproduces the
//! CoCoA+ trainer's (α, w) trajectory bit-for-bit when fed the same
//! coordinate streams.

use cocoa::coordinator::worker::Worker;
use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::linalg::dense;
use cocoa::prelude::*;
use cocoa::util::rng::Pcg32;

/// Direct DisDCA-p transcription (Figure 2 of Yang 2013, scl = K),
/// independent of the cocoa solver/coordinator machinery.
struct DisDcaP {
    k: usize,
    h: usize,
    lambda: f64,
    alpha: Vec<f64>,
    w: Vec<f64>,
}

impl DisDcaP {
    fn round(&mut self, data: &Dataset, parts: &[Vec<usize>], round: usize, seed: u64) {
        let n = data.n() as f64;
        let d = data.d();
        let mut w_next = self.w.clone();
        for (kid, rows) in parts.iter().enumerate() {
            // Same per-(round, worker) stream contract as the trainer.
            let mut rng = Pcg32::new(Worker::round_seed(seed, 0, kid), 101);
            // skip the indices earlier rounds consumed from this stream
            for _ in 0..round * self.h {
                rng.gen_range(rows.len());
            }
            let mut u_local = self.w.clone();
            let mut delta_alpha = vec![0.0; rows.len()];
            for _ in 0..self.h {
                let li = rng.gen_range(rows.len());
                let gi = rows[li];
                let q = data.row_norms_sq[gi];
                if q == 0.0 {
                    continue;
                }
                let y = data.y[gi];
                let xu = data.x.row_dot(gi, &u_local);
                // DisDCA-p single-coordinate step (Eq. 51): curvature K·q/(λn)
                let coef = self.k as f64 * q / (self.lambda * n);
                let a_cur = self.alpha[gi] + delta_alpha[li];
                let b = y * a_cur;
                let b_new = (b + (1.0 - y * xu) / coef).clamp(0.0, 1.0);
                let dlt = y * b_new - a_cur;
                if dlt != 0.0 {
                    delta_alpha[li] += dlt;
                    // u_local += (K/λn)·δ·x_i  (Eq. 50)
                    data.x
                        .row_axpy(gi, self.k as f64 * dlt / (self.lambda * n), &mut u_local);
                }
            }
            // adding: α += Δα, w += A Δα/(λn) = (u_local − w)/K
            for (li, &gi) in rows.iter().enumerate() {
                self.alpha[gi] += delta_alpha[li];
            }
            for j in 0..d {
                w_next[j] += (u_local[j] - self.w[j]) / self.k as f64;
            }
        }
        self.w = w_next;
    }
}

#[test]
fn disdca_p_trajectory_identical_to_cocoa_plus() {
    let n = 120usize;
    let k = 4usize;
    let h = 60usize;
    let lambda = 1e-2;
    let seed = 77u64;
    let data = generate(&SynthConfig::new("eq", n, 10).seed(17));
    let part = random_balanced(n, k, 19);
    assert!(part.is_balanced(), "Lemma 18 requires n_k = n/K");

    // CoCoA+ framework: γ=1, σ'=K, SDCA local solver.
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
    let cfg = CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, SolverSpec::Sdca { h })
        .with_rounds(5)
        .with_gap_tol(0.0)
        .with_seed(seed)
        .with_parallel(false);
    let mut trainer = Trainer::new(problem, part.clone(), cfg);

    // Direct DisDCA-p.
    let mut disdca = DisDcaP {
        k,
        h,
        lambda,
        alpha: vec![0.0; n],
        w: vec![0.0; data.d()],
    };

    for round in 0..5 {
        trainer.round();
        disdca.round(&data, &part.parts, round, seed);
        // the trainer's α lives in its permuted-contiguous layout; compare
        // in the original row order the DisDCA transcription uses
        let trainer_alpha = trainer.alpha_original();
        let a_err = trainer_alpha
            .iter()
            .zip(&disdca.alpha)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let w_err = dense::distance(&trainer.w, &disdca.w);
        assert!(
            a_err < 1e-12 && w_err < 1e-12,
            "round {round}: trajectories diverged (α err {a_err:.2e}, w err {w_err:.2e})"
        );
    }
}

#[test]
fn correspondence_breaks_for_other_sigma_prime() {
    // Lemma 18's discussion: σ' ≠ K breaks the equivalence — verify the
    // trajectories actually differ (guards against a vacuous test above).
    let n = 80usize;
    let k = 4usize;
    let h = 40usize;
    let lambda = 1e-2;
    let seed = 7u64;
    let data = generate(&SynthConfig::new("eq2", n, 8).seed(23));
    let part = random_balanced(n, k, 3);

    let run = |sigma_prime: f64| {
        let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
        let cfg = CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, SolverSpec::Sdca { h })
            .with_sigma_prime(sigma_prime)
            .with_rounds(3)
            .with_gap_tol(0.0)
            .with_seed(seed)
            .with_parallel(false);
        let mut t = Trainer::new(problem, part.clone(), cfg);
        t.run();
        t.alpha
    };
    let a_k = run(k as f64);
    let a_half = run(k as f64 / 2.0);
    let diff = a_k
        .iter()
        .zip(&a_half)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(diff > 1e-9, "σ' change should alter the trajectory");
}
