//! Integration over the PJRT runtime: artifact loading, gap-graph
//! agreement with the native objective, XLA↔native solver trajectory
//! identity, and a short full training run on the XLA path.
//!
//! These tests require `make artifacts` (the Makefile orders it before
//! `cargo test`); they skip with a note when artifacts are absent so
//! plain `cargo test` still works in a fresh checkout. The whole file is
//! additionally gated behind the `xla` cargo feature, since the PJRT
//! bindings crate is not vendored in the offline toolchain.
#![cfg(feature = "xla")]

use cocoa::coordinator::worker::Worker;
use cocoa::prelude::*;
use cocoa::runtime::artifact::{default_artifacts_dir, Manifest};
use cocoa::runtime::pjrt::PjrtRuntime;
use cocoa::runtime::{XlaGapEvaluator, XlaSdcaProgram, XlaSdcaSolver};
use cocoa::solver::sdca::SdcaSolver;
use cocoa::solver::{LocalSolveCtx, LocalSolver};
use cocoa::subproblem::{LocalBlock, SubproblemSpec};
use std::sync::Arc;

struct Env {
    manifest: Manifest,
    rt: PjrtRuntime,
}

fn env() -> Option<Env> {
    let dir = default_artifacts_dir()?;
    let manifest = Manifest::load(&dir).ok()?;
    let rt = PjrtRuntime::cpu().ok()?;
    Some(Env { manifest, rt })
}

macro_rules! require_env {
    () => {
        match env() {
            Some(e) => e,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn smoke_test_all_artifacts() {
    let e = require_env!();
    let report = cocoa::runtime::smoke_test(&e.manifest).expect("smoke test");
    assert!(report.contains("OK"));
}

#[test]
fn gap_graph_matches_native_objective() {
    let e = require_env!();
    let gap = XlaGapEvaluator::load(&e.rt, &e.manifest).unwrap();
    let (rows, cols) = (gap.n.min(200), gap.d.min(32));
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("t", rows, cols)
            .density(1.0)
            .seed(3),
    );
    let lambda = 2e-2;
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
    // random feasible dual point
    let alpha: Vec<f64> = (0..rows)
        .map(|i| data.y[i] * ((i % 17) as f64 / 17.0))
        .collect();
    let native_gap = problem.duality_gap(&alpha);
    let x_dense = data.x.to_dense();
    let certs = gap
        .certificates(&x_dense, rows, cols, &data.y, &alpha, lambda)
        .unwrap();
    assert!(
        (certs.gap - native_gap).abs() < 1e-9,
        "XLA {} vs native {}",
        certs.gap,
        native_gap
    );
    // mapped w agrees too
    let mut w_native = vec![0.0; cols];
    problem.primal_from_dual(&alpha, &mut w_native);
    let werr = certs
        .w
        .iter()
        .zip(&w_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(werr < 1e-12, "w mismatch {werr}");
}

#[test]
fn xla_solver_trajectory_identical_to_native() {
    let e = require_env!();
    let program = Arc::new(XlaSdcaProgram::load(&e.rt, &e.manifest).unwrap());
    let (m, d, h) = (program.m, program.d, program.h);
    // deliberately smaller than the artifact to exercise padding
    let n_local = m - 37;
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("t", n_local, d.min(48))
            .density(1.0)
            .seed(5),
    );
    let rows: Vec<usize> = (0..n_local).collect();
    let block = LocalBlock::from_partition(&data, &rows);
    let lambda = 1e-2;
    let spec = SubproblemSpec {
        loss: Loss::Hinge,
        lambda,
        n_global: n_local,
        sigma_prime: 4.0,
        k: 4,
    };
    let w: Vec<f64> = (0..block.d()).map(|j| 0.01 * (j as f64).sin()).collect();
    let alpha: Vec<f64> = (0..n_local).map(|i| data.y[i] * 0.2).collect();
    let ctx = LocalSolveCtx {
        block: &block,
        spec: &spec,
        w: &w,
        alpha_local: &alpha,
    };

    let seed = Worker::round_seed(9, 0, 0);
    let mut xla = XlaSdcaSolver::new(
        Arc::clone(&program),
        &block,
        lambda * n_local as f64,
        4.0,
        seed,
    )
    .unwrap();
    let mut native = SdcaSolver::new(h, seed);
    let u_x = xla.solve(&ctx);
    let u_n = native.solve(&ctx);
    let da_err = u_x
        .delta_alpha
        .iter()
        .zip(&u_n.delta_alpha)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let dw_err = u_x
        .delta_w
        .iter()
        .zip(&u_n.delta_w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(da_err < 1e-9, "Δα diverged: {da_err}");
    assert!(dw_err < 1e-9, "Δw diverged: {dw_err}");
}

#[test]
fn xla_backed_training_converges() {
    let e = require_env!();
    let program = Arc::new(XlaSdcaProgram::load(&e.rt, &e.manifest).unwrap());
    let (m, d, h) = (program.m, program.d, program.h);
    let k = 2usize;
    let n = k * (m / 2); // half-filled blocks: padding in play
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("t", n, d).density(1.0).seed(7),
    );
    let lambda = 2e-2;
    let part = cocoa::data::partition::random_balanced(n, k, 7);
    let problem = Problem::new(data, Loss::Hinge, lambda);
    let blocks = LocalBlock::split(&problem.data, &part);
    let solvers: Vec<Box<dyn LocalSolver>> = blocks
        .iter()
        .enumerate()
        .map(|(wk, b)| {
            Box::new(
                XlaSdcaSolver::new(
                    Arc::clone(&program),
                    b,
                    lambda * n as f64,
                    k as f64,
                    Worker::round_seed(11, 0, wk),
                )
                .unwrap(),
            ) as Box<dyn LocalSolver>
        })
        .collect();
    let cfg = CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, SolverSpec::Sdca { h })
        .with_rounds(15)
        .with_gap_tol(1e-4)
        .with_parallel(false);
    let mut t = Trainer::with_solvers(problem, part, cfg, solvers);
    let hist = t.run();
    assert!(
        hist.final_gap() < 1e-3,
        "XLA-backed training gap {}",
        hist.final_gap()
    );
    assert!(t.primal_consistency_error() < 1e-9);
}

#[test]
fn oversized_block_is_rejected() {
    let e = require_env!();
    let program = Arc::new(XlaSdcaProgram::load(&e.rt, &e.manifest).unwrap());
    let m = program.m;
    let data = cocoa::data::synth::generate(
        &cocoa::data::synth::SynthConfig::new("t", m + 1, 8).seed(1),
    );
    let rows: Vec<usize> = (0..m + 1).collect();
    let block = LocalBlock::from_partition(&data, &rows);
    let res = XlaSdcaSolver::new(program, &block, 1.0, 1.0, 0);
    assert!(res.is_err(), "block larger than artifact m must be rejected");
}
