//! Telemetry integration tests: the flight recorder attached to a real
//! training run must produce a valid Chrome trace-event file with
//! well-formed span nesting, the spans the instrumentation promises
//! (driver rounds on the leader lane, per-worker compute spans), and
//! zero drops at this scale. Determinism under tracing is locked in by
//! `tests/determinism.rs`; this file covers the trace artifact itself.

use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;
use cocoa::telemetry::{checker, Recorder};
use cocoa::util::json::Json;

const ROUNDS: usize = 6;
const K: usize = 3;

fn traced_trainer(recorder: Recorder, parallel: bool) -> Trainer {
    let n = 96;
    let data = generate(&SynthConfig::new("telemetry", n, 12).seed(7));
    let part = random_balanced(n, K, 3);
    let problem = Problem::new(data, Loss::Hinge, 0.01);
    let cfg = CocoaConfig::cocoa_plus(
        K,
        Loss::Hinge,
        0.01,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(ROUNDS)
    .with_gap_tol(1e-14)
    .with_seed(42)
    .with_parallel(parallel)
    .with_recorder(recorder);
    Trainer::new(problem, part, cfg)
}

/// Collect `(name, tid)` for every complete event in the trace text.
fn span_names(text: &str) -> Vec<(String, u64)> {
    let doc = Json::parse(text).expect("trace parses as JSON");
    doc.get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array")
        .iter()
        .filter(|ev| ev.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .map(|ev| {
            (
                ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(),
                ev.get("tid").and_then(|t| t.as_f64()).unwrap_or(-1.0) as u64,
            )
        })
        .collect()
}

#[test]
fn pooled_run_emits_valid_nested_trace_with_expected_spans() {
    let path = std::env::temp_dir().join("cocoa_telemetry_pooled_trace.json");
    let rec = Recorder::to_file(&path).expect("open trace file");
    let mut trainer = traced_trainer(rec.clone(), true);
    let hist = trainer.run();
    assert_eq!(hist.rounds_run(), ROUNDS);
    // Dropping the trainer joins the pool workers, whose exiting threads
    // flush their rings; only then may the trailer be written.
    drop(trainer);
    let sum = rec.finish().expect("finish trace");
    assert!(sum.events > 0, "an instrumented run must record events");
    assert_eq!(sum.dropped, 0, "nothing may be dropped at this scale");

    // The file passes the structural validator (the same code behind
    // `cocoa trace-check`): every lane's spans nest or are disjoint.
    let check = checker::check_file(&path).expect("trace must validate");
    assert_eq!(check.events as u64, sum.events);
    assert_eq!(check.dropped, 0);
    assert_eq!(
        check.lanes,
        1 + K,
        "leader lane plus one lane per worker"
    );
    assert!(
        check.max_depth >= 2,
        "executor phases must nest inside driver rounds, got depth {}",
        check.max_depth
    );

    let text = std::fs::read_to_string(&path).expect("read trace");
    let spans = span_names(&text);
    let has = |name: &str, tid: u64| spans.iter().any(|(n, t)| n == name && *t == tid);
    // Driver outer loop on the leader lane (tid 0).
    assert!(has("round", 0), "driver round spans missing: {spans:?}");
    assert!(has("eval", 0), "driver eval spans missing: {spans:?}");
    // Pooled-executor leader phases share the leader lane.
    assert!(has("broadcast", 0), "broadcast spans missing: {spans:?}");
    assert!(has("barrier", 0), "barrier spans missing: {spans:?}");
    assert!(has("reduce", 0), "trainer reduce spans missing: {spans:?}");
    // One compute lane per worker.
    for k in 0..K {
        let tid = 1 + k as u64;
        assert!(has("compute", tid), "worker {k} compute missing: {spans:?}");
    }
    // Exactly one driver round span per executed round.
    let rounds = spans.iter().filter(|(n, t)| n == "round" && *t == 0).count();
    assert_eq!(rounds, ROUNDS, "{spans:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn sequential_run_traces_worker_lanes_without_pool_threads() {
    // The sequential executor runs shards on the leader thread but still
    // files compute spans under per-worker tids, so traces are
    // executor-independent for the phases both runtimes share.
    let path = std::env::temp_dir().join("cocoa_telemetry_seq_trace.json");
    let rec = Recorder::to_file(&path).expect("open trace file");
    let mut trainer = traced_trainer(rec.clone(), false);
    trainer.run();
    drop(trainer);
    let sum = rec.finish().expect("finish trace");
    assert_eq!(sum.dropped, 0);
    let check = checker::check_file(&path).expect("trace must validate");
    assert_eq!(check.lanes, 1 + K);
    let text = std::fs::read_to_string(&path).expect("read trace");
    let spans = span_names(&text);
    assert!(spans.iter().any(|(n, t)| n == "round" && *t == 0), "{spans:?}");
    assert!(spans.iter().any(|(n, t)| n == "compute" && *t == 1), "{spans:?}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn socket_broadcast_sends_overlap_for_k4() {
    // The socket leader broadcasts each round's frame to all K workers
    // from concurrent sender threads; the per-worker `send` spans (tid
    // 1+k, recorded after the join from in-thread timestamps) must
    // actually overlap in time. The test statistic per broadcast is
    //   wall  = max(span end) − min(span start)
    //   total = Σ span durations
    // Serialized sends give wall ≥ total; concurrency gives wall < total.
    const KSOCK: usize = 4;
    let path = std::env::temp_dir().join("cocoa_telemetry_socket_overlap.json");
    let rec = Recorder::to_file(&path).expect("open trace file");
    // A wide model (d = 1 << 17) makes each per-worker frame ≈ 1 MiB of
    // f64 payload — far past the kernel socket buffer — so each send
    // span is long enough that overlap cannot hide in timer noise.
    let d = 1 << 17;
    let n = 64;
    let data = generate(&SynthConfig::new("overlap", n, d).density(0.02).seed(7));
    let part = random_balanced(n, KSOCK, 3);
    let problem = Problem::new(data, Loss::Hinge, 0.01);
    let cfg = CocoaConfig::cocoa_plus(
        KSOCK,
        Loss::Hinge,
        0.01,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(3)
    .with_gap_tol(1e-14)
    .with_seed(42)
    .with_executor(ExecutorChoice::Socket)
    .with_socket_worker_bin(env!("CARGO_BIN_EXE_cocoa"))
    .with_recorder(rec.clone());
    let mut trainer = Trainer::new(problem, part, cfg);
    trainer.run();
    drop(trainer);
    rec.finish().expect("finish trace");
    checker::check_file(&path).expect("trace must validate");

    let text = std::fs::read_to_string(&path).expect("read trace");
    let doc = Json::parse(&text).expect("trace parses");
    let mut broadcasts: Vec<(u64, u64)> = Vec::new();
    let mut sends: Vec<(u64, u64)> = Vec::new();
    for ev in doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array")
    {
        if ev.get("ph").and_then(|p| p.as_str()) != Some("X") {
            continue;
        }
        let name = ev.get("name").and_then(|nm| nm.as_str()).unwrap_or("");
        let ts = ev.get("ts").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let dur = ev.get("dur").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        match name {
            "broadcast" => broadcasts.push((ts, ts + dur)),
            "send" => sends.push((ts, ts + dur)),
            _ => {}
        }
    }
    assert!(
        !broadcasts.is_empty(),
        "leader must record broadcast umbrella spans"
    );
    // Group the per-worker send spans under their broadcast umbrella:
    // the umbrella opens before the senders spawn and closes after the
    // join, so each fan-out's K send spans fall inside exactly one.
    let mut full_groups = 0usize;
    let mut overlapped = 0usize;
    for &(bs, be) in &broadcasts {
        let group: Vec<(u64, u64)> = sends
            .iter()
            .copied()
            .filter(|&(s, e)| s >= bs && e <= be)
            .collect();
        if group.len() != KSOCK {
            continue;
        }
        full_groups += 1;
        let start = group.iter().map(|&(s, _)| s).min().unwrap();
        let end = group.iter().map(|&(_, e)| e).max().unwrap();
        let total: u64 = group.iter().map(|&(s, e)| e - s).sum();
        if end - start < total {
            overlapped += 1;
        }
    }
    assert!(
        full_groups > 0,
        "no broadcast umbrella carried all {KSOCK} send spans"
    );
    assert!(
        overlapped > 0,
        "K={KSOCK} sends never overlapped: wall >= sum of span durations \
         in all {full_groups} full broadcasts"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn disabled_recorder_run_is_zero_artifact() {
    // Every config embeds a disabled recorder; a normal run must neither
    // write a file nor count events.
    let rec = Recorder::disabled();
    let mut trainer = traced_trainer(rec.clone(), true);
    trainer.run();
    drop(trainer);
    let sum = rec.finish().expect("finish on disabled is Ok");
    assert_eq!(sum.events, 0);
    assert_eq!(sum.dropped, 0);
}
