//! Failure injection: the coordinator's behaviour when local solvers
//! misbehave — NaN updates must be caught by the divergence guard, a
//! panicking worker must surface as an error (never a hang, under either
//! runtime), the persistent pool must stay alive across failed rounds and
//! shut down cleanly on drop, and checkpoint corruption must be rejected.

use cocoa::coordinator::StopReason;
use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;
use cocoa::solver::{LocalSolveCtx, LocalSolver, LocalUpdate};

/// A solver that behaves for `good_rounds` rounds, then emits NaNs.
struct NanAfter {
    good_rounds: usize,
    calls: usize,
}

impl LocalSolver for NanAfter {
    fn name(&self) -> String {
        "nan_after".into()
    }
    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        self.calls += 1;
        let nk = ctx.block.n_local();
        let d = ctx.block.d();
        out.reset(nk, d);
        if self.calls > self.good_rounds {
            out.delta_alpha.fill(f64::NAN);
            out.delta_w.fill(f64::NAN);
        }
    }
}

/// A solver that panics on every call.
struct Panicker;

impl LocalSolver for Panicker {
    fn name(&self) -> String {
        "panicker".into()
    }
    fn solve_into(&mut self, _ctx: &LocalSolveCtx, _out: &mut LocalUpdate) {
        panic!("injected worker failure");
    }
}

/// A solver that panics only on round `bad_round` (0-based call index).
struct PanicOnce {
    bad_round: usize,
    calls: usize,
}

impl LocalSolver for PanicOnce {
    fn name(&self) -> String {
        "panic_once".into()
    }
    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        let call = self.calls;
        self.calls += 1;
        if call == self.bad_round {
            panic!("transient worker failure");
        }
        out.reset(ctx.block.n_local(), ctx.block.d());
    }
}

fn problem(n: usize) -> (Problem, cocoa::data::Partition) {
    let data = generate(&SynthConfig::new("fi", n, 6).seed(1));
    let part = random_balanced(n, 3, 2);
    (Problem::new(data, Loss::Hinge, 1e-2), part)
}

#[test]
fn nan_updates_stop_as_diverged() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = (0..3)
        .map(|_| {
            Box::new(NanAfter {
                good_rounds: 2,
                calls: 0,
            }) as Box<dyn LocalSolver>
        })
        .collect();
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(10)
        .with_gap_tol(1e-12)
        .with_parallel(false);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let hist = t.run();
    assert_eq!(hist.stop, StopReason::Diverged, "NaN must trip the guard");
    assert!(hist.rounds_run() <= 4, "should stop at the first bad round");
}

#[test]
fn panicking_worker_fails_fast_sequential() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![
        Box::new(Panicker),
        Box::new(Panicker),
        Box::new(Panicker),
    ];
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(5)
        .with_parallel(false);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.round()));
    assert!(res.is_err(), "worker panic must propagate");
}

#[test]
fn panicking_worker_fails_fast_parallel() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![
        Box::new(Panicker),
        Box::new(Panicker),
        Box::new(Panicker),
    ];
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(5)
        .with_parallel(true);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.round()));
    assert!(res.is_err(), "worker panic must propagate across threads");
}

#[test]
fn single_panicking_worker_identified_and_pool_survives() {
    // One bad worker out of three: try_round must name exactly worker 1,
    // and the pool must keep answering (error again, not hang) on the
    // next round — the long-lived threads survive a member's panic.
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![
        Box::new(NanAfter {
            good_rounds: usize::MAX,
            calls: 0,
        }),
        Box::new(Panicker),
        Box::new(NanAfter {
            good_rounds: usize::MAX,
            calls: 0,
        }),
    ];
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(5)
        .with_parallel(true);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    assert_eq!(t.executor_kind(), "pooled");
    for attempt in 0..2 {
        let err = t.try_round().expect_err("panicking worker must fail the round");
        assert_eq!(err.failed.len(), 1, "attempt {attempt}: {err}");
        assert_eq!(err.failed[0].0, 1, "wrong worker blamed: {err}");
        assert!(
            err.failed[0].1.contains("injected worker failure"),
            "panic payload lost: {err}"
        );
    }
}

#[test]
fn transient_panic_then_recovery_under_pool() {
    // Worker 2 panics only in round 1; rounds 0 and 2 must succeed, the
    // leader's (α, w) must be untouched by the failed round, and the
    // surviving workers' locally-applied γΔα must be rolled back — which
    // we verify by comparing against a sequential trainer with identical
    // solvers going through the same failure.
    use cocoa::solver::sdca::SdcaSolver;
    let build = |parallel: bool| {
        let (p, part) = problem(60);
        let solvers: Vec<Box<dyn LocalSolver>> = vec![
            Box::new(SdcaSolver::new(30, 100)),
            Box::new(SdcaSolver::new(30, 200)),
            Box::new(PanicOnce {
                bad_round: 1,
                calls: 0,
            }),
        ];
        let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
            .with_rounds(5)
            .with_parallel(parallel);
        Trainer::with_solvers(p, part, cfg, solvers)
    };
    let mut pooled = build(true);
    let mut sequential = build(false);
    assert_eq!(pooled.executor_kind(), "pooled");

    assert!(pooled.try_round().is_ok(), "round 0 should succeed");
    assert!(sequential.try_round().is_ok());

    let alpha_before = pooled.alpha.clone();
    let w_before = pooled.w.clone();
    let err = pooled.try_round().expect_err("round 1 must fail");
    assert_eq!(err.failed[0].0, 2);
    assert!(sequential.try_round().is_err());
    assert_eq!(pooled.alpha, alpha_before, "failed round must not touch α");
    assert_eq!(pooled.w, w_before, "failed round must not touch w");

    assert!(pooled.try_round().is_ok(), "round 2 should succeed again");
    assert!(sequential.try_round().is_ok());
    assert_eq!(
        pooled.alpha, sequential.alpha,
        "post-recovery trajectories diverged — worker rollback broken"
    );
    assert_eq!(pooled.w, sequential.w);
    assert!(pooled.primal_consistency_error() < 1e-9);
}

#[test]
fn pool_shuts_down_cleanly_on_trainer_drop() {
    // Dropping a pooled trainer mid-run must join all worker threads
    // without hanging — repeatedly, so leaked threads would accumulate
    // into an obvious failure under any thread limit.
    for i in 0..8 {
        let (p, part) = problem(60);
        let cfg = CocoaConfig::cocoa_plus(
            3,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(50)
        .with_seed(i);
        let mut t = Trainer::new(p, part, cfg);
        assert_eq!(t.executor_kind(), "pooled");
        t.round();
        drop(t); // joins the pool; a hang here fails the suite via timeout
    }
}

#[test]
fn k1_parallel_config_runs_on_sequential_path() {
    // K = 1 must degenerate to the in-process executor even when the
    // config asks for the parallel runtime.
    let data = generate(&SynthConfig::new("fi1", 40, 6).seed(2));
    let part = random_balanced(40, 1, 2);
    let p = Problem::new(data, Loss::Hinge, 1e-2);
    let cfg = CocoaConfig::cocoa_plus(
        1,
        Loss::Hinge,
        1e-2,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(3);
    assert!(cfg.parallel);
    let mut t = Trainer::new(p, part, cfg);
    assert_eq!(t.executor_kind(), "sequential");
    for _ in 0..3 {
        t.round();
    }
    assert!(t.primal_consistency_error() < 1e-9);
}

#[test]
fn mismatched_solver_count_rejected() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![Box::new(Panicker)]; // 1 ≠ K=3
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 });
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Trainer::with_solvers(p, part, cfg, solvers)
    }));
    assert!(res.is_err());
}

#[test]
fn mismatched_partition_rejected() {
    let (p, _) = problem(60);
    let wrong_part = random_balanced(50, 3, 2); // n mismatch
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 });
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Trainer::new(p, wrong_part, cfg)
    }));
    assert!(res.is_err());
}

// ---------------------------------------------------------------------
// Socket executor: real worker *processes* misbehaving. Every failure
// here must surface as a typed PoolError naming the worker — never a
// hang, never a leader-side panic.
// ---------------------------------------------------------------------

mod socket_failures {
    use super::*;
    use cocoa::coordinator::pool::Executor;
    use cocoa::coordinator::socket::SocketExecutor;
    use cocoa::subproblem::{LocalBlock, SubproblemSpec};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    /// Blocks + spec + a socket-ready config over the shared
    /// failure-injection problem (n=60, K=3, d=6).
    fn socket_parts() -> (Vec<LocalBlock>, SubproblemSpec, CocoaConfig) {
        let (p, part) = problem(60);
        let layout = part.apply_permutation(Arc::clone(&p.data));
        let blocks = LocalBlock::from_layout(&layout);
        let spec = SubproblemSpec {
            loss: Loss::Hinge,
            lambda: 1e-2,
            n_global: 60,
            sigma_prime: 3.0,
            k: 3,
        };
        let mut cfg = CocoaConfig::cocoa_plus(
            3,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_executor(ExecutorChoice::Socket)
        .with_socket_worker_bin(env!("CARGO_BIN_EXE_cocoa"));
        cfg.socket.round_timeout = Some(Duration::from_secs(20));
        (blocks, spec, cfg)
    }

    #[test]
    fn killed_worker_is_named_and_executor_keeps_erroring() {
        let (blocks, spec, cfg) = socket_parts();
        let mut exec = SocketExecutor::spawn(&blocks, spec, &cfg).expect("spawn workers");
        assert_eq!(exec.kind(), "socket");
        let w = vec![0.0; 6];
        exec.run_round(&w, 1.0).expect("healthy round must succeed");

        exec.kill_worker(1);
        let err = exec
            .run_round(&w, 1.0)
            .expect_err("a dead worker must fail the round");
        assert!(
            err.failed.iter().any(|(id, _)| *id == 1),
            "worker 1 not named: {err}"
        );
        assert!(
            err.failed.iter().all(|(id, _)| *id == 1),
            "healthy workers wrongly blamed: {err}"
        );
        // The executor stays answerable: further rounds and certificate
        // evaluations are errors, not hangs.
        assert!(exec.run_round(&w, 1.0).is_err());
        assert!(exec.eval_partials(&w).is_err());
    }

    #[cfg(unix)]
    #[test]
    fn worker_binary_that_never_handshakes_fails_fast() {
        // /bin/true exits immediately without connecting: spawn must
        // detect the dead child well before the handshake timeout.
        let (blocks, spec, mut cfg) = socket_parts();
        cfg.socket.worker_bin = Some("/bin/true".into());
        cfg.socket.handshake_timeout = Duration::from_secs(60);
        let t0 = Instant::now();
        let err = SocketExecutor::spawn(&blocks, spec, &cfg)
            .expect_err("/bin/true cannot complete the handshake");
        assert!(
            err.to_string().contains("before handshake"),
            "unexpected failure mode: {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "fail-fast took {:?}",
            t0.elapsed()
        );
    }

    #[cfg(unix)]
    #[test]
    fn worker_process_rejects_malformed_init_and_exits() {
        use cocoa::coordinator::socket::validate_hello;
        use cocoa::coordinator::wire;
        use cocoa::util::json::{jnum, jstr, Json};
        use std::os::unix::net::UnixListener;
        use std::process::{Command, Stdio};

        // Act as a (confused) leader: accept the worker's hello, then
        // send an init whose CSR indptr is not monotone. The worker must
        // reject it as a typed error and exit nonzero — not index out of
        // bounds later in the solve, and not hang waiting for rounds.
        let sock = std::env::temp_dir().join(format!("cocoa-fi-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let listener = UnixListener::bind(&sock).expect("bind test socket");
        let mut child = Command::new(env!("CARGO_BIN_EXE_cocoa"))
            .arg("worker")
            .arg("--connect")
            .arg(&sock)
            .arg("--worker")
            .arg("0")
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn worker");
        let (stream, _) = listener.accept().expect("worker connects");
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let hello = wire::read_frame(&mut &stream).expect("hello frame");
        assert_eq!(validate_hello(&hello, 1).expect("well-formed hello"), 0);

        let mut solver = Json::obj();
        solver.set("kind", jstr("sdca"));
        solver.set("h", jnum(1.0));
        let bad = wire::Frame::new("init")
            .set_num("id", 0.0)
            .set_num("k", 1.0)
            .set_num("n", 2.0)
            .set_num("d", 3.0)
            .set_num("n_local", 2.0)
            .set_str("loss", "hinge")
            .set_json("solver", solver)
            .with_f64s("par", vec![0.01, 1.0, 0.0, 0.0, 0.0])
            .with_f64s("y", vec![1.0, -1.0])
            .with_f64s("nr", vec![1.0, 1.0])
            .with_f64s("v", vec![1.0, 0.5, -0.5])
            .with_u64s("ip", vec![0, 3, 2]) // not monotone
            .with_u64s("ix", vec![0, 1, 2])
            .with_u64s("seed", vec![42]);
        wire::write_frame(&mut &stream, &bad).expect("send bad init");

        let deadline = Instant::now() + Duration::from_secs(10);
        let status = loop {
            if let Some(st) = child.try_wait().unwrap() {
                break st;
            }
            assert!(
                Instant::now() < deadline,
                "worker did not exit on malformed init"
            );
            std::thread::sleep(Duration::from_millis(20));
        };
        assert!(
            !status.success(),
            "malformed init must exit nonzero, got {status}"
        );
        let _ = std::fs::remove_file(&sock);
    }
}

#[test]
fn truncated_checkpoint_file_rejected() {
    use cocoa::coordinator::checkpoint::{Checkpoint, CheckpointError};
    // A checkpoint file cut off mid-write (the classic crash-during-save)
    // must come back as a Parse error from load — never a panic, and
    // never a half-restored trainer.
    let (p, part) = problem(60);
    let cfg = CocoaConfig::cocoa_plus(
        3,
        Loss::Hinge,
        1e-2,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(5)
    .with_parallel(false);
    let mut t = Trainer::new(p, part, cfg);
    t.round();
    let ck = Checkpoint::capture(&t);
    let dir = std::env::temp_dir().join("cocoa_fi_ck");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("truncated.json");
    ck.save(&path).unwrap();
    let full = std::fs::read_to_string(&path).unwrap();
    // The compact JSON is pure ASCII, so any byte cut is a char cut.
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    match Checkpoint::load(&path) {
        Err(CheckpointError::Parse(_)) => {}
        other => panic!("truncated checkpoint must be a Parse error, got {other:?}"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn recovery_after_transient_bad_round_via_checkpoint() {
    use cocoa::coordinator::checkpoint::Checkpoint;
    // Train, checkpoint, corrupt the live trainer, restore, verify the
    // restored state reproduces the checkpointed certificates.
    let (p, part) = problem(90);
    let cfg = CocoaConfig::cocoa_plus(
        3,
        Loss::Hinge,
        1e-2,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(30)
    .with_parallel(false);
    let mut t = Trainer::new(p, part, cfg);
    for _ in 0..5 {
        t.round();
    }
    let certs_before = t.problem.certificates(&t.alpha, &t.w);
    let ck = Checkpoint::capture(&t);
    // simulate corruption
    for a in t.alpha.iter_mut() {
        *a = f64::NAN;
    }
    for w in t.w.iter_mut() {
        *w = f64::NAN;
    }
    ck.restore(&mut t).expect("restore after corruption");
    let certs_after = t.problem.certificates(&t.alpha, &t.w);
    assert!((certs_before.gap - certs_after.gap).abs() < 1e-12);
    // and training continues fine
    for _ in 0..5 {
        t.round();
    }
    assert!(t.problem.certificates(&t.alpha, &t.w).gap <= certs_after.gap + 1e-9);
}
