//! Failure injection: the coordinator's behaviour when local solvers
//! misbehave — NaN updates must be caught by the divergence guard, a
//! panicking worker must fail the round loudly (not hang or silently
//! corrupt state), and checkpoint corruption must be rejected.

use cocoa::coordinator::StopReason;
use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;
use cocoa::solver::{LocalSolveCtx, LocalSolver, LocalUpdate};

/// A solver that behaves for `good_rounds` rounds, then emits NaNs.
struct NanAfter {
    good_rounds: usize,
    calls: usize,
}

impl LocalSolver for NanAfter {
    fn name(&self) -> String {
        "nan_after".into()
    }
    fn solve(&mut self, ctx: &LocalSolveCtx) -> LocalUpdate {
        self.calls += 1;
        let nk = ctx.block.n_local();
        let d = ctx.block.d();
        if self.calls <= self.good_rounds {
            LocalUpdate {
                delta_alpha: vec![0.0; nk],
                delta_w: vec![0.0; d],
                steps: 0,
            }
        } else {
            LocalUpdate {
                delta_alpha: vec![f64::NAN; nk],
                delta_w: vec![f64::NAN; d],
                steps: 0,
            }
        }
    }
}

/// A solver that panics on its first call.
struct Panicker;

impl LocalSolver for Panicker {
    fn name(&self) -> String {
        "panicker".into()
    }
    fn solve(&mut self, _ctx: &LocalSolveCtx) -> LocalUpdate {
        panic!("injected worker failure");
    }
}

fn problem(n: usize) -> (Problem, cocoa::data::Partition) {
    let data = generate(&SynthConfig::new("fi", n, 6).seed(1));
    let part = random_balanced(n, 3, 2);
    (Problem::new(data, Loss::Hinge, 1e-2), part)
}

#[test]
fn nan_updates_stop_as_diverged() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = (0..3)
        .map(|_| {
            Box::new(NanAfter {
                good_rounds: 2,
                calls: 0,
            }) as Box<dyn LocalSolver>
        })
        .collect();
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(10)
        .with_gap_tol(1e-12)
        .with_parallel(false);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let hist = t.run();
    assert_eq!(hist.stop, StopReason::Diverged, "NaN must trip the guard");
    assert!(hist.rounds_run() <= 4, "should stop at the first bad round");
}

#[test]
fn panicking_worker_fails_fast_sequential() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![
        Box::new(Panicker),
        Box::new(Panicker),
        Box::new(Panicker),
    ];
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(5)
        .with_parallel(false);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.round()));
    assert!(res.is_err(), "worker panic must propagate");
}

#[test]
fn panicking_worker_fails_fast_parallel() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![
        Box::new(Panicker),
        Box::new(Panicker),
        Box::new(Panicker),
    ];
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 })
        .with_rounds(5)
        .with_parallel(true);
    let mut t = Trainer::with_solvers(p, part, cfg, solvers);
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.round()));
    assert!(res.is_err(), "worker panic must propagate across threads");
}

#[test]
fn mismatched_solver_count_rejected() {
    let (p, part) = problem(60);
    let solvers: Vec<Box<dyn LocalSolver>> = vec![Box::new(Panicker)]; // 1 ≠ K=3
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 });
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Trainer::with_solvers(p, part, cfg, solvers)
    }));
    assert!(res.is_err());
}

#[test]
fn mismatched_partition_rejected() {
    let (p, _) = problem(60);
    let wrong_part = random_balanced(50, 3, 2); // n mismatch
    let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 1e-2, SolverSpec::Sdca { h: 1 });
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        Trainer::new(p, wrong_part, cfg)
    }));
    assert!(res.is_err());
}

#[test]
fn recovery_after_transient_bad_round_via_checkpoint() {
    use cocoa::coordinator::checkpoint::Checkpoint;
    // Train, checkpoint, corrupt the live trainer, restore, verify the
    // restored state reproduces the checkpointed certificates.
    let (p, part) = problem(90);
    let cfg = CocoaConfig::cocoa_plus(
        3,
        Loss::Hinge,
        1e-2,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(30)
    .with_parallel(false);
    let mut t = Trainer::new(p, part, cfg);
    for _ in 0..5 {
        t.round();
    }
    let certs_before = t.problem.certificates(&t.alpha, &t.w);
    let ck = Checkpoint::capture(&t);
    // simulate corruption
    for a in t.alpha.iter_mut() {
        *a = f64::NAN;
    }
    for w in t.w.iter_mut() {
        *w = f64::NAN;
    }
    ck.restore(&mut t).expect("restore after corruption");
    let certs_after = t.problem.certificates(&t.alpha, &t.w);
    assert!((certs_before.gap - certs_after.gap).abs() < 1e-12);
    // and training continues fine
    for _ in 0..5 {
        t.round();
    }
    assert!(t.problem.certificates(&t.alpha, &t.w).gap <= certs_after.gap + 1e-9);
}
