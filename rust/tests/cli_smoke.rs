//! CLI smoke tests: drive the built `cocoa` binary end-to-end as a user
//! would (subprocess), covering train / gen-data / sigma / experiment
//! quick paths and failure modes.

use std::path::PathBuf;
use std::process::Command;

fn cocoa_bin() -> Option<PathBuf> {
    // target/<profile>/cocoa next to the test binary
    let mut p = std::env::current_exe().ok()?;
    p.pop(); // deps/
    p.pop(); // release|debug/
    p.push("cocoa");
    p.exists().then_some(p)
}

macro_rules! require_bin {
    () => {
        match cocoa_bin() {
            Some(b) => b,
            None => {
                eprintln!("skipping: cocoa binary not built (run cargo build first)");
                return;
            }
        }
    };
}

fn run(bin: &PathBuf, args: &[&str]) -> (i32, String, String) {
    let out = Command::new(bin)
        .args(args)
        .env("COCOA_RESULTS_DIR", std::env::temp_dir().join("cocoa_cli_smoke"))
        .output()
        .expect("spawn cocoa");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let bin = require_bin!();
    let (code, stdout, _) = run(&bin, &["help"]);
    assert_eq!(code, 0);
    for sub in [
        "train", "gen-data", "sigma", "experiment", "artifacts-check", "serve", "worker",
        "trace-check",
    ] {
        assert!(stdout.contains(sub), "help missing {sub}");
    }
}

#[test]
fn train_socket_executor_runs() {
    // End-to-end through the CLI: the leader spawns `cocoa worker`
    // processes (resolved via current_exe) and trains over sockets.
    let bin = require_bin!();
    let (code, stdout, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "4000", "--k", "2", "--lambda", "1e-2",
            "--rounds", "3", "--gap-tol", "0", "--executor", "socket",
        ],
    );
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("stopped"), "{stdout}");
}

#[test]
fn train_socket_trace_out_emits_valid_trace_and_comm_report() {
    // The PR-9 acceptance path end to end: a socket-executor run with
    // --trace-out must (a) print the measured-vs-simulated communication
    // report (real bytes moved, so wire time was measured), (b) announce
    // the trace file, and (c) emit a file that the binary's own
    // `trace-check` validator accepts, with per-worker lanes and driver
    // round spans.
    let bin = require_bin!();
    let trace = std::env::temp_dir().join("cocoa_cli_trace.json");
    let trace_s = trace.to_str().unwrap();
    let (code, stdout, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "4000", "--k", "2", "--lambda", "1e-2",
            "--rounds", "3", "--gap-tol", "0", "--executor", "socket", "--trace-out", trace_s,
        ],
    );
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(
        stdout.contains("measured vs simulated communication"),
        "comm validation report missing:\n{stdout}"
    );
    assert!(stdout.contains("trace written to"), "{stdout}");

    let (code2, stdout2, stderr2) = run(&bin, &["trace-check", trace_s]);
    assert_eq!(code2, 0, "trace-check failed: {stderr2}");
    assert!(stdout2.contains("OK"), "{stdout2}");

    let text = std::fs::read_to_string(&trace).unwrap();
    assert!(text.contains("\"name\":\"round\""), "driver round spans missing");
    for tid in 1..=2 {
        assert!(
            text.contains(&format!("\"tid\":{tid}")),
            "worker lane {tid} missing from trace"
        );
    }
    std::fs::remove_file(&trace).ok();
}

#[test]
fn trace_check_rejects_invalid_input() {
    let bin = require_bin!();
    let bad = std::env::temp_dir().join("cocoa_cli_trace_bad.json");
    std::fs::write(&bad, "not json").unwrap();
    let (code, _, stderr) = run(&bin, &["trace-check", bad.to_str().unwrap()]);
    assert_eq!(code, 1);
    assert!(stderr.contains("INVALID"), "{stderr}");
    std::fs::remove_file(&bad).ok();
    let (code, _, stderr) = run(&bin, &["trace-check"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("usage"), "{stderr}");
}

#[test]
fn train_unknown_executor_fails() {
    let bin = require_bin!();
    let (code, _, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "4000", "--executor", "warp-drive",
        ],
    );
    assert_ne!(code, 0);
    assert!(stderr.contains("unknown --executor"), "{stderr}");
}

#[test]
fn unknown_subcommand_fails() {
    let bin = require_bin!();
    let (code, _, stderr) = run(&bin, &["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn train_quick_run_converges() {
    let bin = require_bin!();
    let (code, stdout, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "3000", "--k", "4", "--lambda", "1e-2",
            "--epochs", "1", "--rounds", "80", "--gap-tol", "1e-3",
        ],
    );
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("GapReached"), "did not converge:\n{stdout}");
}

#[test]
fn gen_data_roundtrips_through_train() {
    let bin = require_bin!();
    let svm = std::env::temp_dir().join("cocoa_cli_gen.svm");
    let svm_s = svm.to_str().unwrap();
    let (code, stdout, _) = run(
        &bin,
        &["gen-data", "--dataset", "rcv1", "--scale", "3000", "--out", svm_s],
    );
    assert_eq!(code, 0);
    assert!(stdout.contains("wrote"));
    let (code2, stdout2, stderr2) = run(
        &bin,
        &[
            "train", "--data", svm_s, "--k", "2", "--lambda", "1e-2", "--rounds", "40",
            "--gap-tol", "1e-2",
        ],
    );
    assert_eq!(code2, 0, "stderr: {stderr2}");
    assert!(stdout2.contains("stopped"), "{stdout2}");
    std::fs::remove_file(&svm).ok();
}

#[test]
fn train_every_method_runs_and_names_output_by_method() {
    let bin = require_bin!();
    for method in [
        "cocoa-plus",
        "cocoa",
        "mb-sgd",
        "mb-sdca",
        "one-shot",
        "admm",
        "serial-sdca",
    ] {
        let (code, stdout, stderr) = run(
            &bin,
            &[
                "train", "--dataset", "covtype", "--scale", "4000", "--k", "2", "--lambda",
                "1e-2", "--rounds", "5", "--method", method,
            ],
        );
        assert_eq!(code, 0, "--method {method} failed: {stderr}");
        assert!(stdout.contains("stopped"), "--method {method}:\n{stdout}");
        assert!(
            stdout.contains(&format!("method={method}")),
            "--method {method} not echoed:\n{stdout}"
        );
        // outputs are named by method + dataset (no more clobbered last_run.csv)
        assert!(
            stdout.contains(&format!("{method}_covtype.csv")),
            "--method {method} output not method-named:\n{stdout}"
        );
    }
}

#[test]
fn train_unknown_method_fails() {
    let bin = require_bin!();
    let (code, _, stderr) = run(&bin, &["train", "--method", "frobnicate"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("unknown --method"), "{stderr}");
}

#[test]
fn train_gap_every_thins_certificates() {
    let bin = require_bin!();
    let (code, stdout, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "4000", "--k", "2", "--lambda", "1e-2",
            "--rounds", "5", "--gap-tol", "0", "--gap-every", "2", "--parallel", "false",
        ],
    );
    assert_eq!(code, 0, "stderr: {stderr}");
    // rounds 0, 2, 4 evaluated (final round always included)
    let evaluated = stdout.lines().filter(|l| l.starts_with("round ")).count();
    assert_eq!(evaluated, 3, "{stdout}");
}

#[test]
fn sigma_reports_table() {
    let bin = require_bin!();
    let (code, stdout, _) = run(
        &bin,
        &["sigma", "--dataset", "covtype", "--scale", "3000", "--ks", "2,4"],
    );
    assert_eq!(code, 0);
    assert!(stdout.contains("ratio"));
}

#[test]
fn experiment_table2_quick() {
    let bin = require_bin!();
    let (code, stdout, _) = run(
        &bin,
        &["experiment", "table2", "--quick", "--scale", "3000"],
    );
    assert_eq!(code, 0);
    assert!(stdout.contains("covtype"));
}

#[test]
fn experiment_unknown_name_fails() {
    let bin = require_bin!();
    let (code, _, stderr) = run(&bin, &["experiment", "fig9"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown experiment"));
}

/// Minimal HTTP/1.1 exchange for the serve tests (one shot, close).
fn http1(addr: &str, method: &str, path: &str) -> (u16, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let head = format!("{method} {path} HTTP/1.1\r\nConnection: close\r\n\r\n");
    s.write_all(head.as_bytes()).unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status = buf.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, buf)
}

#[test]
fn serve_cli_end_to_end() {
    use std::io::BufRead;
    let bin = require_bin!();
    let ck = std::env::temp_dir().join("cocoa_cli_serve_ck.json");
    let ck_s = ck.to_str().unwrap();
    let (code, stdout, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "3000", "--k", "2", "--lambda", "1e-2",
            "--rounds", "5", "--gap-tol", "0", "--checkpoint-out", ck_s,
        ],
    );
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("checkpoint written"), "{stdout}");

    // Port 0: the CLI must announce the real bound address on stdout.
    let mut child = Command::new(&bin)
        .args(["serve", "--checkpoint", ck_s, "--addr", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn cocoa serve");
    let mut lines = std::io::BufReader::new(child.stdout.take().unwrap()).lines();
    let addr = loop {
        let line = lines.next().expect("serve exited before announcing").unwrap();
        if let Some(rest) = line.strip_prefix("serving on http://") {
            let host = rest.split_whitespace().next().unwrap();
            break host.trim_end_matches('/').to_string();
        }
    };
    let (status, body) = http1(&addr, "GET", "/healthz");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    let (status, _) = http1(&addr, "POST", "/quit");
    assert_eq!(status, 200);
    let exit = child.wait().expect("wait on serve");
    assert!(exit.success(), "serve must exit 0 after /quit");
    let rest: Vec<String> = lines.map(|l| l.unwrap()).collect();
    assert!(rest.iter().any(|l| l.contains("server stopped")), "{rest:?}");
    std::fs::remove_file(&ck).ok();
}

#[test]
fn serve_missing_checkpoint_fails() {
    let bin = require_bin!();
    let (code, _, stderr) = run(&bin, &["serve", "--checkpoint", "/no/such/ck.json"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("cannot load checkpoint"), "{stderr}");
    let (code, _, stderr) = run(&bin, &["serve"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("--checkpoint"), "{stderr}");
}

#[test]
fn checkpoint_out_rejects_primal_only_methods() {
    let bin = require_bin!();
    let out = std::env::temp_dir().join("cocoa_cli_no_ck.json");
    let (code, _, stderr) = run(
        &bin,
        &[
            "train", "--dataset", "covtype", "--scale", "3000", "--k", "2", "--rounds", "2",
            "--method", "mb-sgd", "--checkpoint-out", out.to_str().unwrap(),
        ],
    );
    assert_eq!(code, 2, "stderr: {stderr}");
    assert!(stderr.contains("no checkpointable dual state"), "{stderr}");
    assert!(!out.exists(), "no checkpoint file may be written");
}
