//! End-to-end tests for `cocoa serve`: real TCP traffic against a real
//! trained model. The load-bearing invariants:
//!
//! * served scores are **bit-identical** to leader-side evaluation (same
//!   CSR row construction, same dot kernel, and f64 → JSON → f64 is
//!   exact because the writer emits shortest-roundtrip decimals);
//! * ≥ 64 concurrent connections complete with zero drops and zero
//!   hangs;
//! * hostile input gets a typed 4xx and the server keeps serving;
//! * `/reload` and `/retrain` swap models without failing in-flight
//!   requests, and `/retrain` reproduces an identically-configured local
//!   warm-start run bit-for-bit (the determinism invariant, extended to
//!   the serving path).

use cocoa::coordinator::checkpoint::Checkpoint;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;
use cocoa::serve::{serve, Model, ServeConfig, ServerHandle};
use cocoa::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

const N: usize = 200;
const D: usize = 16;
const K: usize = 4;
const LAMBDA: f64 = 1e-2;

/// Train a model on the deterministic synth problem `name` and capture
/// its full primal-dual state. The returned dataset is the caller-order
/// original the checkpointed α refers to.
fn trained_with(loss: Loss, name: &str, rounds: usize) -> (Dataset, Checkpoint) {
    let data = generate(&SynthConfig::new(name, N, D).seed(7));
    let problem = Problem::new(data.clone(), loss, LAMBDA);
    let part = cocoa::data::partition::random_balanced(N, K, 5);
    let cfg = CocoaConfig::cocoa_plus(K, loss, LAMBDA, SolverSpec::SdcaEpochs { epochs: 1.0 })
        .with_rounds(rounds)
        .with_gap_tol(0.0)
        .with_seed(11)
        .with_parallel(false);
    let mut trainer = Trainer::new(problem, part, cfg);
    Driver::new(StopPolicy::new(rounds).with_gap_tol(0.0)).run(&mut trainer);
    (data, Checkpoint::capture(&trainer))
}

fn start(loss: Loss, name: &str) -> (Dataset, Checkpoint, ServerHandle) {
    let (data, ck) = trained_with(loss, name, 30);
    let model = Model::from_checkpoint(ck.clone(), name).expect("checkpoint is servable");
    let handle = serve(model, ServeConfig::new("127.0.0.1:0")).expect("bind");
    (data, ck, handle)
}

/// One HTTP exchange over a fresh connection; returns (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    try_raw(addr, raw.as_bytes()).expect("request should get a response")
}

/// Send raw bytes, read to EOF, parse the status line and body. Io
/// errors surface as Err so hostile-input tests can tolerate resets.
fn try_raw(addr: SocketAddr, raw: &[u8]) -> std::io::Result<(u16, String)> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(30)))?;
    let _ = s.write_all(raw);
    let mut buf = Vec::new();
    s.read_to_end(&mut buf)?;
    let text = String::from_utf8_lossy(&buf).into_owned();
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("no status line in {text:?}"));
    let body = text
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

fn row_pairs(data: &Dataset, i: usize) -> Vec<(usize, f64)> {
    (data.x.indptr[i]..data.x.indptr[i + 1])
        .map(|j| (data.x.indices[j] as usize, data.x.values[j]))
        .collect()
}

/// Render pairs as the /predict JSON feature shape. f64 `Display` is
/// shortest-roundtrip, so the value survives the wire bit-for-bit.
fn features_json(pairs: &[(usize, f64)]) -> String {
    let items: Vec<String> = pairs.iter().map(|(c, v)| format!("[{c}, {v}]")).collect();
    format!("[{}]", items.join(", "))
}

fn predict_body(data: &Dataset, i: usize) -> String {
    format!("{{\"features\": {}}}", features_json(&row_pairs(data, i)))
}

fn tmp_path(stem: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cocoa_serve_{stem}_{}", std::process::id()))
}

#[test]
fn served_hinge_predictions_match_training_bit_for_bit() {
    let (data, ck, handle) = start(Loss::Hinge, "serve-hinge");
    let addr = handle.addr();
    let mut served_wrong = 0usize;
    for i in 0..data.n() {
        let z = data.x.row_dot(i, &ck.w);
        assert!(z != 0.0, "row {i} sits exactly on the boundary; tie semantics untestable");
        let (status, body) = http(addr, "POST", "/predict", &predict_body(&data, i));
        assert_eq!(status, 200, "row {i}: {body}");
        let j = Json::parse(&body).unwrap();
        let score = j.get("score").unwrap().as_f64().unwrap();
        assert_eq!(score.to_bits(), z.to_bits(), "row {i}: served {score}, leader {z}");
        let label = j.get("label").unwrap().as_f64().unwrap();
        assert_eq!(label, cocoa::loss::classify(z), "row {i}");
        if label != data.y[i] {
            served_wrong += 1;
        }
    }
    // With no boundary rows, served decisions reproduce the leader-side
    // training error exactly.
    let leader_error = data.classification_error(&ck.w);
    assert_eq!(served_wrong as f64 / data.n() as f64, leader_error);
    handle.shutdown();
}

#[test]
fn served_logistic_probabilities_match_sigmoid() {
    let (data, ck, handle) = start(Loss::Logistic, "serve-logit");
    let addr = handle.addr();
    for i in (0..data.n()).step_by(4) {
        let z = data.x.row_dot(i, &ck.w);
        let (status, body) = http(addr, "POST", "/predict", &predict_body(&data, i));
        assert_eq!(status, 200, "row {i}: {body}");
        let j = Json::parse(&body).unwrap();
        let p = j.get("prediction").unwrap().as_f64().unwrap();
        let expected = cocoa::loss::logistic::sigmoid(z);
        assert!(
            (p - expected).abs() < 1e-12,
            "row {i}: served p = {p}, leader σ(z) = {expected}"
        );
        assert!((0.0..=1.0).contains(&p), "row {i}: {p} is not a probability");
    }
    handle.shutdown();
}

#[test]
fn batch_predict_matches_singles() {
    let (data, ck, handle) = start(Loss::Hinge, "serve-batch");
    let addr = handle.addr();
    let rows: Vec<String> = (0..8).map(|i| features_json(&row_pairs(&data, i))).collect();
    let body = format!("{{\"rows\": [{}]}}", rows.join(", "));
    let (status, resp) = http(addr, "POST", "/predict", &body);
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("count").unwrap().as_f64(), Some(8.0));
    let preds = j.get("predictions").unwrap().as_arr().unwrap();
    for (i, p) in preds.iter().enumerate() {
        let z = data.x.row_dot(i, &ck.w);
        let score = p.get("score").unwrap().as_f64().unwrap();
        assert_eq!(score.to_bits(), z.to_bits(), "row {i}");
    }
    handle.shutdown();
}

#[test]
fn sixty_four_concurrent_connections_zero_drops() {
    let (data, _ck, handle) = start(Loss::Hinge, "serve-conc");
    let addr = handle.addr();
    const CLIENTS: usize = 64;
    const PER_CLIENT: usize = 4;
    let threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let body = predict_body(&data, c % data.n());
            std::thread::spawn(move || {
                for _ in 0..PER_CLIENT {
                    let (status, resp) = http(addr, "POST", "/predict", &body);
                    assert_eq!(status, 200, "client {c}: {resp}");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("no client may fail or hang");
    }
    let metrics = &handle.state().metrics;
    assert!(
        metrics.requests_total() >= (CLIENTS * PER_CLIENT) as u64,
        "every connection must be counted"
    );
    // The last in-flight decrement races the final client's EOF by a few
    // instructions; give it a moment, then require a quiesced gauge.
    let deadline = Instant::now() + Duration::from_secs(2);
    while metrics.in_flight() != 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(metrics.in_flight(), 0, "no request may leak in-flight");
    handle.shutdown();
}

#[test]
fn hostile_requests_get_4xx_and_server_survives() {
    let (data, _ck, handle) = start(Loss::Hinge, "serve-hostile");
    let addr = handle.addr();

    let (status, _) = try_raw(addr, b"GARBAGE\r\n\r\n").unwrap();
    assert_eq!(status, 400, "unparseable request line");
    let (status, _) = try_raw(addr, b"GET /healthz HTTP/1.1\r\nno colon here\r\n\r\n").unwrap();
    assert_eq!(status, 400, "malformed header");
    let (status, _) = http(addr, "GET", "/no/such/endpoint", "");
    assert_eq!(status, 404);
    let (status, _) = http(addr, "GET", "/predict", "");
    assert_eq!(status, 405, "wrong method on a real endpoint");
    let (status, body) = http(addr, "POST", "/predict", "this is not json");
    assert_eq!(status, 400, "{body}");

    // Declared-oversize body: rejected from the Content-Length header
    // alone, before any allocation.
    let raw = b"POST /predict HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n";
    let (status, _) = try_raw(addr, raw).unwrap();
    assert_eq!(status, 413);

    // Oversized head: the server cuts the read off at the cap and
    // answers 431; a client still pushing bytes may instead see a reset,
    // which is an acceptable outcome for abuse — the server must not.
    let mut big = Vec::from(&b"GET /healthz HTTP/1.1\r\nX-Pad: "[..]);
    big.extend(vec![b'a'; 20 * 1024]);
    if let Ok((status, _)) = try_raw(addr, &big) {
        assert_eq!(status, 431);
    }

    // After all of that abuse the server still serves correct answers.
    let (status, body) = http(addr, "POST", "/predict", &predict_body(&data, 0));
    assert_eq!(status, 200, "{body}");
    let (status, _) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    handle.shutdown();
}

#[test]
fn stalled_client_is_timed_out_without_hurting_the_server() {
    let (_data, ck) = trained_with(Loss::Hinge, "serve-stall", 30);
    let model = Model::from_checkpoint(ck, "stall").unwrap();
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.read_timeout = Duration::from_millis(200);
    let handle = serve(model, cfg).expect("bind");
    let addr = handle.addr();

    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    // half a request line, then silence: the server must cut us off
    s.write_all(b"POST /predict HT").unwrap();
    let mut buf = String::new();
    let _ = s.read_to_string(&mut buf);
    if !buf.is_empty() {
        assert!(buf.starts_with("HTTP/1.1 408"), "got {buf:?}");
    }
    let (status, _) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200, "server must survive a stalled client");
    handle.shutdown();
}

#[test]
fn reload_swaps_checkpoints_under_live_traffic() {
    let (data, ck_old) = trained_with(Loss::Hinge, "serve-reload", 3);
    let (_, ck_new) = trained_with(Loss::Hinge, "serve-reload", 30);
    assert_ne!(ck_old.w, ck_new.w, "the two checkpoints must be distinguishable");
    let ck_path = tmp_path("reload.json");
    ck_new.save(&ck_path).unwrap();

    let model = Model::from_checkpoint(ck_old, "old").unwrap();
    let handle = serve(model, ServeConfig::new("127.0.0.1:0")).expect("bind");
    let addr = handle.addr();

    let hammers: Vec<_> = (0..8)
        .map(|c| {
            let body = predict_body(&data, c);
            std::thread::spawn(move || {
                for _ in 0..30 {
                    let (status, resp) = http(addr, "POST", "/predict", &body);
                    assert_eq!(status, 200, "in-flight request failed across reload: {resp}");
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    let body = format!("{{\"checkpoint\": {:?}}}", ck_path.display().to_string());
    let (status, resp) = http(addr, "POST", "/reload", &body);
    assert_eq!(status, 200, "{resp}");
    for t in hammers {
        t.join().expect("no request may fail during a reload");
    }

    // Post-reload scores come from the new weights, bit-for-bit.
    let z_new = data.x.row_dot(0, &ck_new.w);
    let (status, resp) = http(addr, "POST", "/predict", &predict_body(&data, 0));
    assert_eq!(status, 200, "{resp}");
    let served = Json::parse(&resp).unwrap().get("score").unwrap().as_f64().unwrap();
    assert_eq!(served.to_bits(), z_new.to_bits());
    let m = handle.state().metrics.to_json();
    assert_eq!(m.get("reloads_total").unwrap().as_f64(), Some(1.0));
    handle.shutdown();
    let _ = std::fs::remove_file(&ck_path);
}

#[test]
fn retrain_warm_start_matches_local_run_bit_for_bit() {
    let (data, ck) = trained_with(Loss::Hinge, "serve-retrain", 30);
    // Drift: flip every 10th label, write as libsvm.
    let mut drift = data.clone();
    for i in (0..drift.n()).step_by(10) {
        drift.y[i] = -drift.y[i];
    }
    let drift_path = tmp_path("drift.svm");
    cocoa::data::libsvm::save(&drift, &drift_path).unwrap();

    let model = Model::from_checkpoint(ck.clone(), "base").unwrap();
    let handle = serve(model, ServeConfig::new("127.0.0.1:0")).expect("bind");
    let addr = handle.addr();

    // Wrong-sized drift data is a client error, not a crash.
    let small = generate(&SynthConfig::new("serve-retrain-small", 50, D).seed(1));
    let small_path = tmp_path("small.svm");
    cocoa::data::libsvm::save(&small, &small_path).unwrap();
    let body = format!("{{\"data\": {:?}}}", small_path.display().to_string());
    let (status, resp) = http(addr, "POST", "/retrain", &body);
    assert_eq!(status, 400, "{resp}");

    let body = format!(
        "{{\"data\": {:?}, \"rounds\": 20, \"seed\": 9}}",
        drift_path.display().to_string()
    );
    let (status, resp) = http(addr, "POST", "/retrain", &body);
    assert_eq!(status, 200, "{resp}");
    let j = Json::parse(&resp).unwrap();
    assert_eq!(j.get("status").unwrap().as_str(), Some("retrained"));
    assert!(j.get("rounds_run").unwrap().as_f64().unwrap() >= 1.0);

    // Mirror the retrain locally with the identical configuration; the
    // served model must match it bit-for-bit (determinism invariant).
    let reloaded = cocoa::data::libsvm::load(&drift_path, Some(ck.d)).unwrap();
    let problem = Problem::new(reloaded.clone(), Loss::Hinge, ck.lambda);
    let part = cocoa::data::partition::random_balanced(ck.n, ck.k, 9);
    let cfg = CocoaConfig::cocoa_plus(
        ck.k,
        Loss::Hinge,
        ck.lambda,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(20)
    .with_gap_tol(1e-4)
    .with_seed(9);
    let mut local = Trainer::new(problem, part, cfg);
    local.warm_start_from_alpha(&ck.alpha).unwrap();
    Driver::new(
        StopPolicy::new(20)
            .with_gap_tol(1e-4)
            .with_divergence_gap(f64::INFINITY),
    )
    .run(&mut local);

    let z_local = reloaded.x.row_dot(0, &local.w);
    let pairs = row_pairs(&reloaded, 0);
    let body = format!("{{\"features\": {}}}", features_json(&pairs));
    let (status, resp) = http(addr, "POST", "/predict", &body);
    assert_eq!(status, 200, "{resp}");
    let served = Json::parse(&resp).unwrap().get("score").unwrap().as_f64().unwrap();
    assert_eq!(
        served.to_bits(),
        z_local.to_bits(),
        "served retrained model diverged from the local mirror"
    );
    handle.shutdown();
    let _ = std::fs::remove_file(&drift_path);
    let _ = std::fs::remove_file(&small_path);
}

#[test]
fn quit_drains_and_stops_the_server() {
    let (_data, _ck, handle) = start(Loss::Hinge, "serve-quit");
    let addr = handle.addr();
    let (status, _) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let (status, body) = http(addr, "POST", "/quit", "");
    assert_eq!(status, 200, "{body}");
    // wait() returning at all is the assertion: quit must not hang.
    handle.wait();
    // The listener is gone; fresh connections are refused (give the OS a
    // beat to tear the socket down).
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        TcpStream::connect(addr).is_err(),
        "listener must be closed after /quit"
    );
}
