//! Determinism suite for the trainer runtimes: with a fixed seed, the
//! pooled-thread, sequential, and socket-process executors must produce
//! **bit-identical** trajectories — gap records, the global dual iterate
//! α, and the shared primal vector w — for both aggregation regimes of
//! the paper (CoCoA: γ=1/K, σ'=1; CoCoA+: γ=1, σ'=K).
//!
//! This is what makes the pool's scratch reuse safe to rely on: any
//! cross-round buffer contamination, scheduling-order dependence, or
//! misrouted reduce would break bit-identity within a few rounds. For the
//! socket executor it additionally proves the wire format is bit-exact:
//! a single f64 rounded in transit would diverge the trajectory.

use cocoa::data::partition::{contiguous, random_balanced};
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::prelude::*;

const ROUNDS: usize = 8;

fn build(k: usize, plus: bool, parallel: bool, seed: u64) -> Trainer {
    let n = 96;
    let d = 12;
    let data = generate(&SynthConfig::new("det", n, d).seed(7));
    let part = random_balanced(n, k, 3);
    let problem = Problem::new(data, Loss::Hinge, 0.01);
    let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
    let cfg = if plus {
        CocoaConfig::cocoa_plus(k, Loss::Hinge, 0.01, solver)
    } else {
        CocoaConfig::cocoa(k, Loss::Hinge, 0.01, solver)
    }
    .with_rounds(ROUNDS)
    .with_gap_tol(1e-14)
    .with_seed(seed)
    .with_parallel(parallel);
    Trainer::new(problem, part, cfg)
}

/// Same problem/partition/config as [`build`], but executed by K worker
/// *processes* over the wire protocol.
fn build_socket(k: usize, plus: bool, seed: u64) -> Trainer {
    let n = 96;
    let d = 12;
    let data = generate(&SynthConfig::new("det", n, d).seed(7));
    let part = random_balanced(n, k, 3);
    let problem = Problem::new(data, Loss::Hinge, 0.01);
    let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
    let cfg = if plus {
        CocoaConfig::cocoa_plus(k, Loss::Hinge, 0.01, solver)
    } else {
        CocoaConfig::cocoa(k, Loss::Hinge, 0.01, solver)
    }
    .with_rounds(ROUNDS)
    .with_gap_tol(1e-14)
    .with_seed(seed)
    .with_executor(ExecutorChoice::Socket)
    .with_socket_worker_bin(env!("CARGO_BIN_EXE_cocoa"));
    Trainer::new(problem, part, cfg)
}

/// Run to completion; return the bitwise gap trajectory plus final (α, w).
fn trajectory(mut t: Trainer) -> (Vec<u64>, Vec<f64>, Vec<f64>) {
    let hist = t.run();
    let gaps = hist.records.iter().map(|r| r.gap.to_bits()).collect();
    (gaps, t.alpha, t.w)
}

fn assert_bit_identical(k: usize, plus: bool, seed: u64) {
    let pooled = build(k, plus, true, seed);
    let sequential = build(k, plus, false, seed);
    assert_eq!(pooled.executor_kind(), "pooled");
    assert_eq!(sequential.executor_kind(), "sequential");
    let (gaps_p, alpha_p, w_p) = trajectory(pooled);
    let (gaps_s, alpha_s, w_s) = trajectory(sequential);
    let variant = if plus { "cocoa+" } else { "cocoa" };
    assert_eq!(
        gaps_p, gaps_s,
        "{variant} K={k}: gap trajectory diverged between runtimes"
    );
    assert_eq!(alpha_p, alpha_s, "{variant} K={k}: α diverged");
    assert_eq!(w_p, w_s, "{variant} K={k}: w diverged");
}

#[test]
fn pooled_matches_sequential_cocoa_plus() {
    // γ = 1, σ' = K — the paper's adding regime.
    assert_bit_identical(4, true, 42);
}

#[test]
fn pooled_matches_sequential_cocoa() {
    // γ = 1/K, σ' = 1 — the conservative averaging regime (Remark 12).
    assert_bit_identical(4, false, 42);
}

/// The tentpole invariant: sequential ≡ pooled ≡ socket, bit for bit.
fn assert_three_way_identical(k: usize, plus: bool, seed: u64) {
    let socket = build_socket(k, plus, seed);
    assert_eq!(socket.executor_kind(), "socket");
    let (gaps_x, alpha_x, w_x) = trajectory(socket);
    let (gaps_s, alpha_s, w_s) = trajectory(build(k, plus, false, seed));
    let variant = if plus { "cocoa+" } else { "cocoa" };
    assert_eq!(
        gaps_x, gaps_s,
        "{variant} K={k}: socket gap trajectory diverged from sequential"
    );
    assert_eq!(alpha_x, alpha_s, "{variant} K={k}: socket α diverged");
    assert_eq!(w_x, w_s, "{variant} K={k}: socket w diverged");
    // sequential ≡ pooled is covered above; close the triangle anyway so
    // this one test names the invariant end to end.
    let (gaps_p, alpha_p, w_p) = trajectory(build(k, plus, true, seed));
    assert_eq!(gaps_x, gaps_p, "{variant} K={k}: socket diverged from pooled");
    assert_eq!(alpha_x, alpha_p);
    assert_eq!(w_x, w_p);
}

#[test]
fn socket_matches_in_process_cocoa_plus() {
    assert_three_way_identical(4, true, 42);
}

#[test]
fn socket_matches_in_process_cocoa() {
    assert_three_way_identical(4, false, 42);
}

#[test]
fn pooled_matches_sequential_across_k_and_seeds() {
    for k in [2, 8] {
        for seed in [1, 99] {
            assert_bit_identical(k, true, seed);
        }
    }
}

#[test]
fn pooled_matches_sequential_under_permuted_contiguous_layout() {
    // CoCoA+ under both realizations of the shared data plane:
    //  * a shuffled partition, which the trainer canonicalizes by
    //    permuting the dataset once (all shards view the permuted copy);
    //  * an already-contiguous partition, where shards view the caller's
    //    dataset directly (zero-copy, identity permutation).
    // Both must stay bit-identical across runtimes, and the layout itself
    // must be deterministic: two trainers from the same partition agree.
    let n = 96;
    let build_contig = |parallel: bool| {
        let data = generate(&SynthConfig::new("det-c", n, 12).seed(7));
        let part = contiguous(n, 4);
        let problem = Problem::new(data, Loss::Hinge, 0.01);
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            0.01,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(ROUNDS)
        .with_gap_tol(1e-14)
        .with_seed(42)
        .with_parallel(parallel);
        Trainer::new(problem, part, cfg)
    };
    let contig = build_contig(true);
    assert!(contig.rows.is_identity(), "contiguous layout must not permute");
    let (gaps_p, alpha_p, w_p) = trajectory(contig);
    let (gaps_s, alpha_s, w_s) = trajectory(build_contig(false));
    assert_eq!(gaps_p, gaps_s, "contiguous layout: gap trajectory diverged");
    assert_eq!(alpha_p, alpha_s);
    assert_eq!(w_p, w_s);

    // permuted path (random partition): the layout maps must agree across
    // runtimes, so original-order α does too.
    let pooled = build(4, true, true, 9);
    let sequential = build(4, true, false, 9);
    assert!(!pooled.rows.is_identity(), "random partition must permute");
    assert_eq!(pooled.rows.new_to_old, sequential.rows.new_to_old);
    let mut pooled = pooled;
    let mut sequential = sequential;
    pooled.run();
    sequential.run();
    assert_eq!(pooled.alpha_original(), sequential.alpha_original());
}

#[test]
fn tracing_enabled_is_bit_identical_to_untraced() {
    // The flight recorder is observe-only by contract: attaching it to a
    // run must not perturb a single bit of the trajectory. The traced run
    // uses the pooled executor, where a recorder that synchronized or
    // reordered anything would show up immediately.
    use cocoa::telemetry::Recorder;
    let path = std::env::temp_dir().join("cocoa_det_traced.json");
    let rec = Recorder::to_file(&path).expect("open trace file");
    let n = 96;
    let data = generate(&SynthConfig::new("det", n, 12).seed(7));
    let part = random_balanced(n, 4, 3);
    let problem = Problem::new(data, Loss::Hinge, 0.01);
    let cfg = CocoaConfig::cocoa_plus(
        4,
        Loss::Hinge,
        0.01,
        SolverSpec::SdcaEpochs { epochs: 1.0 },
    )
    .with_rounds(ROUNDS)
    .with_gap_tol(1e-14)
    .with_seed(42)
    .with_parallel(true)
    .with_recorder(rec.clone());
    let traced = Trainer::new(problem, part, cfg);
    let (gaps_t, alpha_t, w_t) = trajectory(traced);
    let sum = rec.finish().expect("finish trace");
    assert!(sum.events > 0, "the traced run must actually record");

    let (gaps, alpha, w) = trajectory(build(4, true, true, 42));
    assert_eq!(gaps_t, gaps, "tracing perturbed the gap trajectory");
    assert_eq!(alpha_t, alpha, "tracing perturbed α");
    assert_eq!(w_t, w, "tracing perturbed w");
    std::fs::remove_file(&path).ok();
}

#[test]
fn simd_and_scalar_kernels_are_bit_identical_end_to_end() {
    // The explicit-SIMD kernels (linalg::simd) promise bit-identical
    // results to the portable scalar path: same 4-lane split, same
    // fixed reduction order, mul-then-add on both sides. Re-run the
    // trajectory with the dispatch pinned to each side — if AVX2 ever
    // reassociated a sum, this diverges within a round. (Flipping the
    // global dispatch mid-suite is safe for exactly this reason.)
    use cocoa::linalg::simd;
    simd::force_scalar(true);
    let (gaps_sc, alpha_sc, w_sc) = trajectory(build(4, true, true, 42));
    simd::force_scalar(false);
    let (gaps_v, alpha_v, w_v) = trajectory(build(4, true, true, 42));
    assert_eq!(gaps_sc, gaps_v, "SIMD dispatch changed the gap trajectory");
    assert_eq!(alpha_sc, alpha_v, "SIMD dispatch changed α");
    assert_eq!(w_sc, w_v, "SIMD dispatch changed w");
    // and the three-executor invariant holds with detection re-enabled
    // (socket workers resolve their own dispatch in fresh processes)
    assert_three_way_identical(4, true, 42);
}

#[test]
fn pooled_runs_are_repeatable() {
    // Two independent pooled trainers with the same seed: thread
    // scheduling must not be able to perturb anything.
    let (gaps_a, alpha_a, w_a) = trajectory(build(4, true, true, 5));
    let (gaps_b, alpha_b, w_b) = trajectory(build(4, true, true, 5));
    assert_eq!(gaps_a, gaps_b);
    assert_eq!(alpha_a, alpha_b);
    assert_eq!(w_a, w_b);
}

#[test]
fn scratch_reuse_is_clean_across_many_rounds() {
    // Drive one pooled trainer well past the buffer warm-up and compare
    // against a fresh sequential reference round-by-round: stale scratch
    // contents from round t would corrupt round t+1.
    let mut pooled = build(4, true, true, 11);
    let mut sequential = build(4, true, false, 11);
    for round in 0..20 {
        pooled.round();
        sequential.round();
        assert_eq!(
            pooled.alpha, sequential.alpha,
            "α diverged at round {round}"
        );
        assert_eq!(pooled.w, sequential.w, "w diverged at round {round}");
    }
    assert!(pooled.primal_consistency_error() < 1e-9);
}
