//! Conformance suite for the unified `Method` step-API: every optimizer
//! registered in `driver::registry` must behave identically under the
//! shared `Driver` loop — steps advance the simulated cluster clock,
//! certificate gaps are non-negative, communication totals are monotone,
//! and each `StopPolicy` rule actually stops the run.

use cocoa::baselines::serial_sdca;
use cocoa::data::partition::random_balanced;
use cocoa::data::synth::{generate, SynthConfig};
use cocoa::driver::build_method;
use cocoa::prelude::*;

const K: usize = 3;
const N: usize = 90;
const D: usize = 10;

fn setup() -> (Problem, Partition) {
    let data = generate(&SynthConfig::new("conf", N, D).seed(17));
    let problem = Problem::new(data, Loss::Hinge, 0.05);
    let part = random_balanced(N, K, 5);
    (problem, part)
}

fn opts() -> BuildOpts {
    let mut o = BuildOpts::new(K);
    o.seed = 11;
    o.parallel = false; // keep the suite single-threaded and fast
    o.batch_per_worker = 8;
    o.local_iters = 10;
    o
}

#[test]
fn every_method_conforms_under_the_driver() {
    for name in MethodName::ALL {
        let (problem, part) = setup();
        let mut method = build_method(name, problem, part, &opts());

        assert!(!method.label().is_empty(), "{name:?}: empty label");
        assert_eq!(method.w().len(), D, "{name:?}: w has wrong dimension");

        let rounds = 4;
        let mut driver = Driver::new(
            StopPolicy::new(rounds)
                .with_gap_tol(f64::NEG_INFINITY)
                .with_divergence_gap(f64::INFINITY),
        );
        let hist = driver.run(method.as_mut());

        assert_eq!(hist.stop, StopReason::MaxRounds, "{name:?}");
        assert_eq!(hist.records.len(), rounds, "{name:?}: gap_every=1 records");

        // The sim clock advances and never runs backwards.
        let last = hist.records.last().unwrap();
        assert!(
            last.sim_time_s > 0.0,
            "{name:?}: sim clock did not advance: {}",
            last.sim_time_s
        );
        for pair in hist.records.windows(2) {
            assert!(
                pair[1].sim_time_s >= pair[0].sim_time_s,
                "{name:?}: sim clock ran backwards"
            );
            assert!(
                pair[1].comm_vectors >= pair[0].comm_vectors,
                "{name:?}: comm vectors decreased"
            );
            assert!(
                pair[1].compute_s >= pair[0].compute_s,
                "{name:?}: compute time decreased"
            );
        }

        // eval: gap non-negative (weak duality for dual methods, primal
        // value / suboptimality for primal-only ones), primal finite.
        for r in &hist.records {
            assert!(r.gap >= -1e-9, "{name:?}: negative gap {}", r.gap);
            assert!(r.primal.is_finite(), "{name:?}: non-finite primal");
        }

        // comm accounting: serial SDCA moves nothing, every distributed
        // method moves one vector per worker per communicating round.
        match name {
            MethodName::SerialSdca => {
                assert_eq!(method.comm_vectors_per_round(), 0, "{name:?}");
                assert_eq!(last.comm_vectors, 0, "{name:?}");
            }
            MethodName::OneShot => {
                // single communication round, then free no-ops
                assert_eq!(method.comm_vectors_per_round(), K, "{name:?}");
                assert_eq!(last.comm_vectors, K, "{name:?}");
            }
            _ => {
                assert_eq!(method.comm_vectors_per_round(), K, "{name:?}");
                assert_eq!(last.comm_vectors, K * rounds, "{name:?}");
            }
        }
    }
}

#[test]
fn one_shot_extra_rounds_do_not_inflate_the_clock() {
    let (problem, part) = setup();
    let mut method = build_method(MethodName::OneShot, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(5)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_divergence_gap(f64::INFINITY),
    );
    let hist = driver.run(method.as_mut());
    let first = hist.records.first().unwrap();
    let last = hist.records.last().unwrap();
    assert_eq!(first.sim_time_s, last.sim_time_s);
    assert_eq!(first.comm_vectors, last.comm_vectors);
}

#[test]
fn one_shot_unbalanced_partition_is_uncertifiable_not_diverged() {
    // With n not divisible by K the scaled global dual can leave the
    // hinge box (scale > 1 on small blocks): the gap is legitimately
    // +∞. With divergence disabled the Driver must record it and run to
    // the budget instead of flagging divergence; NaN would still abort.
    let data = generate(&SynthConfig::new("conf-unbal", 100, D).seed(23));
    let problem = Problem::new(data, Loss::Hinge, 0.05);
    let part = random_balanced(100, K, 5); // 100 = 34 + 33 + 33
    let mut method = build_method(MethodName::OneShot, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(2)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_divergence_gap(f64::INFINITY),
    );
    let hist = driver.run(method.as_mut());
    assert!(!hist.diverged(), "infinite gap misreported as divergence");
    assert_eq!(hist.stop, StopReason::MaxRounds);
    assert!(hist.records[0].primal.is_finite());
}

#[test]
fn driver_honors_gap_tolerance_for_every_dual_method() {
    // The three methods with a true duality-gap certificate converge on
    // this easy problem; the Driver must stop them at the tolerance.
    for name in [
        MethodName::CocoaPlus,
        MethodName::Cocoa,
        MethodName::SerialSdca,
    ] {
        let (problem, part) = setup();
        let mut method = build_method(name, problem, part, &opts());
        let mut driver = Driver::new(StopPolicy::new(2000).with_gap_tol(1e-3));
        let hist = driver.run(method.as_mut());
        assert_eq!(
            hist.stop,
            StopReason::GapReached,
            "{name:?}: final gap {}",
            hist.final_gap()
        );
        assert!(hist.final_gap() <= 1e-3, "{name:?}");
    }
}

#[test]
fn driver_honors_dual_target_rule() {
    let (problem, part) = setup();
    let d_star = serial_sdca::estimate_d_star(&problem, 11);
    let mut method = build_method(MethodName::CocoaPlus, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(2000)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_dual_target(d_star, 1e-3),
    );
    let hist = driver.run(method.as_mut());
    assert_eq!(hist.stop, StopReason::DualTargetReached);
    assert!(d_star - hist.final_dual() <= 1e-3);
}

#[test]
fn driver_honors_divergence_rule() {
    // A divergence threshold below the initial gap trips immediately —
    // the rule itself, independent of an actually divergent run.
    let (problem, part) = setup();
    let mut method = build_method(MethodName::CocoaPlus, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(100)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_divergence_gap(1e-12),
    );
    let hist = driver.run(method.as_mut());
    assert_eq!(hist.stop, StopReason::Diverged);
    assert!(hist.diverged());
}

#[test]
fn driver_honors_dual_stall_rule() {
    // An impossible improvement threshold stalls after `patience` evals.
    let (problem, part) = setup();
    let mut method = build_method(MethodName::CocoaPlus, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(100)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_dual_stall(2, 1e9),
    );
    let hist = driver.run(method.as_mut());
    assert_eq!(hist.stop, StopReason::DualStalled);
    assert_eq!(hist.rounds_run(), 3); // 1 best-setting eval + 2 stalled
}

#[test]
fn primal_only_methods_ignore_dual_rules() {
    // SGD reports dual = −∞; dual-target and dual-stall must never fire.
    let (problem, part) = setup();
    let mut method = build_method(MethodName::MbSgd, problem, part, &opts());
    let mut driver = Driver::new(
        StopPolicy::new(5)
            .with_gap_tol(f64::NEG_INFINITY)
            .with_divergence_gap(f64::INFINITY)
            .with_dual_target(0.0, 1e9)
            .with_dual_stall(1, 1e9),
    );
    let hist = driver.run(method.as_mut());
    assert_eq!(hist.stop, StopReason::MaxRounds);
}

#[test]
fn trainer_run_matches_explicit_driver_bitwise() {
    // Trainer::run routes through Driver::from_cocoa_config; an explicit
    // Driver with the same policy must reproduce the trajectory exactly.
    let mk_trainer = || {
        let (problem, part) = setup();
        let cfg = CocoaConfig::cocoa_plus(
            K,
            Loss::Hinge,
            0.05,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(6)
        .with_seed(11)
        .with_parallel(false);
        Trainer::new(problem, part, cfg)
    };
    let mut a = mk_trainer();
    let hist_a = a.run();
    let mut b = mk_trainer();
    let mut driver = Driver::from_cocoa_config(&b.cfg);
    let hist_b = driver.run(&mut b);
    let gaps_a: Vec<u64> = hist_a.records.iter().map(|r| r.gap.to_bits()).collect();
    let gaps_b: Vec<u64> = hist_b.records.iter().map(|r| r.gap.to_bits()).collect();
    assert_eq!(gaps_a, gaps_b);
    assert_eq!(a.alpha, b.alpha);
    assert_eq!(a.w, b.w);
    assert_eq!(hist_a.stop, hist_b.stop);
}
