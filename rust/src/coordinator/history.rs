//! Per-round training history: the raw series behind every figure.
//!
//! Histories are produced by the [`Driver`](crate::driver::Driver) run
//! loop for every [`Method`](crate::driver::Method), serialize to CSV
//! and JSON, and parse back ([`History::from_csv`] /
//! [`History::from_json`]) so recorded series round-trip through the
//! `results/` directory.

use crate::util::json::{jarr, jnum, jobj, jstr, Json};

/// One evaluated round (certificates are computed every `gap_every`
/// rounds, so records may be sparser than rounds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Cumulative communicated vectors (paper's Fig. 1 x-axis).
    pub comm_vectors: usize,
    /// Cumulative simulated cluster time: measured max-worker compute +
    /// modeled network (paper's elapsed-time x-axis).
    pub sim_time_s: f64,
    /// Cumulative measured local-compute seconds (max over workers/round).
    pub compute_s: f64,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// Why a run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    GapReached,
    MaxRounds,
    Diverged,
    DualStalled,
    /// The Fig.-2 criterion: dual suboptimality D(α*) − D(α) reached the
    /// configured ε_D target.
    DualTargetReached,
}

impl StopReason {
    /// Stable serialization name (JSON `stop` field, CSV `# stop=` line).
    pub fn as_str(&self) -> &'static str {
        match self {
            StopReason::GapReached => "gap_reached",
            StopReason::MaxRounds => "max_rounds",
            StopReason::Diverged => "diverged",
            StopReason::DualStalled => "dual_stalled",
            StopReason::DualTargetReached => "dual_target_reached",
        }
    }

    pub fn parse(s: &str) -> Option<StopReason> {
        match s {
            "gap_reached" => Some(StopReason::GapReached),
            "max_rounds" => Some(StopReason::MaxRounds),
            "diverged" => Some(StopReason::Diverged),
            "dual_stalled" => Some(StopReason::DualStalled),
            "dual_target_reached" => Some(StopReason::DualTargetReached),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct History {
    pub label: String,
    pub records: Vec<RoundRecord>,
    pub stop: StopReason,
}

impl History {
    pub fn new(label: &str) -> History {
        History {
            label: label.to_string(),
            records: Vec::new(),
            stop: StopReason::MaxRounds,
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_gap(&self) -> f64 {
        self.records.last().map(|r| r.gap).unwrap_or(f64::INFINITY)
    }

    pub fn final_dual(&self) -> f64 {
        self.records
            .last()
            .map(|r| r.dual)
            .unwrap_or(f64::NEG_INFINITY)
    }

    pub fn best_dual(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.dual)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn rounds_run(&self) -> usize {
        self.records.last().map(|r| r.round + 1).unwrap_or(0)
    }

    /// First record index where gap ≤ tol, with its simulated time and
    /// communicated-vector count. None if never reached.
    pub fn time_to_gap(&self, tol: f64) -> Option<(usize, f64, usize)> {
        self.records
            .iter()
            .find(|r| r.gap <= tol)
            .map(|r| (r.round, r.sim_time_s, r.comm_vectors))
    }

    /// First simulated time where the dual suboptimality D(α*)−D(α) ≤ tol,
    /// given an externally estimated optimum (Fig. 2's y-axis needs this).
    pub fn time_to_dual_subopt(&self, d_star: f64, tol: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| d_star - r.dual <= tol)
            .map(|r| r.sim_time_s)
    }

    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }

    /// The CSV column header (shared by [`History::to_csv`] and the
    /// streaming CSV observer).
    pub fn csv_header() -> &'static str {
        "round,comm_vectors,sim_time_s,compute_s,primal,dual,gap\n"
    }

    /// One CSV row. Floats use Rust's shortest round-trip formatting so
    /// [`History::from_csv`] reconstructs the series exactly
    /// (infinities print as `inf`/`-inf`, which also parse back).
    pub fn csv_row(r: &RoundRecord) -> String {
        format!(
            "{},{},{},{},{},{},{}\n",
            r.round, r.comm_vectors, r.sim_time_s, r.compute_s, r.primal, r.dual, r.gap
        )
    }

    /// CSV serialization: `# label=` / `# stop=` comment lines, the
    /// column header, then one row per record.
    pub fn to_csv(&self) -> String {
        let mut out = format!("# label={}\n# stop={}\n", self.label, self.stop.as_str());
        out.push_str(Self::csv_header());
        for r in &self.records {
            out.push_str(&Self::csv_row(r));
        }
        out
    }

    /// Parse [`History::to_csv`] output (the `#` comment lines are
    /// optional — a streamed CSV without them parses with default
    /// label/stop).
    pub fn from_csv(text: &str) -> Result<History, String> {
        let mut label = String::from("history");
        let mut stop = StopReason::MaxRounds;
        let mut records = Vec::new();
        let mut saw_header = false;
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('#') {
                let rest = rest.trim();
                if let Some(v) = rest.strip_prefix("label=") {
                    label = v.to_string();
                } else if let Some(v) = rest.strip_prefix("stop=") {
                    stop = StopReason::parse(v)
                        .ok_or_else(|| format!("line {}: unknown stop reason {v:?}", idx + 1))?;
                }
                continue;
            }
            if !saw_header {
                if line != Self::csv_header().trim_end() {
                    return Err(format!("line {}: unexpected header {line:?}", idx + 1));
                }
                saw_header = true;
                continue;
            }
            let cells: Vec<&str> = line.split(',').collect();
            if cells.len() != 7 {
                return Err(format!(
                    "line {}: expected 7 cells, got {}",
                    idx + 1,
                    cells.len()
                ));
            }
            let fnum = |i: usize| -> Result<f64, String> {
                cells[i]
                    .parse()
                    .map_err(|e| format!("line {}: {e}", idx + 1))
            };
            records.push(RoundRecord {
                round: cells[0]
                    .parse()
                    .map_err(|e| format!("line {}: {e}", idx + 1))?,
                comm_vectors: cells[1]
                    .parse()
                    .map_err(|e| format!("line {}: {e}", idx + 1))?,
                sim_time_s: fnum(2)?,
                compute_s: fnum(3)?,
                primal: fnum(4)?,
                dual: fnum(5)?,
                gap: fnum(6)?,
            });
        }
        if !saw_header {
            return Err("missing csv header".into());
        }
        Ok(History {
            label,
            records,
            stop,
        })
    }

    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("label", jstr(&self.label)),
            ("stop", jstr(self.stop.as_str())),
            (
                "records",
                jarr(
                    self.records
                        .iter()
                        .map(|r| {
                            jobj(vec![
                                ("round", jnum(r.round as f64)),
                                ("comm_vectors", jnum(r.comm_vectors as f64)),
                                ("sim_time_s", jnum(r.sim_time_s)),
                                ("compute_s", jnum(r.compute_s)),
                                ("primal", jnum(r.primal)),
                                ("dual", jnum(r.dual)),
                                ("gap", jnum(r.gap)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Stream the JSON serialization to `out` without materializing the
    /// document: byte-identical to `to_json().to_string_compact()`
    /// (locked by test), but O(1) memory in the number of records — a
    /// long run's history no longer gets duplicated into a `Json` tree
    /// plus a `String` just to hit the disk.
    pub fn write_json<W: std::io::Write>(&self, out: W) -> std::io::Result<()> {
        use crate::telemetry::writer::JsonWriter;
        // Keys in alphabetical order mirror the BTreeMap-backed Json
        // serializer — that ordering is the byte-parity contract.
        let mut j = JsonWriter::new(out);
        j.begin_obj()?;
        j.key("label")?;
        j.str_val(&self.label)?;
        j.key("records")?;
        j.begin_arr()?;
        for r in &self.records {
            j.begin_obj()?;
            j.key("comm_vectors")?;
            j.num(r.comm_vectors as f64)?;
            j.key("compute_s")?;
            j.num(r.compute_s)?;
            j.key("dual")?;
            j.num(r.dual)?;
            j.key("gap")?;
            j.num(r.gap)?;
            j.key("primal")?;
            j.num(r.primal)?;
            j.key("round")?;
            j.num(r.round as f64)?;
            j.key("sim_time_s")?;
            j.num(r.sim_time_s)?;
            j.end()?;
        }
        j.end()?;
        j.key("stop")?;
        j.str_val(self.stop.as_str())?;
        j.end()?;
        Ok(())
    }

    /// Parse [`History::to_json`] output. JSON cannot represent
    /// non-finite numbers (the writer emits `null`), so a null dual maps
    /// back to `f64::NEG_INFINITY` (primal-only methods) and a null
    /// primal/gap to `f64::INFINITY` (diverged or uncertifiable runs);
    /// the counters and clocks are always finite and remain required.
    pub fn from_json(j: &Json) -> Result<History, String> {
        let label = j
            .get("label")
            .and_then(|v| v.as_str())
            .ok_or("missing label")?
            .to_string();
        let stop = j
            .get("stop")
            .and_then(|v| v.as_str())
            .and_then(StopReason::parse)
            .ok_or("missing or unknown stop reason")?;
        let recs = j
            .get("records")
            .and_then(|v| v.as_arr())
            .ok_or("missing records")?;
        let mut records = Vec::with_capacity(recs.len());
        for (i, r) in recs.iter().enumerate() {
            let fnum = |key: &str| -> Result<f64, String> {
                r.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("record {i}: missing {key}"))
            };
            let opt = |key: &str| r.get(key).and_then(|v| v.as_f64());
            records.push(RoundRecord {
                round: fnum("round")? as usize,
                comm_vectors: fnum("comm_vectors")? as usize,
                sim_time_s: fnum("sim_time_s")?,
                compute_s: fnum("compute_s")?,
                primal: opt("primal").unwrap_or(f64::INFINITY),
                dual: opt("dual").unwrap_or(f64::NEG_INFINITY),
                gap: opt("gap").unwrap_or(f64::INFINITY),
            });
        }
        Ok(History {
            label,
            records,
            stop,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, gap: f64) -> RoundRecord {
        RoundRecord {
            round,
            comm_vectors: round * 4,
            sim_time_s: round as f64 * 0.1,
            compute_s: round as f64 * 0.05,
            primal: 1.0,
            dual: 1.0 - gap,
            gap,
        }
    }

    #[test]
    fn time_to_gap_finds_first_crossing() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        h.push(rec(1, 0.05));
        h.push(rec(2, 0.01));
        let (round, t, vecs) = h.time_to_gap(0.1).unwrap();
        assert_eq!(round, 1);
        assert!((t - 0.1).abs() < 1e-12);
        assert_eq!(vecs, 4);
        assert!(h.time_to_gap(1e-9).is_none());
    }

    #[test]
    fn csv_shape() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        let csv = h.to_csv();
        // 2 comment lines + header + 1 row
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("# label=t\n# stop=max_rounds\n"));
        assert!(csv.contains("round,comm_vectors,"));
    }

    #[test]
    fn csv_roundtrip_exact() {
        let mut h = History::new("series-a");
        h.push(rec(0, 0.123456789012345));
        h.push(rec(3, 1e-9));
        h.stop = StopReason::DualTargetReached;
        let parsed = History::from_csv(&h.to_csv()).unwrap();
        assert_eq!(parsed, h);
    }

    #[test]
    fn csv_roundtrip_handles_infinite_dual() {
        // Primal-only methods (SGD/ADMM) report dual = −∞.
        let mut h = History::new("sgd");
        let mut r = rec(0, 0.5);
        r.dual = f64::NEG_INFINITY;
        h.push(r);
        let parsed = History::from_csv(&h.to_csv()).unwrap();
        assert_eq!(parsed.records[0].dual, f64::NEG_INFINITY);
        assert_eq!(parsed, h);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(History::from_csv("").is_err());
        assert!(History::from_csv("not,the,header\n1,2,3\n").is_err());
        let ragged = format!("{}1,2,3\n", History::csv_header());
        assert!(History::from_csv(&ragged).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let mut h = History::new("series");
        h.push(rec(0, 0.5));
        h.stop = StopReason::GapReached;
        let j = h.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("series"));
        assert_eq!(parsed.get("stop").unwrap().as_str(), Some("gap_reached"));
        assert_eq!(parsed.get("records").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn json_roundtrip_through_from_json() {
        let mut h = History::new("series-b");
        h.push(rec(0, 0.25));
        h.push(rec(2, 0.0625));
        // non-finite certificates (primal-only dual, uncertifiable gap)
        // serialize as JSON null and must map back to the same infinities
        let mut r = rec(3, 0.5);
        r.dual = f64::NEG_INFINITY;
        r.gap = f64::INFINITY;
        h.push(r);
        h.stop = StopReason::DualTargetReached;
        let text = h.to_json().to_string_pretty();
        let parsed = History::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, h);
        // the new variant's name round-trips through its stable string
        assert_eq!(
            StopReason::parse(StopReason::DualTargetReached.as_str()),
            Some(StopReason::DualTargetReached)
        );
    }

    #[test]
    fn streamed_json_is_byte_identical_to_materialized() {
        let mut h = History::new("parity \"series\"\n");
        h.push(rec(0, 0.25));
        h.push(rec(7, 1e-9));
        // exercise the null path (non-finite certificates) too
        let mut r = rec(9, 0.5);
        r.dual = f64::NEG_INFINITY;
        r.gap = f64::NAN;
        h.push(r);
        h.stop = StopReason::GapReached;
        let mut streamed = Vec::new();
        h.write_json(&mut streamed).unwrap();
        assert_eq!(
            String::from_utf8(streamed).unwrap(),
            h.to_json().to_string_compact()
        );
    }

    #[test]
    fn dual_suboptimality_lookup() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        h.push(rec(1, 0.05));
        // d_star = 1.0 (gap vs dual=1-gap): subopt ≤ 0.1 first at round 1
        let t = h.time_to_dual_subopt(1.0, 0.1).unwrap();
        assert!((t - 0.1).abs() < 1e-12);
    }
}
