//! Per-round training history: the raw series behind every figure.

use crate::util::json::{jarr, jnum, jobj, jstr, Json};

/// One evaluated round (certificates are computed every `gap_every`
/// rounds, so records may be sparser than rounds).
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Cumulative communicated vectors (paper's Fig. 1 x-axis).
    pub comm_vectors: usize,
    /// Cumulative simulated cluster time: measured max-worker compute +
    /// modeled network (paper's elapsed-time x-axis).
    pub sim_time_s: f64,
    /// Cumulative measured local-compute seconds (max over workers/round).
    pub compute_s: f64,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// Why a run stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    GapReached,
    MaxRounds,
    Diverged,
    DualStalled,
}

#[derive(Clone, Debug)]
pub struct History {
    pub label: String,
    pub records: Vec<RoundRecord>,
    pub stop: StopReason,
}

impl History {
    pub fn new(label: &str) -> History {
        History {
            label: label.to_string(),
            records: Vec::new(),
            stop: StopReason::MaxRounds,
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn final_gap(&self) -> f64 {
        self.records.last().map(|r| r.gap).unwrap_or(f64::INFINITY)
    }

    pub fn final_dual(&self) -> f64 {
        self.records
            .last()
            .map(|r| r.dual)
            .unwrap_or(f64::NEG_INFINITY)
    }

    pub fn best_dual(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.dual)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn rounds_run(&self) -> usize {
        self.records.last().map(|r| r.round + 1).unwrap_or(0)
    }

    /// First record index where gap ≤ tol, with its simulated time and
    /// communicated-vector count. None if never reached.
    pub fn time_to_gap(&self, tol: f64) -> Option<(usize, f64, usize)> {
        self.records
            .iter()
            .find(|r| r.gap <= tol)
            .map(|r| (r.round, r.sim_time_s, r.comm_vectors))
    }

    /// First simulated time where the dual suboptimality D(α*)−D(α) ≤ tol,
    /// given an externally estimated optimum (Fig. 2's y-axis needs this).
    pub fn time_to_dual_subopt(&self, d_star: f64, tol: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| d_star - r.dual <= tol)
            .map(|r| r.sim_time_s)
    }

    pub fn diverged(&self) -> bool {
        self.stop == StopReason::Diverged
    }

    /// CSV rows: round,comm_vectors,sim_time_s,compute_s,primal,dual,gap.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("round,comm_vectors,sim_time_s,compute_s,primal,dual,gap\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.6},{:.6},{:.10},{:.10},{:.10}\n",
                r.round, r.comm_vectors, r.sim_time_s, r.compute_s, r.primal, r.dual, r.gap
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("label", jstr(&self.label)),
            (
                "stop",
                jstr(match self.stop {
                    StopReason::GapReached => "gap_reached",
                    StopReason::MaxRounds => "max_rounds",
                    StopReason::Diverged => "diverged",
                    StopReason::DualStalled => "dual_stalled",
                }),
            ),
            (
                "records",
                jarr(
                    self.records
                        .iter()
                        .map(|r| {
                            jobj(vec![
                                ("round", jnum(r.round as f64)),
                                ("comm_vectors", jnum(r.comm_vectors as f64)),
                                ("sim_time_s", jnum(r.sim_time_s)),
                                ("compute_s", jnum(r.compute_s)),
                                ("primal", jnum(r.primal)),
                                ("dual", jnum(r.dual)),
                                ("gap", jnum(r.gap)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, gap: f64) -> RoundRecord {
        RoundRecord {
            round,
            comm_vectors: round * 4,
            sim_time_s: round as f64 * 0.1,
            compute_s: round as f64 * 0.05,
            primal: 1.0,
            dual: 1.0 - gap,
            gap,
        }
    }

    #[test]
    fn time_to_gap_finds_first_crossing() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        h.push(rec(1, 0.05));
        h.push(rec(2, 0.01));
        let (round, t, vecs) = h.time_to_gap(0.1).unwrap();
        assert_eq!(round, 1);
        assert!((t - 0.1).abs() < 1e-12);
        assert_eq!(vecs, 4);
        assert!(h.time_to_gap(1e-9).is_none());
    }

    #[test]
    fn csv_shape() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn json_roundtrip() {
        let mut h = History::new("series");
        h.push(rec(0, 0.5));
        h.stop = StopReason::GapReached;
        let j = h.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("series"));
        assert_eq!(parsed.get("stop").unwrap().as_str(), Some("gap_reached"));
        assert_eq!(parsed.get("records").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn dual_suboptimality_lookup() {
        let mut h = History::new("t");
        h.push(rec(0, 0.5));
        h.push(rec(1, 0.05));
        // d_star = 1.0 (gap vs dual=1-gap): subopt ≤ 0.1 first at round 1
        let t = h.time_to_dual_subopt(1.0, 0.1).unwrap();
        assert!((t - 0.1).abs() < 1e-12);
    }
}
