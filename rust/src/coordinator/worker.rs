//! Worker state: one per machine k in the simulated cluster.
//!
//! A worker owns its data block (a zero-copy view of the shared dataset —
//! it never touches other workers' rows, the locality the paper's
//! framework is built around), its slice of the dual variables α_[k], and
//! its local solver instance. Under the persistent-pool runtime
//! ([`crate::coordinator::pool`]) each worker lives on its own long-lived
//! thread and fills a reusable [`WorkerResult`] scratch every round; the
//! sequential executor drives the same state in-process. Besides the
//! local solve, a worker answers the pool's `Eval` message with its
//! [`CertPartial`] — its shard's share of the duality-gap certificate.

use crate::objective::{cert_partial, CertPartial};
use crate::solver::{LocalSolveCtx, LocalSolver, LocalUpdate};
use crate::subproblem::{LocalBlock, SubproblemSpec};
use crate::util::rng::SplitMix64;
use crate::util::timer::Stopwatch;

pub struct Worker {
    pub id: usize,
    pub block: LocalBlock,
    /// α_[k] in local indexing; the global α is the scatter of these.
    pub alpha_local: Vec<f64>,
    pub solver: Box<dyn LocalSolver>,
}

/// What a worker sends back to the leader each round. Allocated once per
/// worker at pool startup and ping-ponged between leader and worker
/// thereafter (zero allocations in the steady-state round loop).
#[derive(Clone, Debug)]
pub struct WorkerResult {
    pub id: usize,
    pub update: LocalUpdate,
    /// Measured local compute seconds for this round.
    pub compute_s: f64,
}

impl WorkerResult {
    /// A zeroed result scratch for worker `id` with an (n_k, d) block.
    pub fn with_dims(id: usize, n_local: usize, d: usize) -> WorkerResult {
        WorkerResult {
            id,
            update: LocalUpdate::with_dims(n_local, d),
            compute_s: 0.0,
        }
    }
}

impl Worker {
    pub fn new(id: usize, block: LocalBlock, solver: Box<dyn LocalSolver>) -> Worker {
        let n_local = block.n_local();
        Worker {
            id,
            block,
            alpha_local: vec![0.0; n_local],
            solver,
        }
    }

    /// Run one outer round's local solve against the shared w, writing
    /// Δα/Δw into the reusable `out` scratch.
    pub fn round_into(&mut self, w: &[f64], spec: &SubproblemSpec, out: &mut WorkerResult) {
        let clock = Stopwatch::started();
        out.id = self.id;
        let ctx = LocalSolveCtx {
            block: &self.block,
            spec,
            w,
            alpha_local: &self.alpha_local,
        };
        self.solver.solve_into(&ctx, &mut out.update);
        out.compute_s = clock.elapsed_secs();
    }

    /// Allocating convenience wrapper around [`Worker::round_into`].
    pub fn round(&mut self, w: &[f64], spec: &SubproblemSpec) -> WorkerResult {
        let mut out = WorkerResult::with_dims(self.id, self.block.n_local(), self.block.d());
        self.round_into(w, spec, &mut out);
        out
    }

    /// This worker's shard-partial of the duality-gap certificate against
    /// the shared `w`: local margins, Σℓ_i over them, and Σℓ*_i over the
    /// worker-owned α_[k]. Same code path as central evaluation
    /// ([`crate::objective::cert_partial`]), so the leader's K-way reduce
    /// is bit-reproducible across runtimes.
    pub fn eval_partial(&self, spec: &SubproblemSpec, w: &[f64]) -> CertPartial {
        cert_partial(
            spec.loss,
            self.block.x(),
            self.block.y(),
            &self.alpha_local,
            w,
        )
    }

    /// Apply the γ-scaled accepted update to the local dual state (Eq. 14,
    /// line 5 of Algorithm 1).
    pub fn apply(&mut self, gamma: f64, delta_alpha: &[f64]) {
        debug_assert_eq!(delta_alpha.len(), self.alpha_local.len());
        for (a, d) in self.alpha_local.iter_mut().zip(delta_alpha) {
            *a += gamma * d;
        }
    }

    /// Deterministic per-(round, worker) solver seed so parallel scheduling
    /// cannot perturb results.
    pub fn round_seed(run_seed: u64, round: usize, worker: usize) -> u64 {
        let mut sm = SplitMix64::new(run_seed ^ 0xC0C0_A500);
        let a = sm.next_u64();
        a ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (worker as u64).wrapping_mul(0xD1B5_4A32_D192_ED03)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;
    use crate::solver::sdca::SdcaSolver;

    fn worker() -> (Worker, SubproblemSpec) {
        let data = generate(&SynthConfig::new("t", 20, 4).seed(1));
        let rows: Vec<usize> = (0..10).collect();
        let block = LocalBlock::from_partition(&data, &rows);
        let spec = SubproblemSpec {
            loss: Loss::Hinge,
            lambda: 0.1,
            n_global: 20,
            sigma_prime: 2.0,
            k: 2,
        };
        (Worker::new(0, block, Box::new(SdcaSolver::new(50, 3))), spec)
    }

    #[test]
    fn round_produces_consistent_update() {
        let (mut w, spec) = worker();
        let shared_w = vec![0.0; 4];
        let res = w.round(&shared_w, &spec);
        assert_eq!(res.update.delta_alpha.len(), 10);
        assert_eq!(res.update.delta_w.len(), 4);
        assert!(res.compute_s >= 0.0);
    }

    #[test]
    fn apply_scales_by_gamma() {
        let (mut w, _spec) = worker();
        let delta = vec![1.0; 10];
        w.apply(0.25, &delta);
        assert!(w.alpha_local.iter().all(|&a| (a - 0.25).abs() < 1e-15));
        w.apply(0.25, &delta);
        assert!(w.alpha_local.iter().all(|&a| (a - 0.5).abs() < 1e-15));
    }

    #[test]
    fn eval_partial_matches_direct_sums() {
        let (mut wk, spec) = worker();
        let shared_w: Vec<f64> = (0..4).map(|j| 0.1 * (j as f64 + 1.0)).collect();
        // move off the zero dual point first
        let res = wk.round(&shared_w, &spec);
        wk.apply(1.0, &res.update.delta_alpha);
        let p = wk.eval_partial(&spec, &shared_w);
        let (mut loss_sum, mut conj_sum) = (0.0, 0.0);
        let y = wk.block.y();
        for i in 0..wk.block.n_local() {
            let z = wk.block.x().row_dot(i, &shared_w);
            loss_sum += spec.loss.value(z, y[i]);
            conj_sum += spec.loss.conjugate_neg(wk.alpha_local[i], y[i]);
        }
        assert_eq!(p.loss_sum.to_bits(), loss_sum.to_bits());
        assert_eq!(p.conj_sum.to_bits(), conj_sum.to_bits());
    }

    #[test]
    fn round_seeds_distinct() {
        let s1 = Worker::round_seed(42, 0, 0);
        let s2 = Worker::round_seed(42, 0, 1);
        let s3 = Worker::round_seed(42, 1, 0);
        let s4 = Worker::round_seed(43, 0, 0);
        assert_ne!(s1, s2);
        assert_ne!(s1, s3);
        assert_ne!(s1, s4);
        // deterministic
        assert_eq!(s1, Worker::round_seed(42, 0, 0));
    }
}
