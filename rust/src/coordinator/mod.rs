//! The CoCoA+ framework — Algorithm 1 of the paper.
//!
//! Per outer round t:
//!   1. broadcast the shared primal vector w to all K workers;
//!   2. each worker k computes a Θ-approximate solution Δα_[k] of its
//!      local subproblem G_k^{σ'} (any [`LocalSolver`]);
//!   3. each worker applies α_[k] ← α_[k] + γ·Δα_[k] locally;
//!   4. the leader reduces w ← w + γ·Σ_k Δw_k, Δw_k = A Δα_[k]/(λn).
//!
//! γ = 1/K + σ' = 1 recovers original CoCoA (Remark 12); γ = 1 + σ' = K is
//! the paper's CoCoA+ "adding" regime with K-independent rates
//! (Corollaries 9/11). The trainer maintains the exact invariant
//! w = Aα/(λn) across rounds (checked in debug builds and by tests) and
//! evaluates primal-dual certificates on a configurable cadence.

pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod history;
pub mod worker;

pub use config::{Aggregation, CocoaConfig, SolverSpec};
pub use history::{History, RoundRecord, StopReason};

use crate::data::Partition;
use crate::linalg::dense;
use crate::objective::Problem;
use crate::solver::{
    cyclic_cd::CyclicCdSolver, jacobi::JacobiSolver, sdca::SdcaSolver, LocalSolver,
};
use crate::subproblem::{LocalBlock, SubproblemSpec};
use comm::CommStats;
use worker::Worker;

/// Build a solver instance from a [`SolverSpec`] for a worker with n_k
/// local points.
pub fn make_solver(spec: &SolverSpec, n_local: usize, seed: u64) -> Box<dyn LocalSolver> {
    match *spec {
        SolverSpec::Sdca { h } => Box::new(SdcaSolver::new(h, seed)),
        SolverSpec::SdcaEpochs { epochs } => {
            Box::new(SdcaSolver::with_epochs(epochs, n_local, seed))
        }
        SolverSpec::Cyclic { epochs, shuffle } => {
            Box::new(CyclicCdSolver::new(epochs, shuffle, seed))
        }
        SolverSpec::Jacobi { sweeps, beta } => Box::new(JacobiSolver::new(sweeps, beta)),
    }
}

/// The distributed trainer (leader + K workers).
pub struct Trainer {
    pub cfg: CocoaConfig,
    pub problem: Problem,
    pub partition: Partition,
    pub workers: Vec<Worker>,
    /// Global dual iterate α ∈ R^n.
    pub alpha: Vec<f64>,
    /// Shared primal vector w = Aα/(λn) ∈ R^d.
    pub w: Vec<f64>,
    spec: SubproblemSpec,
    comm_stats: CommStats,
}

impl Trainer {
    /// Build with solvers constructed from `cfg.solver`.
    pub fn new(problem: Problem, partition: Partition, cfg: CocoaConfig) -> Trainer {
        let solvers: Vec<Box<dyn LocalSolver>> = partition
            .parts
            .iter()
            .enumerate()
            .map(|(k, rows)| {
                make_solver(
                    &cfg.solver,
                    rows.len(),
                    Worker::round_seed(cfg.seed, 0, k),
                )
            })
            .collect();
        Trainer::with_solvers(problem, partition, cfg, solvers)
    }

    /// Build with caller-supplied local solvers (e.g. the PJRT-backed one).
    pub fn with_solvers(
        problem: Problem,
        partition: Partition,
        cfg: CocoaConfig,
        solvers: Vec<Box<dyn LocalSolver>>,
    ) -> Trainer {
        cfg.validate().expect("invalid CocoaConfig");
        assert_eq!(partition.k(), cfg.k, "partition K != config K");
        assert_eq!(partition.n, problem.n(), "partition n != problem n");
        assert_eq!(solvers.len(), cfg.k, "need one solver per worker");
        assert!(
            partition.is_exact_cover(),
            "partition must exactly cover [n]"
        );
        let blocks = LocalBlock::split(&problem.data, &partition);
        let workers: Vec<Worker> = blocks
            .into_iter()
            .zip(solvers)
            .enumerate()
            .map(|(k, (block, solver))| Worker::new(k, block, solver))
            .collect();
        let spec = SubproblemSpec {
            loss: cfg.loss,
            lambda: cfg.lambda,
            n_global: problem.n(),
            sigma_prime: cfg.effective_sigma_prime(),
            k: cfg.k,
        };
        let n = problem.n();
        let d = problem.d();
        Trainer {
            cfg,
            problem,
            partition,
            workers,
            alpha: vec![0.0; n],
            w: vec![0.0; d],
            spec,
            comm_stats: CommStats::default(),
        }
    }

    pub fn spec(&self) -> &SubproblemSpec {
        &self.spec
    }

    pub fn comm_stats(&self) -> &CommStats {
        &self.comm_stats
    }

    /// One synchronous outer round. Returns the measured max-worker compute
    /// seconds (the quantity that gates a synchronous cluster round).
    pub fn round(&mut self) -> f64 {
        let gamma = self.cfg.gamma();
        let w_snapshot = &self.w;
        let spec = &self.spec;

        // --- fan out: local solves ------------------------------------
        let results: Vec<worker::WorkerResult> = if self.cfg.parallel {
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .workers
                    .iter_mut()
                    .map(|wk| scope.spawn(move || wk.round(w_snapshot, spec)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            })
        } else {
            self.workers
                .iter_mut()
                .map(|wk| wk.round(w_snapshot, spec))
                .collect()
        };

        let max_compute = results
            .iter()
            .map(|r| r.compute_s)
            .fold(0.0f64, f64::max);

        // --- reduce (Eq. 14) -------------------------------------------
        for res in &results {
            let wk = &mut self.workers[res.id];
            wk.apply(gamma, &res.update.delta_alpha);
            // scatter to the global dual vector
            for (li, &gi) in wk.block.global_idx.iter().enumerate() {
                self.alpha[gi] += gamma * res.update.delta_alpha[li];
            }
            dense::axpy(gamma, &res.update.delta_w, &mut self.w);
        }
        self.comm_stats
            .record_round(&self.cfg.comm, self.problem.d(), self.cfg.k);
        max_compute
    }

    /// Recompute w from α and report the max deviation from the maintained
    /// w (the coordinator's central invariant; ~0 up to float error).
    pub fn primal_consistency_error(&self) -> f64 {
        let mut w_ref = vec![0.0; self.problem.d()];
        self.problem.primal_from_dual(&self.alpha, &mut w_ref);
        w_ref
            .iter()
            .zip(&self.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max)
    }

    /// Run until the gap tolerance, divergence, or the round budget.
    pub fn run(&mut self) -> History {
        let label = format!(
            "{}(K={},γ={},σ'={},{})",
            if self.cfg.gamma() >= 1.0 { "cocoa+" } else { "cocoa" },
            self.cfg.k,
            self.cfg.gamma(),
            self.spec.sigma_prime,
            self.workers
                .first()
                .map(|w| w.solver.name())
                .unwrap_or_default(),
        );
        let mut hist = History::new(&label);
        let mut cum_compute = 0.0f64;
        let mut cum_sim = 0.0f64;

        for t in 0..self.cfg.max_rounds {
            let max_compute = self.round();
            cum_compute += max_compute;
            cum_sim += max_compute + self.cfg.comm.round_time(self.problem.d());

            if t % self.cfg.gap_every == 0 || t + 1 == self.cfg.max_rounds {
                let certs = self.problem.certificates(&self.alpha, &self.w);
                hist.push(RoundRecord {
                    round: t,
                    comm_vectors: self.comm_stats.vectors,
                    sim_time_s: cum_sim,
                    compute_s: cum_compute,
                    primal: certs.primal,
                    dual: certs.dual,
                    gap: certs.gap,
                });
                crate::log_debug!(
                    "round {t}: P={:.6e} D={:.6e} gap={:.6e}",
                    certs.primal,
                    certs.dual,
                    certs.gap
                );
                if !certs.gap.is_finite() || certs.gap > self.cfg.divergence_gap {
                    hist.stop = StopReason::Diverged;
                    crate::log_warn!("{label}: diverged at round {t} (gap={})", certs.gap);
                    return hist;
                }
                if certs.gap <= self.cfg.gap_tol {
                    hist.stop = StopReason::GapReached;
                    return hist;
                }
            }
        }
        hist.stop = StopReason::MaxRounds;
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    fn problem(n: usize, d: usize, lambda: f64, loss: Loss) -> Problem {
        let data = generate(&SynthConfig::new("t", n, d).seed(31));
        Problem::new(data, loss, lambda)
    }

    fn trainer(k: usize, cfg_fn: impl Fn(CocoaConfig) -> CocoaConfig) -> Trainer {
        let p = problem(80, 10, 0.05, Loss::Hinge);
        let part = random_balanced(80, k, 5);
        let cfg = cfg_fn(CocoaConfig::cocoa_plus(
            k,
            Loss::Hinge,
            0.05,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        ))
        .with_parallel(false);
        Trainer::new(p, part, cfg)
    }

    #[test]
    fn invariant_w_equals_a_alpha() {
        let mut t = trainer(4, |c| c.with_rounds(5));
        for _ in 0..5 {
            t.round();
        }
        assert!(
            t.primal_consistency_error() < 1e-9,
            "w drifted from Aα/(λn): {}",
            t.primal_consistency_error()
        );
    }

    #[test]
    fn dual_monotone_under_safe_sigma() {
        // Lemma 3 + exact coordinate maximization ⇒ D never decreases with
        // the safe σ' = γK.
        let mut t = trainer(4, |c| c.with_rounds(15));
        let mut prev = f64::NEG_INFINITY;
        for _ in 0..15 {
            t.round();
            let d = t.problem.dual_value(&t.alpha, &t.w);
            assert!(d >= prev - 1e-10, "dual decreased: {d} < {prev}");
            prev = d;
        }
    }

    #[test]
    fn run_reaches_gap_on_easy_problem() {
        let mut t = trainer(2, |c| c.with_rounds(300).with_gap_tol(1e-3));
        let hist = t.run();
        assert_eq!(hist.stop, StopReason::GapReached, "final gap {}", hist.final_gap());
    }

    #[test]
    fn parallel_matches_sequential() {
        let mk = |parallel: bool| {
            let p = problem(60, 8, 0.05, Loss::Hinge);
            let part = random_balanced(60, 3, 5);
            let cfg = CocoaConfig::cocoa_plus(
                3,
                Loss::Hinge,
                0.05,
                SolverSpec::Sdca { h: 40 },
            )
            .with_rounds(6)
            .with_parallel(parallel);
            let mut t = Trainer::new(p, part, cfg);
            t.run();
            (t.alpha, t.w)
        };
        let (a_seq, w_seq) = mk(false);
        let (a_par, w_par) = mk(true);
        assert_eq!(a_seq, a_par, "parallel execution changed the trajectory");
        assert_eq!(w_seq, w_par);
    }

    #[test]
    fn averaging_preset_converges_slower_per_round() {
        // CoCoA (γ=1/K) gains less per round than CoCoA+ (γ=1) at equal
        // local work — the paper's core claim, in miniature.
        let gap_after = |plus: bool| {
            let p = problem(120, 10, 0.01, Loss::Hinge);
            let part = random_balanced(120, 8, 5);
            let cfg = if plus {
                CocoaConfig::cocoa_plus(8, Loss::Hinge, 0.01, SolverSpec::SdcaEpochs { epochs: 1.0 })
            } else {
                CocoaConfig::cocoa(8, Loss::Hinge, 0.01, SolverSpec::SdcaEpochs { epochs: 1.0 })
            }
            .with_rounds(10)
            .with_parallel(false);
            let mut t = Trainer::new(p, part, cfg);
            t.run().final_gap()
        };
        let plus = gap_after(true);
        let avg = gap_after(false);
        assert!(
            plus < avg,
            "CoCoA+ ({plus}) should beat CoCoA ({avg}) after equal rounds"
        );
    }

    #[test]
    fn unsafe_sigma_prime_can_diverge_or_stall() {
        // Fig. 3: σ' well below safe (e.g. σ'=1 with γ=1, K=8) breaks the
        // guarantee. We only assert it is *worse* than safe, since tiny
        // problems may not blow up spectacularly.
        let run_with = |sp: f64| {
            let p = problem(120, 10, 0.001, Loss::Hinge);
            let part = random_balanced(120, 8, 5);
            let cfg = CocoaConfig::cocoa_plus(
                8,
                Loss::Hinge,
                0.001,
                SolverSpec::SdcaEpochs { epochs: 2.0 },
            )
            .with_sigma_prime(sp)
            .with_rounds(25)
            .with_parallel(false);
            let mut t = Trainer::new(p, part, cfg);
            let h = t.run();
            (h.final_gap(), h.diverged())
        };
        let (gap_safe, div_safe) = run_with(8.0);
        let (gap_unsafe, div_unsafe) = run_with(0.5);
        assert!(!div_safe);
        assert!(
            div_unsafe || gap_unsafe > gap_safe,
            "unsafe σ' should diverge or trail safe: {gap_unsafe} vs {gap_safe}"
        );
    }
}
