//! The CoCoA+ framework — Algorithm 1 of the paper, on a persistent
//! worker-pool runtime.
//!
//! Per outer round t:
//!   1. the leader broadcasts the shared primal vector w to all K workers;
//!   2. each worker k computes a Θ-approximate solution Δα_[k] of its
//!      local subproblem G_k^{σ'} (any [`LocalSolver`]);
//!   3. each worker applies α_[k] ← α_[k] + γ·Δα_[k] locally;
//!   4. the leader reduces w ← w + γ·Σ_k Δw_k, Δw_k = A Δα_[k]/(λn).
//!
//! γ = 1/K + σ' = 1 recovers original CoCoA (Remark 12); γ = 1 + σ' = K is
//! the paper's CoCoA+ "adding" regime with K-independent rates
//! (Corollaries 9/11).
//!
//! ### Execution model
//!
//! Steps 1–3 run on a [`pool::Executor`] — one of three interchangeable
//! runtimes selected by [`config::ExecutorChoice`]:
//!
//! * [`pool::PooledExecutor`] — K persistent worker threads spawned once
//!   at [`Trainer::new`], rounds driven over bounded channels with
//!   per-worker reusable scratch (zero thread spawns and zero result
//!   allocations per steady-state round);
//! * [`pool::SequentialExecutor`] — in-process, one worker after another
//!   on the leader thread (`cfg.parallel = false`, or K = 1);
//! * [`socket::SocketExecutor`] — K worker *processes* (`cocoa worker`)
//!   connected over Unix domain sockets or TCP, exchanging rounds in the
//!   length-prefixed [`wire`] format.
//!
//! All three execute bit-identical trajectories: per-worker solver
//! streams are seeded from `(seed, worker)`, shard data crosses the
//! process boundary bit-exactly (binary f64 sections, cached norms
//! shipped rather than recomputed), and the leader applies the step-4
//! reduce in worker-id order — so neither scheduling nor serialization
//! can perturb results.
//!
//! ### Shared data plane
//!
//! The trainer canonicalizes its partition into the permuted-contiguous
//! [`ShardLayout`](crate::data::ShardLayout) at construction: the dataset
//! is reordered **once** so worker k's rows are the contiguous range
//! `shards[k] = (start, len)`, and the leader's [`Problem`] plus all K
//! worker [`LocalBlock`]s view the same `Arc<Dataset>` — total resident
//! data is 1× the dataset instead of the old leader copy + K cloned
//! shards, and shard addressing is K `(start, len)` pairs instead of K
//! index vectors totalling n entries. Consequently `alpha`, `shards`,
//! and `problem.data` all live in *layout* row order; [`Trainer::rows`]
//! maps back to the caller's original order
//! ([`Trainer::alpha_original`]), and per-shard contents are unchanged,
//! so trajectories are what the index-list semantics produced.
//!
//! ### Time accounting
//!
//! Each round reports the *measured* max per-worker compute seconds (the
//! quantity that gates a synchronous cluster round) to the simulated
//! cluster model in [`comm`]; the runtime's own fan-out/gather barrier
//! and the leader's reduce are measured separately into
//! [`comm::CommStats`] (`barrier_s`, `reduce_s`), so compute-time curves
//! no longer absorb scheduling overhead (previously: per-round thread
//! spawns).
//!
//! The trainer maintains the exact invariant w = Aα/(λn) across rounds
//! (checked in debug builds and by tests) and evaluates primal-dual
//! certificates on a configurable cadence — as a pool-distributed
//! shard-partial reduction (see [`pool`]), not a serial leader pass.

pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod history;
pub mod pool;
pub mod socket;
pub mod wire;
pub mod worker;

pub use config::{Aggregation, CocoaConfig, ExecutorChoice, SocketOpts, SolverSpec};
pub use history::{History, RoundRecord, StopReason};
pub use pool::{Executor, PoolError, RoundTiming};

use crate::data::{Partition, RowPermutation};
use crate::driver::{Driver, Method, StepStats};
use crate::linalg::dense;
use crate::objective::Problem;
use crate::solver::{
    cyclic_cd::CyclicCdSolver, jacobi::JacobiSolver, sdca::SdcaSolver, LocalSolver,
};
use crate::subproblem::{LocalBlock, SubproblemSpec};
use comm::CommStats;
use crate::telemetry::Ring;
use crate::util::timer::Stopwatch;
use std::sync::Arc;
use worker::Worker;

/// Build a solver instance from a [`SolverSpec`] for a worker with n_k
/// local points.
pub fn make_solver(spec: &SolverSpec, n_local: usize, seed: u64) -> Box<dyn LocalSolver> {
    match *spec {
        SolverSpec::Sdca { h } => Box::new(SdcaSolver::new(h, seed)),
        SolverSpec::SdcaEpochs { epochs } => {
            Box::new(SdcaSolver::with_epochs(epochs, n_local, seed))
        }
        SolverSpec::Cyclic { epochs, shuffle } => {
            Box::new(CyclicCdSolver::new(epochs, shuffle, seed))
        }
        SolverSpec::Jacobi { sweeps, beta } => Box::new(JacobiSolver::new(sweeps, beta)),
    }
}

/// The distributed trainer (leader + K workers behind an [`Executor`]).
///
/// The trainer works in the permuted-contiguous shard layout: `problem`,
/// `shards`, and `alpha` all use *layout* row order (worker k owns a
/// contiguous row range of the one shared dataset), and [`Trainer::rows`]
/// maps layout rows back to the row order the trainer was constructed
/// with.
pub struct Trainer {
    pub cfg: CocoaConfig,
    /// The problem over the shared (layout-ordered) dataset.
    pub problem: Problem,
    /// Worker k's `(start, len)` row range of `problem.data` — the whole
    /// shard addressing in a contiguous layout.
    pub shards: Vec<(usize, usize)>,
    /// Layout ↔ caller row order maps (identity for partitions that were
    /// already contiguous).
    pub rows: RowPermutation,
    /// Global dual iterate α ∈ R^n, in layout row order (see
    /// [`Trainer::alpha_original`] for the caller-order view).
    pub alpha: Vec<f64>,
    /// Shared primal vector w = Aα/(λn) ∈ R^d (row-order free).
    pub w: Vec<f64>,
    executor: Box<dyn Executor>,
    spec: SubproblemSpec,
    comm_stats: CommStats,
    /// Leader-lane (tid 0) flight-recorder ring for the Eq.-14 reduce.
    ring: Ring,
}

impl Trainer {
    /// Build with solvers constructed from `cfg.solver`.
    pub fn new(problem: Problem, partition: Partition, cfg: CocoaConfig) -> Trainer {
        Trainer::build(problem, partition, cfg, None)
    }

    /// Build with caller-supplied local solvers (e.g. the PJRT-backed
    /// one). Incompatible with the socket executor, which constructs its
    /// solvers inside the worker processes.
    pub fn with_solvers(
        problem: Problem,
        partition: Partition,
        cfg: CocoaConfig,
        solvers: Vec<Box<dyn LocalSolver>>,
    ) -> Trainer {
        Trainer::build(problem, partition, cfg, Some(solvers))
    }

    fn build(
        problem: Problem,
        partition: Partition,
        cfg: CocoaConfig,
        solvers: Option<Vec<Box<dyn LocalSolver>>>,
    ) -> Trainer {
        cfg.validate().expect("invalid CocoaConfig");
        assert_eq!(partition.k(), cfg.k, "partition K != config K");
        assert_eq!(partition.n, problem.n(), "partition n != problem n");
        assert!(
            partition.is_exact_cover(),
            "partition must exactly cover [n]"
        );
        // Shared data plane: realize the partition as the permuted-
        // contiguous layout. The problem's Arc is released *before* the
        // reorder, so when the trainer holds the only reference (the
        // normal ingest path) the dataset is permuted by consuming its
        // storage array-by-array — never two resident datasets; the
        // leader's problem and every worker's view share the resulting
        // single Arc from here on.
        let Problem { data, loss, lambda } = problem;
        let layout = partition.apply_permutation(data);
        let problem = Problem::shared(Arc::clone(&layout.data), loss, lambda);
        let blocks = LocalBlock::from_layout(&layout);
        let shards = layout.shards;
        let rows = layout.rows;
        debug_assert!(blocks
            .iter()
            .all(|b| Arc::ptr_eq(b.shared_data(), &problem.data)));
        let spec = SubproblemSpec {
            loss: cfg.loss,
            lambda: cfg.lambda,
            n_global: problem.n(),
            sigma_prime: cfg.effective_sigma_prime(),
            k: cfg.k,
        };
        let n = problem.n();
        let d = problem.d();
        let executor: Box<dyn Executor> = match (cfg.executor, solvers) {
            (ExecutorChoice::Socket, Some(_)) => panic!(
                "the socket executor builds solvers inside worker processes; \
                 use Trainer::new with cfg.solver instead of with_solvers"
            ),
            (ExecutorChoice::Socket, None) => Box::new(
                socket::SocketExecutor::spawn(&blocks, spec, &cfg)
                    .unwrap_or_else(|e| panic!("failed to start socket workers: {e}")),
            ),
            (choice, solvers) => {
                // Identical seeds/lengths whether solvers come from the
                // caller or cfg.solver — shard sizes survive the layout.
                let solvers = solvers.unwrap_or_else(|| {
                    blocks
                        .iter()
                        .enumerate()
                        .map(|(k, b)| {
                            make_solver(
                                &cfg.solver,
                                b.n_local(),
                                Worker::round_seed(cfg.seed, 0, k),
                            )
                        })
                        .collect()
                });
                assert_eq!(solvers.len(), cfg.k, "need one solver per worker");
                let workers: Vec<Worker> = blocks
                    .into_iter()
                    .zip(solvers)
                    .enumerate()
                    .map(|(k, (block, solver))| Worker::new(k, block, solver))
                    .collect();
                match choice {
                    ExecutorChoice::Auto => {
                        pool::make_executor(workers, spec, cfg.parallel, cfg.trace.clone())
                    }
                    ExecutorChoice::Sequential => Box::new(pool::SequentialExecutor::new(
                        workers,
                        spec,
                        cfg.trace.clone(),
                    )),
                    ExecutorChoice::Pooled => {
                        pool::make_executor(workers, spec, true, cfg.trace.clone())
                    }
                    ExecutorChoice::Socket => unreachable!("handled above"),
                }
            }
        };
        let ring = cfg.trace.ring(0);
        Trainer {
            cfg,
            problem,
            shards,
            rows,
            alpha: vec![0.0; n],
            w: vec![0.0; d],
            executor,
            spec,
            comm_stats: CommStats::default(),
            ring,
        }
    }

    pub fn spec(&self) -> &SubproblemSpec {
        &self.spec
    }

    pub fn comm_stats(&self) -> &CommStats {
        &self.comm_stats
    }

    /// Which runtime this trainer executes on: `"pooled"`, `"sequential"`,
    /// or `"socket"`.
    pub fn executor_kind(&self) -> &'static str {
        self.executor.kind()
    }

    /// One synchronous outer round. Returns the measured max-worker compute
    /// seconds (the quantity that gates a synchronous cluster round).
    /// Panics if a worker fails; use [`Trainer::try_round`] to handle
    /// failures as values.
    pub fn round(&mut self) -> f64 {
        match self.try_round() {
            Ok(compute) => compute,
            Err(e) => panic!("round failed: {e}"),
        }
    }

    /// One synchronous outer round; worker failures (e.g. a panicking
    /// local solver) surface as a [`PoolError`] naming the failed workers.
    /// The pool stays alive and consistent: the leader's (α, w) are
    /// untouched by a failed round and surviving workers' α_[k] views are
    /// re-synced from the leader, so a later round may be attempted.
    pub fn try_round(&mut self) -> Result<f64, PoolError> {
        let gamma = self.cfg.gamma();

        // --- fan out: broadcast w, local solves, gather ----------------
        let timing = match self.executor.run_round(&self.w, gamma) {
            Ok(timing) => timing,
            Err(e) => {
                // Workers apply γΔα_[k] locally before the leader sees a
                // failure; roll their views back to the leader's α so the
                // discarded round leaves no split state behind.
                self.executor.load_alpha(&self.alpha);
                return Err(e);
            }
        };

        // --- reduce (Eq. 14), in worker-id order for determinism -------
        let t_reduce = self.ring.now();
        let reduce_clock = Stopwatch::started();
        for k in 0..self.cfg.k {
            let res = self.executor.result(k);
            // scatter to the global dual vector (workers already applied
            // γΔα to their local views during the round); shard k is the
            // contiguous layout range (start, len), so this is a slice zip
            let (start, len) = self.shards[k];
            for (a, &da) in self.alpha[start..start + len]
                .iter_mut()
                .zip(&res.update.delta_alpha)
            {
                *a += gamma * da;
            }
            dense::axpy(gamma, &res.update.delta_w, &mut self.w);
        }
        let reduce_s = reduce_clock.elapsed_secs();
        self.ring.complete("reduce", "executor", t_reduce, None);

        self.comm_stats
            .record_round(&self.cfg.comm, self.problem.d(), self.cfg.k);
        self.comm_stats
            .record_runtime(timing.barrier_s, reduce_s, timing.wire_s);
        Ok(timing.max_compute_s)
    }

    /// Push the leader's global α into every worker's local α_[k] view
    /// (used by checkpoint restore).
    pub fn sync_workers_from_alpha(&mut self) {
        self.executor.load_alpha(&self.alpha);
    }

    /// The dual iterate scattered back to the row order the trainer was
    /// constructed with (the layout-independent view used by checkpoints
    /// and external comparisons).
    pub fn alpha_original(&self) -> Vec<f64> {
        self.rows.to_original(&self.alpha)
    }

    /// Adopt a caller-row-order dual vector as the starting iterate and
    /// recompute w = Aα/(λn) against *this* trainer's data — the
    /// warm-start entry point for re-training on drifted data.
    ///
    /// Contrast with [`checkpoint::Checkpoint::restore`], which copies a
    /// stored w and *verifies* it against α, rejecting any drift: here the
    /// data may legitimately differ from what produced α (labels flipped,
    /// features re-measured), so w is derived fresh and the (α, w) pair is
    /// consistent by construction. Note α from old labels can start
    /// dual-infeasible on the new rows — the first local solves clamp it
    /// back into the feasible box, and callers driving this through a
    /// [`Driver`] should allow an infinite initial gap
    /// (`StopPolicy::with_divergence_gap(f64::INFINITY)`).
    pub fn warm_start_from_alpha(&mut self, alpha_original: &[f64]) -> Result<(), String> {
        if alpha_original.len() != self.problem.n() {
            return Err(format!(
                "warm-start α has {} entries, problem has n = {}",
                alpha_original.len(),
                self.problem.n()
            ));
        }
        if alpha_original.iter().any(|v| !v.is_finite()) {
            return Err("warm-start α contains non-finite values".into());
        }
        let layout_alpha = self.rows.to_permuted(alpha_original);
        self.alpha.copy_from_slice(&layout_alpha);
        self.problem.primal_from_dual(&self.alpha, &mut self.w);
        self.sync_workers_from_alpha();
        Ok(())
    }

    /// Recompute w from α and report the max deviation from the maintained
    /// w (the coordinator's central invariant; ~0 up to float error).
    pub fn primal_consistency_error(&self) -> f64 {
        let mut w_ref = vec![0.0; self.problem.d()];
        self.problem.primal_from_dual(&self.alpha, &mut w_ref);
        w_ref
            .iter()
            .zip(&self.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max)
    }

    /// Run under the policy encoded in `cfg` (gap tolerance, divergence,
    /// round budget, certificate cadence) through the shared
    /// method-agnostic [`Driver`] loop.
    pub fn run(&mut self) -> History {
        let mut driver = Driver::from_cocoa_config(&self.cfg);
        driver.run(self)
    }
}

impl Method for Trainer {
    fn step(&mut self) -> StepStats {
        let compute_s = self.round();
        StepStats {
            compute_s,
            comm_vectors: self.cfg.comm.round_vectors(self.cfg.k),
        }
    }

    /// Pool-distributed duality-gap certificate: each worker reduces its
    /// own shard to a partial primal-loss sum and partial conjugate sum
    /// (its local margins are consumed on the fly) in parallel, and the
    /// leader combines the K partials with the ‖w‖² term. The sequential
    /// executor runs the identical partial/combine path, so both runtimes
    /// produce bit-identical gap trajectories.
    fn eval(&mut self) -> crate::objective::Certificates {
        match self.executor.eval_partials(&self.w) {
            Ok(partials) => self.problem.certificates_from_partials(partials, &self.w),
            Err(e) => panic!("distributed certificate evaluation failed: {e}"),
        }
    }

    fn comm_vectors_per_round(&self) -> usize {
        self.cfg.comm.round_vectors(self.cfg.k)
    }

    fn w(&self) -> &[f64] {
        &self.w
    }

    fn label(&self) -> String {
        format!(
            "{}(K={},γ={},σ'={},{})",
            if self.cfg.gamma() >= 1.0 { "cocoa+" } else { "cocoa" },
            self.cfg.k,
            self.cfg.gamma(),
            self.spec.sigma_prime,
            self.executor.solver_name(),
        )
    }

    fn comm_model(&self) -> comm::CommModel {
        self.cfg.comm
    }

    fn runtime_notes(&self) -> Option<String> {
        Some(format!(
            "{} executor; {}",
            self.executor_kind(),
            self.comm_stats().runtime_summary()
        ))
    }

    /// Measured-vs-simulated communication validation (socket runtime
    /// only — the in-process executors move no real bytes).
    fn comm_report(&self) -> Option<String> {
        self.comm_stats().validation_report()
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.w))
    }

    fn checkpoint(&self) -> Option<checkpoint::Checkpoint> {
        Some(checkpoint::Checkpoint::capture(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    fn problem(n: usize, d: usize, lambda: f64, loss: Loss) -> Problem {
        let data = generate(&SynthConfig::new("t", n, d).seed(31));
        Problem::new(data, loss, lambda)
    }

    fn trainer(k: usize, cfg_fn: impl Fn(CocoaConfig) -> CocoaConfig) -> Trainer {
        let p = problem(80, 10, 0.05, Loss::Hinge);
        let part = random_balanced(80, k, 5);
        let cfg = cfg_fn(CocoaConfig::cocoa_plus(
            k,
            Loss::Hinge,
            0.05,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        ))
        .with_parallel(false);
        Trainer::new(p, part, cfg)
    }

    #[test]
    fn invariant_w_equals_a_alpha() {
        let mut t = trainer(4, |c| c.with_rounds(5));
        for _ in 0..5 {
            t.round();
        }
        assert!(
            t.primal_consistency_error() < 1e-9,
            "w drifted from Aα/(λn): {}",
            t.primal_consistency_error()
        );
    }

    #[test]
    fn warm_start_adopts_alpha_and_recomputes_w() {
        // Train one trainer, warm-start a fresh one (different partition
        // seed → different internal layout) from its caller-order α: the
        // adopted state must satisfy w = Aα/(λn) by construction and reach
        // the workers, and a converged α must leave the warm trainer
        // already near the optimum (the drift re-training story).
        let mut src = trainer(4, |c| c.with_rounds(60).with_gap_tol(1e-4));
        src.run();
        let src_gap = src.eval().gap;
        let alpha0 = src.alpha_original();

        let p = problem(80, 10, 0.05, Loss::Hinge);
        let part = random_balanced(80, 4, 99); // different permutation
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            0.05,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(50)
        .with_parallel(false);
        let mut warm = Trainer::new(p, part, cfg);
        warm.warm_start_from_alpha(&alpha0).unwrap();
        assert!(warm.primal_consistency_error() < 1e-12);
        assert_eq!(warm.alpha_original(), alpha0, "layout gather lost α");
        // same data + same (α, w) ⇒ same global gap, up to the different
        // partition's partial-sum order
        let gap = warm.eval().gap;
        assert!(
            (gap - src_gap).abs() < 1e-9,
            "warm-start gap {gap} vs source gap {src_gap}"
        );

        // hostile warm starts are rejected without touching state
        let before = warm.alpha.clone();
        assert!(warm.warm_start_from_alpha(&alpha0[..10]).is_err());
        let mut bad = alpha0.clone();
        bad[0] = f64::NAN;
        assert!(warm.warm_start_from_alpha(&bad).is_err());
        assert_eq!(warm.alpha, before);
    }

    #[test]
    fn dual_monotone_under_safe_sigma() {
        // Lemma 3 + exact coordinate maximization ⇒ D never decreases with
        // the safe σ' = γK.
        let mut t = trainer(4, |c| c.with_rounds(15));
        let mut prev = f64::NEG_INFINITY;
        for _ in 0..15 {
            t.round();
            let d = t.problem.dual_value(&t.alpha, &t.w);
            assert!(d >= prev - 1e-10, "dual decreased: {d} < {prev}");
            prev = d;
        }
    }

    #[test]
    fn run_reaches_gap_on_easy_problem() {
        let mut t = trainer(2, |c| c.with_rounds(300).with_gap_tol(1e-3));
        let hist = t.run();
        assert_eq!(hist.stop, StopReason::GapReached, "final gap {}", hist.final_gap());
    }

    #[test]
    fn distributed_certificates_match_central_evaluation() {
        let mut t = trainer(4, |c| c.with_rounds(5));
        for _ in 0..5 {
            t.round();
        }
        let dist = t.eval();
        let central = t.problem.certificates(&t.alpha, &t.w);
        assert!(
            (dist.primal - central.primal).abs() < 1e-12,
            "primal {} vs {}",
            dist.primal,
            central.primal
        );
        assert!((dist.dual - central.dual).abs() < 1e-12);
        assert!((dist.gap - central.gap).abs() < 1e-12);
    }

    #[test]
    fn shared_layout_one_dataset_copy_and_original_order_mapping() {
        let original = problem(80, 10, 0.05, Loss::Hinge);
        let part = random_balanced(80, 4, 5);
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            0.05,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_parallel(false);
        let mut t = Trainer::new(original.clone(), part, cfg);
        // the trainer's partition was canonicalized to contiguous ranges
        let mut next = 0usize;
        for &(start, len) in &t.shards {
            assert_eq!(start, next, "shards must tile 0..n in worker order");
            next += len;
        }
        assert_eq!(next, 80);
        assert!(!t.rows.is_identity(), "random partition must permute");
        for _ in 0..5 {
            t.round();
        }
        // the caller-order α certifies equivalently on the caller's problem
        let internal = t.problem.certificates(&t.alpha, &t.w);
        let external = original.certificates(&t.alpha_original(), &t.w);
        assert!(
            (internal.gap - external.gap).abs() < 1e-9,
            "layout changed the certificate: {} vs {}",
            internal.gap,
            external.gap
        );
        // scatter check: layout row holds exactly the original row's dual
        let orig = t.alpha_original();
        for (new, &old) in t.rows.new_to_old.iter().enumerate() {
            assert_eq!(orig[old], t.alpha[new]);
        }
    }

    #[test]
    fn pooled_runtime_selected_and_runtime_stats_recorded() {
        let p = problem(60, 8, 0.05, Loss::Hinge);
        let part = random_balanced(60, 3, 5);
        let cfg = CocoaConfig::cocoa_plus(3, Loss::Hinge, 0.05, SolverSpec::Sdca { h: 20 })
            .with_rounds(2);
        let mut t = Trainer::new(p, part, cfg);
        assert_eq!(t.executor_kind(), "pooled");
        t.round();
        t.round();
        let s = t.comm_stats();
        assert_eq!(s.rounds, 2);
        assert!(s.barrier_s >= 0.0, "barrier time must be accounted");
        assert!(s.reduce_s >= 0.0, "reduce time must be accounted");
    }

    #[test]
    fn k1_parallel_degenerates_to_sequential_runtime() {
        let p = problem(40, 6, 0.05, Loss::Hinge);
        let part = random_balanced(40, 1, 5);
        let cfg = CocoaConfig::cocoa_plus(1, Loss::Hinge, 0.05, SolverSpec::Sdca { h: 20 })
            .with_rounds(3);
        assert!(cfg.parallel, "preset should default to parallel");
        let mut t = Trainer::new(p, part, cfg);
        assert_eq!(t.executor_kind(), "sequential");
        for _ in 0..3 {
            t.round();
        }
        assert!(t.primal_consistency_error() < 1e-9);
    }

    #[test]
    fn parallel_matches_sequential() {
        let mk = |parallel: bool| {
            let p = problem(60, 8, 0.05, Loss::Hinge);
            let part = random_balanced(60, 3, 5);
            let cfg = CocoaConfig::cocoa_plus(
                3,
                Loss::Hinge,
                0.05,
                SolverSpec::Sdca { h: 40 },
            )
            .with_rounds(6)
            .with_parallel(parallel);
            let mut t = Trainer::new(p, part, cfg);
            t.run();
            (t.alpha, t.w)
        };
        let (a_seq, w_seq) = mk(false);
        let (a_par, w_par) = mk(true);
        assert_eq!(a_seq, a_par, "parallel execution changed the trajectory");
        assert_eq!(w_seq, w_par);
    }

    #[test]
    fn averaging_preset_converges_slower_per_round() {
        // CoCoA (γ=1/K) gains less per round than CoCoA+ (γ=1) at equal
        // local work — the paper's core claim, in miniature.
        let gap_after = |plus: bool| {
            let p = problem(120, 10, 0.01, Loss::Hinge);
            let part = random_balanced(120, 8, 5);
            let cfg = if plus {
                CocoaConfig::cocoa_plus(8, Loss::Hinge, 0.01, SolverSpec::SdcaEpochs { epochs: 1.0 })
            } else {
                CocoaConfig::cocoa(8, Loss::Hinge, 0.01, SolverSpec::SdcaEpochs { epochs: 1.0 })
            }
            .with_rounds(10)
            .with_parallel(false);
            let mut t = Trainer::new(p, part, cfg);
            t.run().final_gap()
        };
        let plus = gap_after(true);
        let avg = gap_after(false);
        assert!(
            plus < avg,
            "CoCoA+ ({plus}) should beat CoCoA ({avg}) after equal rounds"
        );
    }

    #[test]
    fn unsafe_sigma_prime_can_diverge_or_stall() {
        // Fig. 3: σ' well below safe (e.g. σ'=1 with γ=1, K=8) breaks the
        // guarantee. We only assert it is *worse* than safe, since tiny
        // problems may not blow up spectacularly.
        let run_with = |sp: f64| {
            let p = problem(120, 10, 0.001, Loss::Hinge);
            let part = random_balanced(120, 8, 5);
            let cfg = CocoaConfig::cocoa_plus(
                8,
                Loss::Hinge,
                0.001,
                SolverSpec::SdcaEpochs { epochs: 2.0 },
            )
            .with_sigma_prime(sp)
            .with_rounds(25)
            .with_parallel(false);
            let mut t = Trainer::new(p, part, cfg);
            let h = t.run();
            (h.final_gap(), h.diverged())
        };
        let (gap_safe, div_safe) = run_with(8.0);
        let (gap_unsafe, div_unsafe) = run_with(0.5);
        assert!(!div_safe);
        assert!(
            div_unsafe || gap_unsafe > gap_safe,
            "unsafe σ' should diverge or trail safe: {gap_unsafe} vs {gap_safe}"
        );
    }
}
