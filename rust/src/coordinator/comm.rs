//! Communication accounting and the simulated cluster time model.
//!
//! The paper reports (i) the number of communicated vectors and (ii)
//! elapsed wall-clock on a Spark/EC2 cluster. We execute all workers on
//! one host, so the *communication* share of each round is simulated with
//! a simple star-topology model calibrated to EC2-class hardware, while
//! the *compute* share is the measured max over workers (the slowest
//! worker gates the round, exactly as in a synchronous cluster):
//!
//!   t_round = max_k(compute_k) + 2·(latency + d·8B / bandwidth)
//!
//! (one gather of Δw_k and one broadcast of the new w per round; transfers
//! to/from K workers overlap, latency does not). Vector counting follows
//! the paper: one vector per worker per round (Fig. 1's x-axis).

/// Network model for the simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// One-way latency per round trip component, seconds.
    pub latency_s: f64,
    /// Effective per-link bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// If false, report zero simulated comm time (pure compute curves).
    pub enabled: bool,
}

impl CommModel {
    /// EC2 m3.large-era constants: ~0.5 ms latency, ~1 Gbit/s effective.
    pub fn ec2_like() -> CommModel {
        CommModel {
            latency_s: 5e-4,
            bandwidth_bps: 125e6,
            enabled: true,
        }
    }

    /// A slower network (e.g. cross-rack): stresses communication
    /// efficiency, widening the CoCoA+ vs mini-batch gap.
    pub fn slow_network() -> CommModel {
        CommModel {
            latency_s: 5e-3,
            bandwidth_bps: 12.5e6,
            enabled: true,
        }
    }

    pub fn disabled() -> CommModel {
        CommModel {
            latency_s: 0.0,
            bandwidth_bps: 1.0,
            enabled: false,
        }
    }

    /// Simulated communication seconds for one synchronous round that
    /// moves one d-dimensional f64 vector up (reduce) and one down
    /// (broadcast).
    pub fn round_time(&self, d: usize) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        let bytes = (d * 8) as f64;
        2.0 * (self.latency_s + bytes / self.bandwidth_bps)
    }

    /// Vectors communicated in one round: one per worker (paper's count).
    pub fn round_vectors(&self, k: usize) -> usize {
        k
    }
}

/// One round's communication, simulated next to measured. `wire_s` is
/// zero for the in-process executors — only the socket runtime moves
/// real bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundComm {
    /// Simulated cluster comm seconds (the star-topology model).
    pub sim_s: f64,
    /// Measured leader-side wire seconds (frame sends + reply body reads).
    pub wire_s: f64,
}

/// Running totals the coordinator keeps. The simulated quantities
/// (`sim_comm_s`) model the cluster network; `barrier_s`/`reduce_s` are
/// *measured* runtime overheads of the in-process execution engine, kept
/// separate so compute-time curves stay clean: the fan-out/gather
/// synchronization of the worker pool lands in `barrier_s` (under the old
/// spawn-per-round runtime, thread-spawn cost silently inflated measured
/// compute instead) and the leader's Eq.-14 scatter/axpy lands in
/// `reduce_s`. On the socket runtime the leader's measured per-round wire
/// time additionally lands in `wire_s` and the per-round `samples`, so
/// the simulated model can be validated against a real transport
/// ([`CommStats::validation_report`]).
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub rounds: usize,
    pub vectors: usize,
    pub bytes: usize,
    pub sim_comm_s: f64,
    /// Measured runtime fan-out/gather seconds beyond worker compute.
    pub barrier_s: f64,
    /// Measured leader-side reduce seconds (α scatter + w axpy).
    pub reduce_s: f64,
    /// Total measured wire seconds (socket executor only; else 0).
    pub wire_s: f64,
    /// Per-round simulated-vs-measured comm samples, in round order.
    pub samples: Vec<RoundComm>,
}

impl CommStats {
    pub fn record_round(&mut self, model: &CommModel, d: usize, k: usize) {
        self.rounds += 1;
        self.vectors += model.round_vectors(k);
        self.bytes += k * d * 8;
        let sim_s = model.round_time(d);
        self.sim_comm_s += sim_s;
        self.samples.push(RoundComm { sim_s, wire_s: 0.0 });
    }

    /// Accumulate the measured runtime overheads of one round. Pairs with
    /// the `record_round` of the same round: the measured wire share is
    /// filed into that round's sample.
    pub fn record_runtime(&mut self, barrier_s: f64, reduce_s: f64, wire_s: f64) {
        self.barrier_s += barrier_s;
        self.reduce_s += reduce_s;
        self.wire_s += wire_s;
        if let Some(sample) = self.samples.last_mut() {
            sample.wire_s += wire_s;
        }
    }

    /// Measured-vs-simulated communication report: per-round measured
    /// wire seconds next to the model's prediction, with totals and the
    /// mean measured/simulated ratio. `None` when nothing was measured
    /// (in-process executors move no bytes). The per-round table is
    /// capped; totals always cover every round.
    pub fn validation_report(&self) -> Option<String> {
        if !(self.wire_s > 0.0) {
            return None;
        }
        const MAX_ROWS: usize = 20;
        let mut out = String::from(
            "measured vs simulated communication (leader wire time per round):\n",
        );
        out.push_str("  round   measured(µs)  simulated(µs)   ratio\n");
        for (i, s) in self.samples.iter().take(MAX_ROWS).enumerate() {
            let ratio = if s.sim_s > 0.0 {
                format!("{:7.3}", s.wire_s / s.sim_s)
            } else {
                "      -".to_string()
            };
            out.push_str(&format!(
                "  {:5}  {:12.1}  {:13.1}  {}\n",
                i,
                s.wire_s * 1e6,
                s.sim_s * 1e6,
                ratio
            ));
        }
        if self.samples.len() > MAX_ROWS {
            out.push_str(&format!(
                "  ... {} more round(s) elided\n",
                self.samples.len() - MAX_ROWS
            ));
        }
        let ratio_total = if self.sim_comm_s > 0.0 {
            format!("{:.3}", self.wire_s / self.sim_comm_s)
        } else {
            "-".to_string()
        };
        out.push_str(&format!(
            "  total  {:12.1}  {:13.1}  ratio {} over {} round(s)",
            self.wire_s * 1e6,
            self.sim_comm_s * 1e6,
            ratio_total,
            self.rounds
        ));
        Some(out)
    }

    /// Mean per-round runtime overhead (barrier + reduce), seconds.
    pub fn runtime_overhead_per_round_s(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        (self.barrier_s + self.reduce_s) / self.rounds as f64
    }

    /// One-line human-readable per-round overhead breakdown (CLI + bench).
    pub fn runtime_summary(&self) -> String {
        let rounds = self.rounds.max(1) as f64;
        format!(
            "per-round overhead {:.1}µs (barrier {:.1}µs + reduce {:.1}µs over {} rounds)",
            self.runtime_overhead_per_round_s() * 1e6,
            self.barrier_s / rounds * 1e6,
            self.reduce_s / rounds * 1e6,
            self.rounds
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_time_scales_with_d() {
        let m = CommModel::ec2_like();
        let t_small = m.round_time(100);
        let t_big = m.round_time(1_000_000);
        assert!(t_big > t_small);
        // latency floor
        assert!(t_small >= 2.0 * m.latency_s);
    }

    #[test]
    fn disabled_model_is_free() {
        let m = CommModel::disabled();
        assert_eq!(m.round_time(1_000_000), 0.0);
    }

    #[test]
    fn stats_accumulate() {
        let m = CommModel::ec2_like();
        let mut s = CommStats::default();
        s.record_round(&m, 1000, 8);
        s.record_round(&m, 1000, 8);
        assert_eq!(s.rounds, 2);
        assert_eq!(s.vectors, 16);
        assert_eq!(s.bytes, 2 * 8 * 1000 * 8);
        assert!((s.sim_comm_s - 2.0 * m.round_time(1000)).abs() < 1e-12);
    }

    #[test]
    fn runtime_overhead_accumulates_separately() {
        let m = CommModel::ec2_like();
        let mut s = CommStats::default();
        s.record_round(&m, 100, 4);
        s.record_runtime(2e-4, 1e-4, 0.0);
        s.record_round(&m, 100, 4);
        s.record_runtime(2e-4, 1e-4, 0.0);
        assert!((s.barrier_s - 4e-4).abs() < 1e-12);
        assert!((s.reduce_s - 2e-4).abs() < 1e-12);
        assert!((s.runtime_overhead_per_round_s() - 3e-4).abs() < 1e-12);
        // runtime overhead must not leak into the simulated comm model
        assert!((s.sim_comm_s - 2.0 * m.round_time(100)).abs() < 1e-12);
    }

    #[test]
    fn slow_network_slower() {
        assert!(CommModel::slow_network().round_time(10_000) > CommModel::ec2_like().round_time(10_000));
    }

    #[test]
    fn validation_report_needs_measured_wire() {
        let m = CommModel::ec2_like();
        let mut s = CommStats::default();
        s.record_round(&m, 100, 4);
        s.record_runtime(2e-4, 1e-4, 0.0);
        // No wire time measured (in-process run): nothing to validate.
        assert!(s.validation_report().is_none());

        s.record_round(&m, 100, 4);
        s.record_runtime(2e-4, 1e-4, 3e-3);
        let report = s.validation_report().expect("wire time was measured");
        assert!(report.contains("measured vs simulated"), "{report}");
        assert!(report.contains("total"), "{report}");
        assert_eq!(s.samples.len(), 2);
        assert_eq!(s.samples[0].wire_s, 0.0);
        assert!((s.samples[1].wire_s - 3e-3).abs() < 1e-12);
        assert!((s.wire_s - 3e-3).abs() < 1e-12);
    }
}
