//! Length-prefixed wire format for the socket executor.
//!
//! Every message is a single *frame*:
//!
//! ```text
//! [u32 BE total_len] [u32 BE header_len] [header JSON] [binary sections]
//! ```
//!
//! `total_len` counts everything after the first four bytes. The header is
//! compact JSON (see [`crate::util::json`]) carrying small scalar fields
//! plus a section manifest under the reserved key `"sec"`: a list of
//! `[name, kind, len]` entries describing the binary payload that follows,
//! in order. Numeric payloads (`w`, `Δα`, `Δw`, CSR arrays, …) ride as raw
//! little-endian 8-byte words — `f64::to_bits` for floats, plain `u64` for
//! indices — so values round-trip *bit-exactly*, including NaN payloads,
//! infinities, and signed zeros that JSON would mangle.
//!
//! The reader is written for hostile input: truncated frames, oversized
//! length prefixes, mid-message EOF, garbage headers, and section manifests
//! that overrun the frame all surface as typed [`WireError`]s — never a
//! panic, and never an unbounded read.

use std::io::{ErrorKind, Read, Write};

use crate::util::json::{jarr, jnum, jstr, Json};
use crate::util::timer::Stopwatch;

/// Hard ceiling on a single frame (1 GiB). A corrupt or malicious length
/// prefix must not make the leader try to allocate 4 GiB.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Magic string exchanged in the hello handshake.
pub const WIRE_MAGIC: &str = "cocoa-wire";

/// Wire protocol version; bumped on any incompatible frame change.
pub const WIRE_VERSION: f64 = 1.0;

/// Typed errors for frame encoding/decoding and socket I/O.
#[derive(Debug)]
pub enum WireError {
    /// Underlying I/O failure (includes read timeouts).
    Io(std::io::Error),
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Closed,
    /// EOF in the middle of a frame: `got` of `expected` bytes arrived.
    Truncated { expected: usize, got: usize },
    /// Declared frame length exceeds [`MAX_FRAME_BYTES`].
    TooLarge { len: usize },
    /// Header is not valid UTF-8 / JSON, or a required field is missing
    /// or has the wrong type.
    Header(String),
    /// Section manifest is inconsistent with the binary payload.
    Section(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "i/o error: {e}"),
            WireError::Closed => write!(f, "connection closed by peer"),
            WireError::Truncated { expected, got } => {
                write!(f, "truncated frame: expected {expected} bytes, got {got}")
            }
            WireError::TooLarge { len } => {
                write!(f, "frame length {len} exceeds limit {MAX_FRAME_BYTES}")
            }
            WireError::Header(msg) => write!(f, "bad frame header: {msg}"),
            WireError::Section(msg) => write!(f, "bad frame section: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

impl WireError {
    /// True when the error is a read timeout rather than a dead peer.
    pub fn is_timeout(&self) -> bool {
        matches!(self, WireError::Io(e)
            if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
    }
}

/// One binary section: a named vector of 8-byte words.
#[derive(Debug, Clone, PartialEq)]
pub enum Section {
    F64(Vec<f64>),
    U64(Vec<u64>),
}

impl Section {
    fn kind(&self) -> &'static str {
        match self {
            Section::F64(_) => "f",
            Section::U64(_) => "u",
        }
    }

    fn len(&self) -> usize {
        match self {
            Section::F64(v) => v.len(),
            Section::U64(v) => v.len(),
        }
    }
}

/// A decoded (or to-be-encoded) message: JSON header + binary sections.
#[derive(Debug, Clone)]
pub struct Frame {
    header: Json,
    sections: Vec<(String, Section)>,
}

impl Frame {
    /// Start a frame whose header carries `{"t": msg_type}`.
    pub fn new(msg_type: &str) -> Frame {
        let mut header = Json::obj();
        header.set("t", jstr(msg_type));
        Frame {
            header,
            sections: Vec::new(),
        }
    }

    /// Set a numeric header field.
    pub fn set_num(mut self, key: &str, v: f64) -> Frame {
        self.header.set(key, jnum(v));
        self
    }

    /// Set a string header field.
    pub fn set_str(mut self, key: &str, v: &str) -> Frame {
        self.header.set(key, jstr(v));
        self
    }

    /// Set an arbitrary JSON header field.
    pub fn set_json(mut self, key: &str, v: Json) -> Frame {
        self.header.set(key, v);
        self
    }

    /// Append a named `f64` section (bit-exact transport).
    pub fn with_f64s(mut self, name: &str, v: Vec<f64>) -> Frame {
        self.sections.push((name.to_string(), Section::F64(v)));
        self
    }

    /// Append a named `u64` section.
    pub fn with_u64s(mut self, name: &str, v: Vec<u64>) -> Frame {
        self.sections.push((name.to_string(), Section::U64(v)));
        self
    }

    /// The message type tag (`"t"` header field), or `""` if absent.
    pub fn msg_type(&self) -> &str {
        self.header
            .get("t")
            .and_then(|j| j.as_str())
            .unwrap_or("")
    }

    /// Required numeric header field.
    pub fn num(&self, key: &str) -> Result<f64, WireError> {
        self.header
            .get(key)
            .and_then(|j| j.as_f64())
            .ok_or_else(|| WireError::Header(format!("missing numeric field {key:?}")))
    }

    /// Required non-negative integral header field. Rejects NaN, negative,
    /// and fractional values instead of truncating them.
    pub fn usize_field(&self, key: &str) -> Result<usize, WireError> {
        let v = self.num(key)?;
        if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > 9.007_199_254_740_992e15 {
            return Err(WireError::Header(format!(
                "field {key:?} is not a valid index: {v}"
            )));
        }
        Ok(v as usize)
    }

    /// Required string header field.
    pub fn str_field(&self, key: &str) -> Result<&str, WireError> {
        self.header
            .get(key)
            .and_then(|j| j.as_str())
            .ok_or_else(|| WireError::Header(format!("missing string field {key:?}")))
    }

    /// Optional string header field.
    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.header.get(key).and_then(|j| j.as_str())
    }

    /// Optional JSON header field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.header.get(key)
    }

    /// Required `f64` section by name.
    pub fn f64s(&self, name: &str) -> Result<&[f64], WireError> {
        for (n, s) in &self.sections {
            if n == name {
                return match s {
                    Section::F64(v) => Ok(v),
                    Section::U64(_) => Err(WireError::Section(format!(
                        "section {name:?} is u64, expected f64"
                    ))),
                };
            }
        }
        Err(WireError::Section(format!("missing f64 section {name:?}")))
    }

    /// Required `u64` section by name.
    pub fn u64s(&self, name: &str) -> Result<&[u64], WireError> {
        for (n, s) in &self.sections {
            if n == name {
                return match s {
                    Section::U64(v) => Ok(v),
                    Section::F64(_) => Err(WireError::Section(format!(
                        "section {name:?} is f64, expected u64"
                    ))),
                };
            }
        }
        Err(WireError::Section(format!("missing u64 section {name:?}")))
    }
}

/// Serialize one frame to `w`. The section manifest is injected into the
/// header at write time, so callers never maintain it by hand.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    let mut header = frame.header.clone();
    let manifest: Vec<Json> = frame
        .sections
        .iter()
        .map(|(name, s)| jarr(vec![jstr(name), jstr(s.kind()), jnum(s.len() as f64)]))
        .collect();
    header.set("sec", jarr(manifest));
    let header_bytes = header.to_string_compact().into_bytes();

    // Checked end to end: a silent wrap here would emit an under-sized
    // length prefix and desynchronize the stream for every later frame
    // (cocoa-lint `arith_overflow` rejects unchecked `+`/`*` on these
    // size computations).
    let total_len = frame
        .sections
        .iter()
        .try_fold(0usize, |acc, (_, s)| acc.checked_add(s.len()))
        .and_then(|words| words.checked_mul(8))
        .and_then(|body| body.checked_add(header_bytes.len()))
        .and_then(|len| len.checked_add(4))
        .ok_or(WireError::TooLarge { len: usize::MAX })?;
    if total_len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge { len: total_len });
    }
    w.write_all(&(total_len as u32).to_be_bytes())?;
    w.write_all(&(header_bytes.len() as u32).to_be_bytes())?;
    w.write_all(&header_bytes)?;
    for (_, s) in &frame.sections {
        match s {
            Section::F64(v) => {
                for x in v {
                    w.write_all(&x.to_bits().to_le_bytes())?;
                }
            }
            Section::U64(v) => {
                for x in v {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Read exactly `buf.len()` bytes. EOF before the first byte of a frame is
/// a clean [`WireError::Closed`] when `at_frame_start`; EOF mid-way is
/// [`WireError::Truncated`].
fn read_exact_prefix<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    at_frame_start: bool,
) -> Result<(), WireError> {
    let mut got = 0;
    while got < buf.len() {
        // `got < buf.len()`, so the tail is never empty; the empty-slice
        // default keeps the bounds proof out of the panic domain (a read
        // into it would return Ok(0) → Truncated).
        let tail = buf.get_mut(got..).unwrap_or_default();
        match r.read(tail) {
            Ok(0) => {
                return if got == 0 && at_frame_start {
                    Err(WireError::Closed)
                } else {
                    Err(WireError::Truncated {
                        expected: buf.len(),
                        got,
                    })
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Decode one little-endian 8-byte word. `chunks_exact(8)` guarantees the
/// length, but `u64::from_le_bytes(c.try_into().unwrap())` would put an
/// `unwrap` on the hostile-input path; the fold is branch- and panic-free.
#[inline]
fn le_word(chunk: &[u8]) -> u64 {
    chunk
        .iter()
        .take(8)
        .enumerate()
        .fold(0u64, |acc, (i, &b)| acc | (u64::from(b) << (8 * i)))
}

/// Where one frame receive spent its wall time, split where the protocol
/// splits: `wait_s` is time blocked on the 4-byte length prefix (the
/// peer is still computing or the message is in flight), `body_s` is
/// time actually moving the frame body once bytes are flowing — the
/// share that is genuinely wire transfer.
#[derive(Clone, Copy, Debug, Default)]
pub struct RecvTiming {
    pub wait_s: f64,
    pub body_s: f64,
}

/// Read and decode one frame from `r`.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    read_frame_timed(r).map(|(frame, _)| frame)
}

/// [`read_frame`], also reporting where the receive's wall time went.
/// The telemetry layer and `CommStats` use this to separate measured
/// wire transfer from the barrier wait.
pub fn read_frame_timed<R: Read>(r: &mut R) -> Result<(Frame, RecvTiming), WireError> {
    let mut len_buf = [0u8; 4];
    let wait_clock = Stopwatch::started();
    read_exact_prefix(r, &mut len_buf, true)?;
    let wait_s = wait_clock.elapsed_secs();
    let total_len = u32::from_be_bytes(len_buf) as usize;
    if total_len > MAX_FRAME_BYTES {
        return Err(WireError::TooLarge { len: total_len });
    }
    if total_len < 4 {
        return Err(WireError::Header(format!(
            "frame length {total_len} too short for a header"
        )));
    }
    let mut body = vec![0u8; total_len];
    let body_clock = Stopwatch::started();
    read_exact_prefix(r, &mut body, false)?;
    let timing = RecvTiming {
        wait_s,
        body_s: body_clock.elapsed_secs(),
    };

    // `total_len >= 4` was checked above, so the split cannot fail; the
    // typed fallback keeps even the impossible case out of the panic
    // domain (this module forbids direct indexing — see `cocoa-lint`).
    let (len_bytes, payload) = body
        .split_at_checked(4)
        .ok_or_else(|| WireError::Header("frame body shorter than its length prefix".to_string()))?;
    let header_len = match <[u8; 4]>::try_from(len_bytes) {
        Ok(b) => u32::from_be_bytes(b) as usize,
        Err(_) => return Err(WireError::Header("length prefix missing".to_string())),
    };
    let header_bytes = payload.get(..header_len).ok_or_else(|| {
        WireError::Header(format!(
            "header length {header_len} exceeds frame payload {}",
            payload.len()
        ))
    })?;
    let header_str = std::str::from_utf8(header_bytes)
        .map_err(|e| WireError::Header(format!("header is not UTF-8: {e}")))?;
    let header = Json::parse(header_str).map_err(WireError::Header)?;

    let mut sections = Vec::new();
    let mut off = header_len;
    let manifest = header
        .get("sec")
        .and_then(|j| j.as_arr())
        .ok_or_else(|| WireError::Header("missing section manifest \"sec\"".to_string()))?;
    for entry in manifest {
        let fields = entry
            .as_arr()
            .ok_or_else(|| WireError::Section("manifest entry is not an array".to_string()))?;
        let (name_j, kind_j, len_j) = match fields {
            [a, b, c] => (a, b, c),
            _ => {
                return Err(WireError::Section(format!(
                    "manifest entry has {} fields, expected 3",
                    fields.len()
                )))
            }
        };
        let name = name_j
            .as_str()
            .ok_or_else(|| WireError::Section("section name is not a string".to_string()))?;
        let kind = kind_j
            .as_str()
            .ok_or_else(|| WireError::Section("section kind is not a string".to_string()))?;
        let len_f = len_j
            .as_f64()
            .ok_or_else(|| WireError::Section("section length is not a number".to_string()))?;
        if !len_f.is_finite() || len_f < 0.0 || len_f.fract() != 0.0 {
            return Err(WireError::Section(format!(
                "section {name:?} has invalid length {len_f}"
            )));
        }
        let len = len_f as usize;
        let bytes = len
            .checked_mul(8)
            .ok_or_else(|| WireError::Section(format!("section {name:?} length overflows")))?;
        let end = off
            .checked_add(bytes)
            .ok_or_else(|| WireError::Section(format!("section {name:?} offset overflows")))?;
        let raw = payload.get(off..end).ok_or_else(|| {
            WireError::Section(format!(
                "section {name:?} ({bytes} bytes) overruns frame payload"
            ))
        })?;
        let section = match kind {
            "f" => Section::F64(
                raw.chunks_exact(8)
                    .map(|c| f64::from_bits(le_word(c)))
                    .collect(),
            ),
            "u" => Section::U64(raw.chunks_exact(8).map(le_word).collect()),
            other => {
                return Err(WireError::Section(format!(
                    "section {name:?} has unknown kind {other:?}"
                )));
            }
        };
        sections.push((name.to_string(), section));
        off = end;
    }
    if off != payload.len() {
        return Err(WireError::Section(format!(
            "{} trailing bytes after last section",
            payload.len() - off
        )));
    }
    Ok((Frame { header, sections }, timing))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).expect("encode");
        read_frame(&mut buf.as_slice()).expect("decode")
    }

    #[test]
    fn word_decode_is_bit_exact_for_raw_patterns() {
        // Regression for the panic-free little-endian word decode
        // (`le_word`): every byte position must land in its lane for both
        // section kinds, including sign-bit-only and all-ones words.
        let bits: Vec<u64> = vec![
            0x0123_4567_89AB_CDEF,
            u64::MAX,
            1,
            0x8000_0000_0000_0000,
            0x00FF_0000_0000_0000,
        ];
        let frame = Frame::new("t")
            .with_f64s("f", bits.iter().map(|&b| f64::from_bits(b)).collect())
            .with_u64s("u", bits.clone());
        let back = roundtrip(&frame);
        let fb: Vec<u64> = back
            .f64s("f")
            .unwrap()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(fb, bits);
        assert_eq!(back.u64s("u").unwrap(), &bits[..]);
    }

    #[test]
    fn roundtrip_is_bit_exact_for_special_floats() {
        let specials = vec![
            0.0,
            -0.0,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE / 2.0, // subnormal
            1.0 + f64::EPSILON,
            -1e308,
        ];
        let bits: Vec<u64> = specials.iter().map(|v| v.to_bits()).collect();
        let frame = Frame::new("round")
            .set_num("id", 3.0)
            .with_f64s("w", specials)
            .with_u64s("ix", vec![0, 1, u64::MAX]);
        let back = roundtrip(&frame);
        assert_eq!(back.msg_type(), "round");
        assert_eq!(back.num("id").unwrap(), 3.0);
        let got: Vec<u64> = back.f64s("w").unwrap().iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, bits, "f64 section must round-trip bit-exactly");
        assert_eq!(back.u64s("ix").unwrap(), &[0, 1, u64::MAX]);
    }

    #[test]
    fn empty_reader_is_closed_not_truncated() {
        let empty: &[u8] = &[];
        match read_frame(&mut &empty[..]) {
            Err(WireError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn truncated_length_prefix_is_truncated() {
        let partial: &[u8] = &[0, 0];
        match read_frame(&mut &partial[..]) {
            Err(WireError::Truncated { expected: 4, got: 2 }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn mid_message_eof_is_truncated() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::new("eval").with_f64s("w", vec![1.0; 16])).unwrap();
        let cut = &buf[..buf.len() / 2];
        match read_frame(&mut &cut[..]) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let hostile = u32::MAX.to_be_bytes();
        match read_frame(&mut &hostile[..]) {
            Err(WireError::TooLarge { len }) => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn garbage_header_is_header_error() {
        let header = b"not json";
        let total = 4 + header.len();
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Header(_)) => {}
            other => panic!("expected Header, got {other:?}"),
        }
    }

    #[test]
    fn header_len_exceeding_frame_is_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_be_bytes()); // total_len = 8 → 4 payload bytes
        buf.extend_from_slice(&100u32.to_be_bytes()); // header_len = 100 > 4
        buf.extend_from_slice(&[0u8; 4]);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Header(_)) => {}
            other => panic!("expected Header, got {other:?}"),
        }
    }

    #[test]
    fn section_overrun_is_rejected() {
        // Manifest claims 1000 f64 words, but the frame carries none.
        let header = r#"{"sec":[["w","f",1000]],"t":"round"}"#.as_bytes();
        let total = 4 + header.len();
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Section(_)) => {}
            other => panic!("expected Section, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let header = r#"{"sec":[],"t":"round"}"#.as_bytes();
        let total = 4 + header.len() + 8;
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        buf.extend_from_slice(&[0u8; 8]); // 8 bytes no manifest entry claims
        match read_frame(&mut &buf[..]) {
            Err(WireError::Section(_)) => {}
            other => panic!("expected Section, got {other:?}"),
        }
    }

    #[test]
    fn unknown_section_kind_is_rejected() {
        let header = r#"{"sec":[["w","x",1]],"t":"round"}"#.as_bytes();
        let total = 4 + header.len() + 8;
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        buf.extend_from_slice(&[0u8; 8]);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Section(_)) => {}
            other => panic!("expected Section, got {other:?}"),
        }
    }

    #[test]
    fn missing_manifest_is_header_error() {
        let header = r#"{"t":"round"}"#.as_bytes();
        let total = 4 + header.len();
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Header(_)) => {}
            other => panic!("expected Header, got {other:?}"),
        }
    }

    #[test]
    fn fractional_section_length_is_rejected() {
        let header = r#"{"sec":[["w","f",1.5]],"t":"round"}"#.as_bytes();
        let total = 4 + header.len() + 16;
        let mut buf = Vec::new();
        buf.extend_from_slice(&(total as u32).to_be_bytes());
        buf.extend_from_slice(&(header.len() as u32).to_be_bytes());
        buf.extend_from_slice(header);
        buf.extend_from_slice(&[0u8; 16]);
        match read_frame(&mut &buf[..]) {
            Err(WireError::Section(_)) => {}
            other => panic!("expected Section, got {other:?}"),
        }
    }

    #[test]
    fn usize_field_rejects_hostile_values() {
        let f = Frame::new("init")
            .set_num("neg", -1.0)
            .set_num("frac", 1.5)
            .set_num("ok", 42.0);
        assert!(f.usize_field("neg").is_err());
        assert!(f.usize_field("frac").is_err());
        assert!(f.usize_field("missing").is_err());
        assert_eq!(f.usize_field("ok").unwrap(), 42);
    }

    #[test]
    fn timed_read_matches_untimed_and_reports_phases() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::new("round").with_f64s("w", vec![1.0; 8])).unwrap();
        let (frame, timing) = read_frame_timed(&mut buf.as_slice()).unwrap();
        assert_eq!(frame.msg_type(), "round");
        assert_eq!(frame.f64s("w").unwrap().len(), 8);
        assert!(timing.wait_s >= 0.0);
        assert!(timing.body_s >= 0.0);
    }

    #[test]
    fn timeout_detection() {
        let timeout = WireError::Io(std::io::Error::new(ErrorKind::WouldBlock, "t"));
        assert!(timeout.is_timeout());
        assert!(!WireError::Closed.is_timeout());
    }
}
