//! Multi-process socket executor: K worker *processes* connected to the
//! leader over Unix domain sockets (or TCP behind an address flag).
//!
//! This is the third [`Executor`] next to the in-process sequential and
//! pooled-thread runtimes, and the first one where CoCoA+'s communication
//! rounds cross a real OS boundary: the leader serializes `w` into a
//! [`super::wire`] frame per round, each worker process solves its local
//! subproblem and replies with `(Δα_[k], Δw_k)`, and the leader gathers
//! replies in worker-id order so the reduction is bit-identical to the
//! other two executors.
//!
//! Lifecycle:
//!
//! 1. [`SocketExecutor::spawn`] binds a listener, launches K `cocoa worker
//!    --connect <addr> --worker <k>` child processes, and handshakes each
//!    one (hello → init → ready) under `cfg.socket.handshake_timeout`. A
//!    child that dies before connecting, presents a bad magic/version, or
//!    claims an out-of-range id fails the spawn with a [`PoolError`]
//!    naming it — never a hang.
//! 2. Each round broadcasts the `round` frame to all K workers at once —
//!    one scoped sender thread per connection, so the K serializations
//!    overlap on the wire — followed by an id-ordered gather. Dead
//!    connections, malformed replies, and read timeouts
//!    (`cfg.socket.round_timeout`) surface as `PoolError` entries; a
//!    worker-side solver panic is reported in-band and leaves the
//!    connection alive, mirroring the thread pool's semantics.
//! 3. Dropping the executor sends best-effort `shutdown` frames, closes
//!    the sockets, and reaps the children (kill after a 2 s grace).
//!
//! Determinism: the worker process receives its shard bit-exactly (CSR
//! arrays, labels, and cached row norms ride binary f64/u64 sections, and
//! are *not* recomputed), builds its local solver with the same
//! [`Worker::round_seed`] the in-process runtimes use, and runs the exact
//! same solver code — which is what lets the determinism suite assert
//! sequential ≡ pooled ≡ socket down to the last bit.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::config::{CocoaConfig, SolverSpec};
use super::make_solver;
use super::pool::{panic_message, Executor, PoolError, RoundTiming};
use super::wire::{self, Frame, WireError, WIRE_MAGIC, WIRE_VERSION};
use super::worker::{Worker, WorkerResult};
use crate::data::Dataset;
use crate::linalg::sparse::CsrMatrix;
use crate::loss::Loss;
use crate::objective::CertPartial;
use crate::subproblem::{LocalBlock, SubproblemSpec};
use crate::telemetry::Ring;
use crate::util::cli::Args;
use crate::util::json::{jnum, jstr, Json};
use crate::util::timer::{trace_now_us, Deadline, Stopwatch};

static SOCKET_COUNTER: AtomicUsize = AtomicUsize::new(0);

// ---------------------------------------------------------------------
// Transport: one stream type over Unix / TCP sockets
// ---------------------------------------------------------------------

/// A connected byte stream — Unix domain socket by default, TCP when the
/// config carries `socket.tcp_addr`.
enum Stream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(t),
            Stream::Tcp(s) => s.set_read_timeout(t),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nb),
            Stream::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

enum Listener {
    #[cfg(unix)]
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

/// One framed connection: buffered reader/writer over two clones of the
/// same socket.
struct Conn {
    reader: BufReader<Stream>,
    writer: BufWriter<Stream>,
}

impl Conn {
    fn new(stream: Stream) -> std::io::Result<Conn> {
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn {
            reader,
            writer: BufWriter::new(stream),
        })
    }

    fn send(&mut self, frame: &Frame) -> Result<(), WireError> {
        self.send_timed(frame).map(|_| ())
    }

    /// Send one frame, returning the seconds spent serializing and
    /// flushing it — the leader's measured outbound wire time.
    fn send_timed(&mut self, frame: &Frame) -> Result<f64, WireError> {
        let clock = Stopwatch::started();
        wire::write_frame(&mut self.writer, frame)?;
        self.writer.flush()?;
        Ok(clock.elapsed_secs())
    }

    fn recv(&mut self) -> Result<Frame, WireError> {
        wire::read_frame(&mut self.reader)
    }

    /// Receive one frame along with where its wall time went (blocked on
    /// the length prefix vs. moving the body).
    fn recv_timed(&mut self) -> Result<(Frame, wire::RecvTiming), WireError> {
        wire::read_frame_timed(&mut self.reader)
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        self.writer.get_ref().set_read_timeout(t)
    }
}

fn connect(addr: &str) -> Result<Stream, String> {
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        return TcpStream::connect(hostport)
            .map(Stream::Tcp)
            .map_err(|e| format!("connect {hostport:?} failed: {e}"));
    }
    #[cfg(unix)]
    {
        UnixStream::connect(addr)
            .map(Stream::Unix)
            .map_err(|e| format!("connect {addr:?} failed: {e}"))
    }
    #[cfg(not(unix))]
    {
        Err(format!(
            "unix socket {addr:?} unsupported on this platform; use socket.tcp_addr"
        ))
    }
}

// ---------------------------------------------------------------------
// Handshake frames
// ---------------------------------------------------------------------

fn hello_frame(id: usize) -> Frame {
    Frame::new("hello")
        .set_str("magic", WIRE_MAGIC)
        .set_num("version", WIRE_VERSION)
        .set_num("worker", id as f64)
}

/// Validate a worker's hello against this leader's protocol and K.
/// Public so the hostile-input suite can drive it directly.
pub fn validate_hello(frame: &Frame, k: usize) -> Result<usize, String> {
    if frame.msg_type() != "hello" {
        return Err(format!("expected hello, got {:?}", frame.msg_type()));
    }
    if frame.opt_str("magic") != Some(WIRE_MAGIC) {
        return Err(format!(
            "bad magic {:?} (expected {WIRE_MAGIC:?})",
            frame.opt_str("magic")
        ));
    }
    let version = frame.num("version").map_err(|e| e.to_string())?;
    if version != WIRE_VERSION {
        return Err(format!(
            "wire version {version} unsupported (leader speaks {WIRE_VERSION})"
        ));
    }
    let id = frame.usize_field("worker").map_err(|e| e.to_string())?;
    if id >= k {
        return Err(format!("worker id {id} out of range for K={k}"));
    }
    Ok(id)
}

/// Encode one worker's full init: subproblem spec + solver recipe in the
/// header, shard data (CSR arrays, labels, cached norms) and the solver
/// seed in bit-exact binary sections.
fn init_frame(block: &LocalBlock, spec: &SubproblemSpec, cfg: &CocoaConfig, id: usize) -> Frame {
    let ds = block.shared_data();
    let start = block.start();
    let len = block.n_local();
    let lo = ds.x.indptr[start];
    let hi = ds.x.indptr[start + len];
    let ip: Vec<u64> = ds.x.indptr[start..=start + len]
        .iter()
        .map(|p| (p - lo) as u64)
        .collect();
    let ix: Vec<u64> = ds.x.indices[lo..hi].iter().map(|&i| i as u64).collect();
    let values = ds.x.values[lo..hi].to_vec();

    let mu = match spec.loss {
        Loss::SmoothedHinge { mu } => mu,
        _ => 0.0,
    };
    let (mut epochs_f, mut beta) = (0.0, 0.0);
    let mut solver = Json::obj();
    match cfg.solver {
        SolverSpec::Sdca { h } => {
            solver.set("kind", jstr("sdca"));
            solver.set("h", jnum(h as f64));
        }
        SolverSpec::SdcaEpochs { epochs } => {
            solver.set("kind", jstr("sdca_epochs"));
            epochs_f = epochs;
        }
        SolverSpec::Cyclic { epochs, shuffle } => {
            solver.set("kind", jstr("cyclic"));
            solver.set("epochs", jnum(epochs as f64));
            solver.set("shuffle", Json::Bool(shuffle));
        }
        SolverSpec::Jacobi { sweeps, beta: b } => {
            solver.set("kind", jstr("jacobi"));
            solver.set("sweeps", jnum(sweeps as f64));
            beta = b;
        }
    }

    Frame::new("init")
        .set_num("id", id as f64)
        .set_num("k", spec.k as f64)
        .set_num("n", spec.n_global as f64)
        .set_num("d", block.d() as f64)
        .set_num("n_local", len as f64)
        .set_str("loss", spec.loss.name())
        .set_json("solver", solver)
        .with_f64s(
            "par",
            vec![spec.lambda, spec.sigma_prime, mu, epochs_f, beta],
        )
        .with_f64s("y", block.y().to_vec())
        .with_f64s("nr", block.norms_sq().to_vec())
        .with_f64s("v", values)
        .with_u64s("ip", ip)
        .with_u64s("ix", ix)
        .with_u64s("seed", vec![Worker::round_seed(cfg.seed, 0, id)])
}

// ---------------------------------------------------------------------
// Leader side: SocketExecutor
// ---------------------------------------------------------------------

/// Multi-process executor: K worker processes over sockets. See the
/// module docs for the protocol and failure contract.
pub struct SocketExecutor {
    k: usize,
    conns: Vec<Option<Conn>>,
    children: Vec<Option<Child>>,
    results: Vec<WorkerResult>,
    /// `(start, len)` row range per worker in the shared layout (for
    /// `load_alpha` slice copies).
    parts: Vec<(usize, usize)>,
    solver_name: String,
    round_timeout: Option<Duration>,
    /// Unix socket path to unlink on drop.
    sock_path: Option<PathBuf>,
    /// Leader trace lane (tid 0): per-frame send/recv wire spans.
    ring: Ring,
    /// One lane per worker process: the leader synthesizes each worker's
    /// `compute` span from its reported compute seconds (the process's
    /// own clock never crosses the wire, so lanes stay on one epoch).
    worker_rings: Vec<Ring>,
    round: u64,
}

impl SocketExecutor {
    /// Spawn and handshake K worker processes, one per local block. Any
    /// failure — no worker binary, a child dying before its handshake, a
    /// protocol mismatch — returns a [`PoolError`] naming the worker, and
    /// already-spawned children are reaped.
    pub fn spawn(
        blocks: &[LocalBlock],
        spec: SubproblemSpec,
        cfg: &CocoaConfig,
    ) -> Result<SocketExecutor, PoolError> {
        let k = blocks.len();
        assert!(k > 0, "cannot build an empty socket executor");
        let results = blocks
            .iter()
            .enumerate()
            .map(|(i, b)| WorkerResult::with_dims(i, b.n_local(), b.d()))
            .collect();
        let parts = blocks.iter().map(|b| (b.start(), b.n_local())).collect();
        let mut exec = SocketExecutor {
            k,
            conns: (0..k).map(|_| None).collect(),
            children: (0..k).map(|_| None).collect(),
            results,
            parts,
            solver_name: String::new(),
            round_timeout: cfg.socket.round_timeout,
            sock_path: None,
            ring: cfg.trace.ring(0),
            worker_rings: (0..k).map(|i| cfg.trace.ring(1 + i as u32)).collect(),
            round: 0,
        };
        // On error the partially-built executor is dropped here, which
        // reaps any children already spawned and unlinks the socket.
        exec.handshake(blocks, &spec, cfg)?;
        Ok(exec)
    }

    fn handshake(
        &mut self,
        blocks: &[LocalBlock],
        spec: &SubproblemSpec,
        cfg: &CocoaConfig,
    ) -> Result<(), PoolError> {
        let k = self.k;
        let bin = cfg
            .socket
            .worker_bin
            .clone()
            .or_else(|| std::env::var_os("COCOA_WORKER_BIN").map(PathBuf::from))
            .or_else(|| std::env::current_exe().ok())
            .ok_or_else(|| spawn_err(0, "cannot locate a cocoa binary for worker processes"))?;

        let (listener, addr) = match &cfg.socket.tcp_addr {
            Some(tcp) => {
                let l = TcpListener::bind(tcp)
                    .map_err(|e| spawn_err(0, &format!("bind {tcp:?} failed: {e}")))?;
                let local = l
                    .local_addr()
                    .map_err(|e| spawn_err(0, &format!("local_addr failed: {e}")))?;
                (Listener::Tcp(l), format!("tcp:{local}"))
            }
            None => {
                #[cfg(unix)]
                {
                    let path = std::env::temp_dir().join(format!(
                        "cocoa-{}-{}.sock",
                        std::process::id(),
                        SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
                    ));
                    let _ = std::fs::remove_file(&path);
                    let l = UnixListener::bind(&path).map_err(|e| {
                        spawn_err(0, &format!("bind {} failed: {e}", path.display()))
                    })?;
                    self.sock_path = Some(path.clone());
                    (Listener::Unix(l), path.display().to_string())
                }
                #[cfg(not(unix))]
                {
                    return Err(spawn_err(
                        0,
                        "unix sockets unsupported on this platform; set socket.tcp_addr",
                    ));
                }
            }
        };

        for (i, child) in self.children.iter_mut().enumerate() {
            let spawned = Command::new(&bin)
                .arg("worker")
                .arg("--connect")
                .arg(&addr)
                .arg("--worker")
                .arg(i.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .spawn()
                .map_err(|e| spawn_err(i, &format!("spawn {} failed: {e}", bin.display())))?;
            *child = Some(spawned);
        }

        // Accept-poll loop: take hellos as they arrive, failing fast when
        // a not-yet-connected child has already exited.
        listener
            .set_nonblocking(true)
            .map_err(|e| spawn_err(0, &format!("listener setup failed: {e}")))?;
        let deadline = Deadline::after(cfg.socket.handshake_timeout);
        let mut connected = 0usize;
        while connected < k {
            for id in 0..k {
                if self.conns[id].is_some() {
                    continue;
                }
                if let Some(status) = self.child_status(id) {
                    return Err(spawn_err(
                        id,
                        &format!("worker process exited before handshake ({status})"),
                    ));
                }
            }
            if deadline.expired() {
                let failed = (0..k)
                    .filter(|&id| self.conns[id].is_none())
                    .map(|id| {
                        (
                            id,
                            format!(
                                "no handshake within {:?}",
                                cfg.socket.handshake_timeout
                            ),
                        )
                    })
                    .collect();
                return Err(PoolError { failed });
            }
            match listener.accept() {
                Ok(stream) => {
                    self.take_hello(stream, cfg.socket.handshake_timeout)
                        .map_err(|msg| spawn_err(0, &format!("handshake rejected: {msg}")))?;
                    connected += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => return Err(spawn_err(0, &format!("accept failed: {e}"))),
            }
        }

        // Fan out inits, then gather readys in id order.
        for id in 0..k {
            let frame = init_frame(&blocks[id], spec, cfg, id);
            let res = self.conns[id].as_mut().expect("connected above").send(&frame);
            if let Err(e) = res {
                return Err(spawn_err(id, &format!("init send failed: {e}")));
            }
        }
        for id in 0..k {
            let reply = self.conns[id]
                .as_mut()
                .expect("connected above")
                .recv()
                .map_err(|e| {
                    let extra = self.child_status(id).map(|s| format!(" ({s})"));
                    spawn_err(
                        id,
                        &format!("ready recv failed: {e}{}", extra.unwrap_or_default()),
                    )
                })?;
            if reply.msg_type() != "ready" {
                return Err(spawn_err(
                    id,
                    &format!("expected ready, got {:?}", reply.msg_type()),
                ));
            }
            if id == 0 {
                self.solver_name = reply.opt_str("solver").unwrap_or("").to_string();
            }
        }
        for conn in self.conns.iter().flatten() {
            conn.set_read_timeout(self.round_timeout)
                .map_err(|e| spawn_err(0, &format!("set timeout failed: {e}")))?;
        }
        Ok(())
    }

    /// Read and validate one hello on a freshly-accepted stream, filing
    /// the connection under the worker id it claims.
    fn take_hello(&mut self, stream: Stream, timeout: Duration) -> Result<usize, String> {
        stream
            .set_nonblocking(false)
            .and_then(|()| stream.set_read_timeout(Some(timeout)))
            .map_err(|e| format!("socket setup failed: {e}"))?;
        let mut conn = Conn::new(stream).map_err(|e| format!("socket clone failed: {e}"))?;
        let hello = conn.recv().map_err(|e| format!("hello recv failed: {e}"))?;
        let id = validate_hello(&hello, self.k)?;
        if self.conns[id].is_some() {
            return Err(format!("duplicate hello for worker {id}"));
        }
        self.conns[id] = Some(conn);
        Ok(id)
    }

    /// Exit status of worker `id`'s process, if it has terminated.
    fn child_status(&mut self, id: usize) -> Option<String> {
        let child = self.children.get_mut(id)?.as_mut()?;
        match child.try_wait() {
            Ok(Some(status)) => Some(format!("worker process exited: {status}")),
            _ => None,
        }
    }

    /// Annotate a connection-level failure with the child's exit status
    /// when the process is gone — "connection reset" alone doesn't tell
    /// an operator *why*.
    fn describe_failure(&mut self, id: usize, base: String) -> String {
        match self.child_status(id) {
            Some(status) => format!("{base} ({status})"),
            None => base,
        }
    }

    fn recv_timeout_message(&self) -> String {
        match self.round_timeout {
            Some(t) => format!("no reply within {t:?}"),
            None => "recv interrupted".to_string(),
        }
    }

    /// Copy a validated `result` reply into the worker's slot; protocol
    /// violations (wrong section lengths) are errors, not panics.
    fn copy_result(&mut self, id: usize, reply: &Frame) -> Result<f64, String> {
        let n_k = self.results[id].update.delta_alpha.len();
        let d = self.results[id].update.delta_w.len();
        let da = reply.f64s("da").map_err(|e| e.to_string())?;
        let dw = reply.f64s("dw").map_err(|e| e.to_string())?;
        let cs = reply.f64s("cs").map_err(|e| e.to_string())?;
        let steps = reply.usize_field("steps").map_err(|e| e.to_string())?;
        if da.len() != n_k || dw.len() != d {
            return Err(format!(
                "protocol error: result dims {}×{} do not match shard {n_k}×{d}",
                da.len(),
                dw.len()
            ));
        }
        let slot = &mut self.results[id];
        slot.update.delta_alpha.copy_from_slice(da);
        slot.update.delta_w.copy_from_slice(dw);
        slot.update.steps = steps;
        slot.compute_s = cs.first().copied().unwrap_or(0.0);
        Ok(slot.compute_s)
    }

    /// Kill worker `id`'s process, leaving its connection in place so the
    /// next round observes the dead peer. Test hook for the
    /// failure-injection suite.
    pub fn kill_worker(&mut self, id: usize) {
        if let Some(child) = self.children.get_mut(id).and_then(|c| c.as_mut()) {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Fan a frame out to every live connection **concurrently**: one
    /// scoped sender thread per worker, so the K frame writes overlap on
    /// the wire instead of stacking serially (for a round frame carrying
    /// `w`, the last worker used to wait K−1 full serializations before
    /// its copy even started). Send failures drop the connection and are
    /// reported against the worker.
    ///
    /// Tracing: each worker's `send` span is recorded on *its own* lane
    /// (the spans genuinely overlap in time, which a single lane cannot
    /// represent), and the leader's lane gets one `broadcast` span
    /// covering the whole fan-out.
    fn fan_out(&mut self, frame: &Frame, failed: &mut Vec<(usize, String)>) -> FanOut {
        let t_bcast = self.ring.now();
        // Each sender thread owns exactly one `&mut Conn`; timestamps are
        // read from the shared trace epoch inside the thread so the spans
        // bound the actual serialize+flush work.
        let outcomes: Vec<(usize, u64, u64, Result<f64, String>)> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(self.k);
            for (id, slot) in self.conns.iter_mut().enumerate() {
                if let Some(conn) = slot.as_mut() {
                    handles.push(scope.spawn(move || {
                        let t_send = trace_now_us();
                        let res = conn
                            .send_timed(frame)
                            .map_err(|e| format!("send failed: {e}"));
                        (id, t_send, trace_now_us(), res)
                    }));
                }
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("sender thread panicked"))
                .collect()
        });
        self.ring.complete("broadcast", "wire", t_bcast, None);
        for (id, slot) in self.conns.iter().enumerate() {
            if slot.is_none() {
                failed.push((id, "no connection (worker previously failed)".to_string()));
            }
        }
        let mut pending = Vec::with_capacity(self.k);
        let mut send_s = 0.0f64;
        let mut send_end_us = vec![0u64; self.k];
        for (id, t_send, t_done, res) in outcomes {
            match res {
                Ok(s) => {
                    send_s += s;
                    self.worker_rings[id].span_at(
                        "send",
                        "wire",
                        t_send,
                        t_done,
                        Some(("worker", id as f64)),
                    );
                    send_end_us[id] = t_done;
                    pending.push(id);
                }
                Err(base) => {
                    self.conns[id] = None;
                    let msg = self.describe_failure(id, base);
                    failed.push((id, msg));
                }
            }
        }
        FanOut {
            pending,
            send_s,
            send_end_us,
        }
    }
}

/// Outcome of one concurrent broadcast: which workers took the frame,
/// the summed per-connection send seconds (measured serialize+flush
/// time, which can exceed wall clock now that sends overlap), and each
/// worker's send-span end timestamp on the trace epoch (0 where no send
/// happened) — used to clamp synthesized compute spans past the
/// broadcast on that worker's lane.
struct FanOut {
    pending: Vec<usize>,
    send_s: f64,
    send_end_us: Vec<u64>,
}

fn spawn_err(id: usize, msg: &str) -> PoolError {
    PoolError {
        failed: vec![(id, msg.to_string())],
    }
}

impl Executor for SocketExecutor {
    fn kind(&self) -> &'static str {
        "socket"
    }

    fn solver_name(&self) -> String {
        self.solver_name.clone()
    }

    fn run_round(&mut self, w: &[f64], gamma: f64) -> Result<RoundTiming, PoolError> {
        let round_clock = Stopwatch::started();
        let round = self.round;
        self.round += 1;
        let t_round = self.ring.now();
        let mut failed: Vec<(usize, String)> = Vec::new();
        let frame = Frame::new("round")
            .with_f64s("g", vec![gamma])
            .with_f64s("w", w.to_vec());
        let fan = self.fan_out(&frame, &mut failed);
        let mut wire_s = fan.send_s;
        let mut max_compute = 0.0f64;
        for id in fan.pending {
            let t_recv = self.ring.now();
            let recv = self.conns[id]
                .as_mut()
                .expect("pending ids are live")
                .recv_timed();
            self.ring
                .complete("recv", "wire", t_recv, Some(("worker", id as f64)));
            match recv {
                Err(e) => {
                    let base = if e.is_timeout() {
                        self.recv_timeout_message()
                    } else {
                        format!("recv failed: {e}")
                    };
                    self.conns[id] = None;
                    let msg = self.describe_failure(id, base);
                    failed.push((id, msg));
                }
                Ok((reply, timing)) => {
                    // Only the body transfer is wire time — the prefix
                    // wait is the barrier (the worker still computing).
                    wire_s += timing.body_s;
                    if reply.msg_type() != "result" {
                        self.conns[id] = None;
                        failed.push((
                            id,
                            format!(
                                "protocol error: expected result, got {:?}",
                                reply.msg_type()
                            ),
                        ));
                    } else if let Some(p) = reply.opt_str("panic") {
                        // In-band panic report: the process survives, as a
                        // pooled worker thread would.
                        failed.push((id, p.to_string()));
                    } else {
                        match self.copy_result(id, &reply) {
                            Ok(cs) => {
                                max_compute = max_compute.max(cs);
                                // Render the worker's reported compute on
                                // its own lane, ending where its reply
                                // arrived; clamp past the round start AND
                                // this worker's broadcast send span so the
                                // lane stays well-nested.
                                let end = self.worker_rings[id].now();
                                let dur_us = (cs * 1e6) as u64;
                                let start = end
                                    .saturating_sub(dur_us)
                                    .max(t_round)
                                    .max(fan.send_end_us[id]);
                                self.worker_rings[id].span_at(
                                    "compute",
                                    "worker",
                                    start,
                                    end,
                                    Some(("round", round as f64)),
                                );
                            }
                            Err(msg) => {
                                self.conns[id] = None;
                                failed.push((id, msg));
                            }
                        }
                    }
                }
            }
        }
        if !failed.is_empty() {
            failed.sort_by_key(|f| f.0);
            return Err(PoolError { failed });
        }
        let barrier_s = (round_clock.elapsed_secs() - max_compute).max(0.0);
        Ok(RoundTiming {
            max_compute_s: max_compute,
            barrier_s,
            wire_s,
        })
    }

    fn eval_partials(&mut self, w: &[f64]) -> Result<Vec<CertPartial>, PoolError> {
        let mut failed: Vec<(usize, String)> = Vec::new();
        let frame = Frame::new("eval").with_f64s("w", w.to_vec());
        let fan = self.fan_out(&frame, &mut failed);
        let mut partials = vec![CertPartial::default(); self.k];
        for id in fan.pending {
            let t_recv = self.ring.now();
            let recv = self.conns[id].as_mut().expect("pending ids are live").recv();
            self.ring
                .complete("recv", "wire", t_recv, Some(("worker", id as f64)));
            match recv {
                Err(e) => {
                    let base = if e.is_timeout() {
                        self.recv_timeout_message()
                    } else {
                        format!("recv failed: {e}")
                    };
                    self.conns[id] = None;
                    let msg = self.describe_failure(id, base);
                    failed.push((id, msg));
                }
                Ok(reply) => {
                    if reply.msg_type() != "cert" {
                        self.conns[id] = None;
                        failed.push((
                            id,
                            format!(
                                "protocol error: expected cert, got {:?}",
                                reply.msg_type()
                            ),
                        ));
                    } else if let Some(p) = reply.opt_str("panic") {
                        failed.push((id, p.to_string()));
                    } else {
                        match reply.f64s("cp") {
                            Ok(cp) if cp.len() == 2 => {
                                partials[id] = CertPartial {
                                    loss_sum: cp[0],
                                    conj_sum: cp[1],
                                };
                            }
                            Ok(cp) => {
                                self.conns[id] = None;
                                failed.push((
                                    id,
                                    format!(
                                        "protocol error: cert partial has {} values",
                                        cp.len()
                                    ),
                                ));
                            }
                            Err(e) => {
                                self.conns[id] = None;
                                failed.push((id, e.to_string()));
                            }
                        }
                    }
                }
            }
        }
        if !failed.is_empty() {
            failed.sort_by_key(|f| f.0);
            return Err(PoolError { failed });
        }
        Ok(partials)
    }

    fn result(&self, k: usize) -> &WorkerResult {
        &self.results[k]
    }

    fn load_alpha(&mut self, alpha: &[f64]) {
        for id in 0..self.k {
            let (start, len) = self.parts[id];
            let frame = Frame::new("alpha").with_f64s("a", alpha[start..start + len].to_vec());
            let dead = match self.conns[id].as_mut() {
                None => false,
                Some(conn) => conn.send(&frame).is_err(),
            };
            if dead {
                // Mirror the pool's `let _ = tx.send(...)`: a dead worker
                // is reported at the next round, not here.
                self.conns[id] = None;
            }
        }
    }
}

impl Drop for SocketExecutor {
    fn drop(&mut self) {
        let bye = Frame::new("shutdown");
        for conn in self.conns.iter_mut().flatten() {
            let _ = conn.send(&bye);
        }
        for conn in self.conns.iter_mut() {
            *conn = None; // close the sockets
        }
        let deadline = Deadline::after(Duration::from_secs(2));
        for child in self.children.iter_mut().flatten() {
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if !deadline.expired() => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        if let Some(p) = &self.sock_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

// ---------------------------------------------------------------------
// Worker side: `cocoa worker` entry point
// ---------------------------------------------------------------------

/// Entry point for the `cocoa worker` CLI mode. Returns the process exit
/// code; errors print to stderr. Never panics on malformed input — a bad
/// init or a broken stream is a diagnostic and exit code 1.
pub fn worker_main(args: &Args) -> i32 {
    match run_worker(args) {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("cocoa worker: {msg}");
            1
        }
    }
}

fn run_worker(args: &Args) -> Result<i32, String> {
    let addr = args
        .get_opt("connect")
        .ok_or("missing --connect <address>")?
        .to_string();
    let id = args
        .get_opt("worker")
        .ok_or("missing --worker <id>")?
        .parse::<usize>()
        .map_err(|e| format!("bad --worker: {e}"))?;
    let stream = connect(&addr)?;
    let mut conn = Conn::new(stream).map_err(|e| format!("socket setup failed: {e}"))?;
    conn.send(&hello_frame(id))
        .map_err(|e| format!("hello send failed: {e}"))?;
    let init = conn.recv().map_err(|e| format!("init recv failed: {e}"))?;
    let (worker, spec, d) = build_worker(&init, id)?;
    let ready = Frame::new("ready")
        .set_num("worker", id as f64)
        .set_str("solver", &worker.solver.name());
    conn.send(&ready)
        .map_err(|e| format!("ready send failed: {e}"))?;
    serve(&mut conn, worker, spec, d)
}

/// Integral field out of a solver JSON object, rejecting hostile values.
fn obj_usize(obj: &Json, key: &str) -> Result<usize, String> {
    let v = obj
        .get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| format!("init solver field {key:?} missing or not a number"))?;
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 {
        return Err(format!("init solver field {key:?} invalid: {v}"));
    }
    Ok(v as usize)
}

/// Decode and validate an init frame into a ready-to-run [`Worker`].
/// Every length and index is checked before any allocation-by-trust:
/// a malformed CSR from a confused (or hostile) leader is an error,
/// never an out-of-bounds panic later in the solve.
fn build_worker(
    init: &Frame,
    claimed_id: usize,
) -> Result<(Worker, SubproblemSpec, usize), String> {
    let err = |e: WireError| e.to_string();
    if init.msg_type() != "init" {
        return Err(format!("expected init, got {:?}", init.msg_type()));
    }
    let id = init.usize_field("id").map_err(err)?;
    if id != claimed_id {
        return Err(format!("init addressed to worker {id}, this is {claimed_id}"));
    }
    let k = init.usize_field("k").map_err(err)?;
    let n = init.usize_field("n").map_err(err)?;
    let d = init.usize_field("d").map_err(err)?;
    let n_local = init.usize_field("n_local").map_err(err)?;
    let par = init.f64s("par").map_err(err)?;
    if par.len() != 5 {
        return Err(format!("init params have {} slots, expected 5", par.len()));
    }
    let (lambda, sigma_prime, mu, epochs_f, beta) = (par[0], par[1], par[2], par[3], par[4]);

    let loss = match init.str_field("loss").map_err(err)? {
        "hinge" => Loss::Hinge,
        "smoothed_hinge" => Loss::SmoothedHinge { mu },
        "logistic" => Loss::Logistic,
        "squared" => Loss::Squared,
        "absolute" => Loss::Absolute,
        other => return Err(format!("unknown loss {other:?}")),
    };

    let solver_obj = init.get("solver").ok_or("init missing solver object")?;
    let spec_solver = match solver_obj.get("kind").and_then(|j| j.as_str()) {
        Some("sdca") => SolverSpec::Sdca {
            h: obj_usize(solver_obj, "h")?,
        },
        Some("sdca_epochs") => SolverSpec::SdcaEpochs { epochs: epochs_f },
        Some("cyclic") => SolverSpec::Cyclic {
            epochs: obj_usize(solver_obj, "epochs")?,
            shuffle: solver_obj
                .get("shuffle")
                .and_then(|j| j.as_bool())
                .ok_or("init solver field \"shuffle\" missing")?,
        },
        Some("jacobi") => SolverSpec::Jacobi {
            sweeps: obj_usize(solver_obj, "sweeps")?,
            beta,
        },
        other => return Err(format!("unknown solver kind {other:?}")),
    };

    let y = init.f64s("y").map_err(err)?;
    let nr = init.f64s("nr").map_err(err)?;
    let values = init.f64s("v").map_err(err)?;
    let ip = init.u64s("ip").map_err(err)?;
    let ix = init.u64s("ix").map_err(err)?;
    let seed = *init
        .u64s("seed")
        .map_err(err)?
        .first()
        .ok_or("init seed section empty")?;

    if y.len() != n_local || nr.len() != n_local {
        return Err(format!(
            "init shard dims inconsistent: n_local={n_local}, y={}, norms={}",
            y.len(),
            nr.len()
        ));
    }
    if n_local > n {
        return Err(format!("init n_local={n_local} exceeds n={n}"));
    }
    if ip.len() != n_local + 1 {
        return Err(format!(
            "init indptr has {} entries, expected {}",
            ip.len(),
            n_local + 1
        ));
    }
    if ip.first() != Some(&0) {
        return Err("init indptr does not start at 0".to_string());
    }
    if ip.windows(2).any(|pair| pair[0] > pair[1]) {
        return Err("init indptr is not monotone".to_string());
    }
    let nnz = usize::try_from(*ip.last().unwrap())
        .map_err(|_| "init CSR nnz overflows".to_string())?;
    if nnz != values.len() || nnz != ix.len() {
        return Err(format!(
            "init CSR nnz mismatch: indptr says {nnz}, values={}, indices={}",
            values.len(),
            ix.len()
        ));
    }
    if d > u32::MAX as usize {
        return Err(format!("init d={d} exceeds index width"));
    }
    if ix.iter().any(|&c| c >= d as u64) {
        return Err(format!("init column index out of range for d={d}"));
    }

    let x = CsrMatrix {
        rows: n_local,
        cols: d,
        indptr: ip.iter().map(|&p| p as usize).collect(),
        indices: ix.iter().map(|&c| c as u32).collect(),
        values: values.to_vec(),
    };
    // Construct the dataset literally: the shipped row norms are the
    // leader's cached values, and recomputing them could differ in the
    // last bit and break the cross-executor determinism invariant.
    let ds = Dataset {
        x,
        y: y.to_vec(),
        row_norms_sq: nr.to_vec(),
        name: format!("wire-shard-{id}"),
    };
    let block = LocalBlock::view(Arc::new(ds), 0, n_local);
    let solver = make_solver(&spec_solver, n_local, seed);
    let spec = SubproblemSpec {
        loss,
        lambda,
        n_global: n,
        sigma_prime,
        k,
    };
    Ok((Worker::new(id, block, solver), spec, d))
}

/// Serve round/eval/alpha requests until the leader shuts down or the
/// connection closes. A solver panic is caught and reported in-band; the
/// process keeps serving, like a pooled worker thread would.
fn serve(
    conn: &mut Conn,
    mut worker: Worker,
    spec: SubproblemSpec,
    d: usize,
) -> Result<i32, String> {
    let id = worker.id;
    let mut scratch = WorkerResult::with_dims(id, worker.block.n_local(), d);
    loop {
        let frame = match conn.recv() {
            Ok(f) => f,
            Err(WireError::Closed) => return Ok(0), // leader gone — clean exit
            Err(e) => return Err(format!("recv failed: {e}")),
        };
        match frame.msg_type() {
            "round" => {
                let gamma = *frame
                    .f64s("g")
                    .map_err(|e| e.to_string())?
                    .first()
                    .ok_or("round frame has empty gamma section")?;
                let w = frame.f64s("w").map_err(|e| e.to_string())?;
                if w.len() != d {
                    return Err(format!("round w has {} entries, expected {d}", w.len()));
                }
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    worker.round_into(w, &spec, &mut scratch);
                    // Line 5 of Algorithm 1: the worker owns its α_[k].
                    worker.apply(gamma, &scratch.update.delta_alpha);
                }));
                let mut reply = Frame::new("result")
                    .set_num("id", id as f64)
                    .set_num("steps", scratch.update.steps as f64);
                if let Err(payload) = outcome {
                    reply = reply.set_str("panic", &panic_message(payload.as_ref()));
                }
                reply = reply
                    .with_f64s("da", scratch.update.delta_alpha.clone())
                    .with_f64s("dw", scratch.update.delta_w.clone())
                    .with_f64s("cs", vec![scratch.compute_s]);
                conn.send(&reply)
                    .map_err(|e| format!("result send failed: {e}"))?;
            }
            "eval" => {
                let w = frame.f64s("w").map_err(|e| e.to_string())?;
                if w.len() != d {
                    return Err(format!("eval w has {} entries, expected {d}", w.len()));
                }
                let outcome = catch_unwind(AssertUnwindSafe(|| worker.eval_partial(&spec, w)));
                let reply = match outcome {
                    Ok(p) => Frame::new("cert")
                        .set_num("id", id as f64)
                        .with_f64s("cp", vec![p.loss_sum, p.conj_sum]),
                    Err(payload) => Frame::new("cert")
                        .set_num("id", id as f64)
                        .set_str("panic", &panic_message(payload.as_ref()))
                        .with_f64s("cp", vec![0.0, 0.0]),
                };
                conn.send(&reply)
                    .map_err(|e| format!("cert send failed: {e}"))?;
            }
            "alpha" => {
                let a = frame.f64s("a").map_err(|e| e.to_string())?;
                if a.len() != worker.alpha_local.len() {
                    return Err(format!(
                        "alpha load has {} entries, expected {}",
                        a.len(),
                        worker.alpha_local.len()
                    ));
                }
                worker.alpha_local.copy_from_slice(a);
            }
            "shutdown" => return Ok(0),
            other => return Err(format!("unexpected message type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_hello_accepts_good_hello() {
        assert_eq!(validate_hello(&hello_frame(2), 4).unwrap(), 2);
    }

    #[test]
    fn validate_hello_rejects_bad_magic() {
        let f = Frame::new("hello")
            .set_str("magic", "not-cocoa")
            .set_num("version", WIRE_VERSION)
            .set_num("worker", 0.0);
        assert!(validate_hello(&f, 4).unwrap_err().contains("magic"));
    }

    #[test]
    fn validate_hello_rejects_version_mismatch() {
        let f = Frame::new("hello")
            .set_str("magic", WIRE_MAGIC)
            .set_num("version", 99.0)
            .set_num("worker", 0.0);
        assert!(validate_hello(&f, 4).unwrap_err().contains("version"));
    }

    #[test]
    fn validate_hello_rejects_out_of_range_and_hostile_ids() {
        assert!(validate_hello(&hello_frame(4), 4).unwrap_err().contains("range"));
        let f = Frame::new("hello")
            .set_str("magic", WIRE_MAGIC)
            .set_num("version", WIRE_VERSION)
            .set_num("worker", -1.0);
        assert!(validate_hello(&f, 4).is_err());
    }

    #[test]
    fn validate_hello_rejects_wrong_message_type() {
        let f = Frame::new("round");
        assert!(validate_hello(&f, 4).unwrap_err().contains("hello"));
    }

    #[test]
    fn build_worker_rejects_non_monotone_indptr() {
        let mut init = base_init();
        init = replace_u64s(init, "ip", vec![0, 3, 2]);
        assert!(build_worker(&init, 0).unwrap_err().contains("monotone"));
    }

    #[test]
    fn build_worker_rejects_out_of_range_column() {
        let mut init = base_init();
        init = replace_u64s(init, "ix", vec![0, 1, 99]);
        assert!(build_worker(&init, 0).unwrap_err().contains("column index"));
    }

    #[test]
    fn build_worker_accepts_well_formed_init() {
        let (worker, spec, d) = build_worker(&base_init(), 0).expect("good init");
        assert_eq!(worker.id, 0);
        assert_eq!(worker.block.n_local(), 2);
        assert_eq!(d, 3);
        assert_eq!(spec.k, 2);
        assert_eq!(spec.loss, Loss::Hinge);
    }

    /// A tiny well-formed init for worker 0: n_local=2, d=3, nnz=3.
    fn base_init() -> Frame {
        let mut solver = Json::obj();
        solver.set("kind", jstr("sdca"));
        solver.set("h", jnum(1.0));
        Frame::new("init")
            .set_num("id", 0.0)
            .set_num("k", 2.0)
            .set_num("n", 4.0)
            .set_num("d", 3.0)
            .set_num("n_local", 2.0)
            .set_str("loss", "hinge")
            .set_json("solver", solver)
            .with_f64s("par", vec![0.01, 2.0, 0.0, 0.0, 0.0])
            .with_f64s("y", vec![1.0, -1.0])
            .with_f64s("nr", vec![1.25, 0.5])
            .with_f64s("v", vec![1.0, 0.5, -0.5])
            .with_u64s("ip", vec![0, 2, 3])
            .with_u64s("ix", vec![0, 2, 1])
            .with_u64s("seed", vec![42])
    }

    /// Rebuild `frame` with one u64 section swapped out (Frames are
    /// append-only by design; tests rebuild through the wire instead).
    fn replace_u64s(frame: Frame, name: &str, v: Vec<u64>) -> Frame {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &frame).unwrap();
        let decoded = wire::read_frame(&mut buf.as_slice()).unwrap();
        // Re-encode every section except the replaced one.
        let mut out = Frame::new("init");
        out = copy_headers(&decoded, out);
        for sec in ["y", "nr", "v"] {
            out = out.with_f64s(sec, decoded.f64s(sec).unwrap().to_vec());
        }
        out = out.with_f64s("par", decoded.f64s("par").unwrap().to_vec());
        for sec in ["ip", "ix", "seed"] {
            if sec == name {
                out = out.with_u64s(sec, v.clone());
            } else {
                out = out.with_u64s(sec, decoded.u64s(sec).unwrap().to_vec());
            }
        }
        out
    }

    fn copy_headers(from: &Frame, mut to: Frame) -> Frame {
        for key in ["id", "k", "n", "d", "n_local"] {
            to = to.set_num(key, from.num(key).unwrap());
        }
        to = to.set_str("loss", from.str_field("loss").unwrap());
        to.set_json("solver", from.get("solver").unwrap().clone())
    }
}
