//! Trainer checkpointing: snapshot (α, w, round counters) to JSON and
//! resume later — production necessity for long distributed runs, and a
//! natural fit for the dual formulation (α is the *complete* optimizer
//! state; w is recomputable but stored for cheap integrity checking).

use crate::coordinator::Trainer;
use crate::util::json::{jarr, jnum, jobj, jstr, Json};
use std::borrow::Cow;
use std::path::Path;

#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Parse(String),
    Incompatible(String),
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "io error: {e}"),
            CheckpointError::Parse(msg) => write!(f, "parse error: {msg}"),
            CheckpointError::Incompatible(msg) => write!(f, "checkpoint incompatible: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> CheckpointError {
        CheckpointError::Io(e)
    }
}

/// Serializable snapshot of the optimizer state. `alpha` is stored in the
/// *caller's original row order* (mapped out of the trainer's internal
/// permuted-contiguous layout), so a checkpoint is valid across trainers
/// regardless of how their partitions permuted the shared dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub lambda: f64,
    pub loss: String,
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
}

/// A borrowed view of checkpointable trainer state — what
/// [`Checkpoint::capture`] used to clone eagerly. Serialization runs off
/// this view, so *saving* a trainer's state copies nothing: `w` is always
/// borrowed, and `alpha` is borrowed whenever the shard layout kept the
/// caller's row order (contiguous partitions). Only a permuted layout
/// forces the one gather back into caller order (`Cow::Owned`), because
/// the on-disk format stores α layout-independently.
pub struct CheckpointView<'a> {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub lambda: f64,
    pub loss: &'a str,
    pub alpha: Cow<'a, [f64]>,
    pub w: &'a [f64],
}

impl<'a> CheckpointView<'a> {
    pub fn capture(trainer: &'a Trainer) -> CheckpointView<'a> {
        let alpha = if trainer.rows.is_identity() {
            Cow::Borrowed(trainer.alpha.as_slice())
        } else {
            Cow::Owned(trainer.alpha_original())
        };
        CheckpointView {
            n: trainer.problem.n(),
            d: trainer.problem.d(),
            k: trainer.cfg.k,
            lambda: trainer.cfg.lambda,
            loss: trainer.cfg.loss.name(),
            alpha,
            w: &trainer.w,
        }
    }

    /// The one checkpoint serializer: [`Checkpoint::to_json`] routes its
    /// owned buffers through here, so the two capture paths cannot drift.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("version", jnum(1.0)),
            ("n", jnum(self.n as f64)),
            ("d", jnum(self.d as f64)),
            ("k", jnum(self.k as f64)),
            ("lambda", jnum(self.lambda)),
            ("loss", jstr(self.loss)),
            ("alpha", jarr(self.alpha.iter().map(|&v| jnum(v)).collect())),
            ("w", jarr(self.w.iter().map(|&v| jnum(v)).collect())),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    /// Materialize an owned [`Checkpoint`] (the restore-path object).
    pub fn to_checkpoint(&self) -> Checkpoint {
        Checkpoint {
            n: self.n,
            d: self.d,
            k: self.k,
            lambda: self.lambda,
            loss: self.loss.to_string(),
            alpha: self.alpha.to_vec(),
            w: self.w.to_vec(),
        }
    }
}

impl Checkpoint {
    pub fn capture(trainer: &Trainer) -> Checkpoint {
        CheckpointView::capture(trainer).to_checkpoint()
    }

    fn view(&self) -> CheckpointView<'_> {
        CheckpointView {
            n: self.n,
            d: self.d,
            k: self.k,
            lambda: self.lambda,
            loss: &self.loss,
            alpha: Cow::Borrowed(&self.alpha),
            w: &self.w,
        }
    }

    pub fn to_json(&self) -> Json {
        self.view().to_json()
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint, CheckpointError> {
        let num = |k: &str| -> Result<f64, CheckpointError> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| CheckpointError::Parse(format!("missing {k}")))
        };
        // A checkpoint written by a future incompatible format must be
        // rejected here, not misread: enforce the version tag up front.
        let version = num("version")
            .map_err(|_| CheckpointError::Parse("missing checkpoint version".into()))?;
        if version != 1.0 {
            return Err(CheckpointError::Parse(format!(
                "unsupported checkpoint version {version} (this build reads version 1)"
            )));
        }
        // Dimension fields index into buffers, so a NaN, negative, or
        // fractional value must not survive the `as usize` cast (which
        // would silently saturate or truncate).
        let dim = |k: &str| -> Result<usize, CheckpointError> {
            let v = num(k)?;
            if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > (1u64 << 53) as f64 {
                return Err(CheckpointError::Parse(format!(
                    "field {k} is not a valid dimension: {v}"
                )));
            }
            Ok(v as usize)
        };
        let vecf = |k: &str| -> Result<Vec<f64>, CheckpointError> {
            j.get(k)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| CheckpointError::Parse(format!("missing {k}")))?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| CheckpointError::Parse(format!("bad value in {k}")))
                })
                .collect()
        };
        Ok(Checkpoint {
            n: dim("n")?,
            d: dim("d")?,
            k: dim("k")?,
            lambda: num("lambda")?,
            loss: j
                .get("loss")
                .and_then(|v| v.as_str())
                .ok_or_else(|| CheckpointError::Parse("missing loss".into()))?
                .to_string(),
            alpha: vecf("alpha")?,
            w: vecf("w")?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        self.view().save(path)
    }

    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(CheckpointError::Parse)?;
        Checkpoint::from_json(&j)
    }

    /// Restore the state into a freshly constructed trainer (same problem
    /// and partition). Verifies dimensions, loss, λ, and the w↔α
    /// consistency invariant before accepting.
    pub fn restore(&self, trainer: &mut Trainer) -> Result<(), CheckpointError> {
        if trainer.problem.n() != self.n || trainer.problem.d() != self.d {
            return Err(CheckpointError::Incompatible(format!(
                "problem is {}×{}, checkpoint is {}×{}",
                trainer.problem.n(),
                trainer.problem.d(),
                self.n,
                self.d
            )));
        }
        if trainer.cfg.loss.name() != self.loss {
            return Err(CheckpointError::Incompatible(format!(
                "loss {} vs checkpoint {}",
                trainer.cfg.loss.name(),
                self.loss
            )));
        }
        if (trainer.cfg.lambda - self.lambda).abs() > 1e-15 {
            return Err(CheckpointError::Incompatible(format!(
                "λ {} vs checkpoint {}",
                trainer.cfg.lambda, self.lambda
            )));
        }
        // The header dims can agree while the vectors themselves were
        // truncated (a partial write, a hand-edited file): check the
        // actual lengths before any copy touches trainer state, so a bad
        // checkpoint leaves the trainer exactly as it was.
        if self.alpha.len() != self.n {
            return Err(CheckpointError::Incompatible(format!(
                "alpha has {} entries, header says n={}",
                self.alpha.len(),
                self.n
            )));
        }
        if self.w.len() != self.d {
            return Err(CheckpointError::Incompatible(format!(
                "w has {} entries, header says d={}",
                self.w.len(),
                self.d
            )));
        }
        // NaN poisons the drift check below (f64::max ignores NaN, so a
        // NaN α would *pass* it) — reject non-finite state explicitly.
        if self.alpha.iter().chain(self.w.iter()).any(|v| !v.is_finite()) {
            return Err(CheckpointError::Incompatible(
                "checkpoint contains non-finite values".into(),
            ));
        }
        // gather the caller-order α into the trainer's layout order, then
        // scatter into per-worker local views (runtime-agnostic: the
        // executor routes it to pool threads or in-process workers)
        let layout_alpha = trainer.rows.to_permuted(&self.alpha);
        trainer.alpha.copy_from_slice(&layout_alpha);
        trainer.w.copy_from_slice(&self.w);
        trainer.sync_workers_from_alpha();
        let drift = trainer.primal_consistency_error();
        if drift > 1e-6 {
            return Err(CheckpointError::Incompatible(format!(
                "w inconsistent with α (drift {drift:.3e}) — corrupt checkpoint?"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CocoaConfig, SolverSpec};
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;
    use crate::objective::Problem;

    fn trainer() -> Trainer {
        let data = generate(&SynthConfig::new("ck", 80, 8).seed(1));
        let part = random_balanced(80, 4, 2);
        let problem = Problem::new(data, Loss::Hinge, 1e-2);
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(50)
        .with_parallel(false);
        Trainer::new(problem, part, cfg)
    }

    #[test]
    fn roundtrip_resume_produces_same_trajectory() {
        // Train 5 rounds, checkpoint, train 5 more → must equal a fresh
        // trainer restored from the checkpoint and trained 5 rounds
        // (solver RNG state is part of neither — we reseed per restore in
        // this test by comparing dual values, not exact trajectories).
        let mut a = trainer();
        for _ in 0..5 {
            a.round();
        }
        let ck = Checkpoint::capture(&a);
        let path = std::env::temp_dir().join("cocoa_ck_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);

        let mut b = trainer();
        loaded.restore(&mut b).unwrap();
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.w, b.w);
        // Both continue (solver RNG streams differ — checkpoints restore
        // optimizer state, not RNG state) and converge to the same optimum.
        for _ in 0..25 {
            a.round();
            b.round();
        }
        let ga = a.problem.certificates(&a.alpha, &a.w).gap;
        let gb = b.problem.certificates(&b.alpha, &b.w).gap;
        assert!(ga < 2e-2, "original did not converge: gap {ga}");
        assert!(gb < 2e-2, "resumed did not converge: gap {gb}");
        let da = a.problem.dual_value(&a.alpha, &a.w);
        let db = b.problem.dual_value(&b.alpha, &b.w);
        assert!((da - db).abs() < 5e-3, "{da} vs {db}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn view_serialization_is_byte_identical_to_owned_capture() {
        // The zero-copy view and the owned capture must write the same
        // bytes — same JSON text and same file contents — or a resumed
        // run could depend on which capture path produced its checkpoint.
        let mut t = trainer();
        for _ in 0..3 {
            t.round();
        }
        let owned = Checkpoint::capture(&t);
        let view = CheckpointView::capture(&t);
        assert_eq!(
            view.to_json().to_string_compact(),
            owned.to_json().to_string_compact()
        );
        let p_owned = std::env::temp_dir().join("cocoa_ck_owned.json");
        let p_view = std::env::temp_dir().join("cocoa_ck_view.json");
        owned.save(&p_owned).unwrap();
        view.save(&p_view).unwrap();
        assert_eq!(
            std::fs::read(&p_owned).unwrap(),
            std::fs::read(&p_view).unwrap(),
            "view save differs from owned save on disk"
        );
        // and the view round-trips into an equal owned checkpoint
        assert_eq!(view.to_checkpoint(), owned);
        std::fs::remove_file(&p_owned).ok();
        std::fs::remove_file(&p_view).ok();
    }

    #[test]
    fn view_borrows_alpha_when_layout_keeps_caller_order() {
        // Contiguous partitions keep the identity row permutation, so the
        // view must not gather (Cow::Borrowed); a random partition
        // permutes rows and needs the one gather back (Cow::Owned).
        let data = generate(&SynthConfig::new("ck", 80, 8).seed(1));
        let part = crate::data::partition::contiguous(80, 4);
        let problem = Problem::new(data, Loss::Hinge, 1e-2);
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(50)
        .with_parallel(false);
        let t = Trainer::new(problem, part, cfg);
        assert!(matches!(
            CheckpointView::capture(&t).alpha,
            std::borrow::Cow::Borrowed(_)
        ));

        let t2 = trainer(); // random_balanced → permuted layout
        assert!(matches!(
            CheckpointView::capture(&t2).alpha,
            std::borrow::Cow::Owned(_)
        ));
    }

    #[test]
    fn restore_reaches_pooled_worker_state() {
        // Capture a mid-training checkpoint, restore it into a fresh
        // pooled trainer and a fresh sequential trainer, and train both:
        // bit-identical trajectories prove the α scatter actually reached
        // the pool's worker threads (stale α_[k] would change the solves).
        let mut src = trainer();
        for _ in 0..4 {
            src.round();
        }
        let ck = Checkpoint::capture(&src);

        let pooled_cfg = |parallel: bool| {
            let data = generate(&SynthConfig::new("ck", 80, 8).seed(1));
            let part = random_balanced(80, 4, 2);
            let problem = Problem::new(data, Loss::Hinge, 1e-2);
            let cfg = CocoaConfig::cocoa_plus(
                4,
                Loss::Hinge,
                1e-2,
                SolverSpec::SdcaEpochs { epochs: 1.0 },
            )
            .with_rounds(50)
            .with_parallel(parallel);
            Trainer::new(problem, part, cfg)
        };
        let mut a = pooled_cfg(true);
        let mut b = pooled_cfg(false);
        assert_eq!(a.executor_kind(), "pooled");
        assert_eq!(b.executor_kind(), "sequential");
        ck.restore(&mut a).unwrap();
        ck.restore(&mut b).unwrap();
        for _ in 0..3 {
            a.round();
            b.round();
        }
        assert_eq!(a.alpha, b.alpha, "pooled restore diverged from sequential");
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn incompatible_checkpoints_rejected() {
        let a = trainer();
        let mut ck = Checkpoint::capture(&a);
        ck.lambda = 0.5;
        let mut b = trainer();
        assert!(matches!(
            ck.restore(&mut b),
            Err(CheckpointError::Incompatible(_))
        ));
        let mut ck2 = Checkpoint::capture(&a);
        ck2.loss = "squared".into();
        assert!(ck2.restore(&mut b).is_err());
    }

    #[test]
    fn truncated_vectors_rejected_before_touching_trainer() {
        // A checkpoint whose header dims match the problem but whose
        // vectors were truncated (partial write) must fail up front and
        // leave the trainer state untouched.
        let a = trainer();
        let mut short_alpha = Checkpoint::capture(&a);
        short_alpha.alpha.truncate(short_alpha.n - 3);
        let mut short_w = Checkpoint::capture(&a);
        short_w.w.pop();

        let mut b = trainer();
        let alpha_before = b.alpha.clone();
        let w_before = b.w.clone();
        for ck in [&short_alpha, &short_w] {
            match ck.restore(&mut b) {
                Err(CheckpointError::Incompatible(msg)) => {
                    assert!(msg.contains("entries"), "unexpected message: {msg}")
                }
                other => panic!("expected Incompatible, got {other:?}"),
            }
        }
        assert_eq!(b.alpha, alpha_before, "failed restore mutated alpha");
        assert_eq!(b.w, w_before, "failed restore mutated w");
    }

    #[test]
    fn non_finite_state_rejected() {
        // f64::max ignores NaN, so without an explicit check a NaN α
        // would sail through the drift invariant.
        let a = trainer();
        let mut ck = Checkpoint::capture(&a);
        ck.alpha[1] = f64::NAN;
        let mut b = trainer();
        let err = ck.restore(&mut b).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let mut ck2 = Checkpoint::capture(&a);
        ck2.w[0] = f64::INFINITY;
        assert!(ck2.restore(&mut b).is_err());
    }

    #[test]
    fn version_enforced_on_parse() {
        let a = trainer();
        let good = Checkpoint::capture(&a).to_json();
        assert!(Checkpoint::from_json(&good).is_ok());

        let mut missing = good.clone();
        missing.set("version", Json::Null);
        match Checkpoint::from_json(&missing) {
            Err(CheckpointError::Parse(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("expected Parse, got {other:?}"),
        }

        let mut future = good.clone();
        future.set("version", jnum(2.0));
        match Checkpoint::from_json(&future) {
            Err(CheckpointError::Parse(msg)) => {
                assert!(msg.contains("unsupported"), "{msg}")
            }
            other => panic!("expected Parse, got {other:?}"),
        }
    }

    #[test]
    fn hostile_dimension_fields_rejected() {
        let a = trainer();
        let good = Checkpoint::capture(&a).to_json();
        for (field, bad) in [
            ("n", f64::NAN),
            ("n", -1.0),
            ("d", 2.5),
            ("k", f64::INFINITY),
            ("k", -0.5),
        ] {
            let mut j = good.clone();
            j.set(field, jnum(bad));
            match Checkpoint::from_json(&j) {
                Err(CheckpointError::Parse(msg)) => {
                    assert!(msg.contains(field), "message does not name {field}: {msg}")
                }
                other => panic!("{field}={bad} should be Parse error, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupted_w_rejected_by_invariant() {
        let mut a = trainer();
        for _ in 0..3 {
            a.round();
        }
        let mut ck = Checkpoint::capture(&a);
        ck.w[0] += 1.0; // corrupt
        let mut b = trainer();
        let err = ck.restore(&mut b).unwrap_err();
        assert!(err.to_string().contains("inconsistent"));
    }
}
