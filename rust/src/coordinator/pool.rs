//! Persistent worker-pool runtime for the CoCoA+ trainer.
//!
//! The paper's headline result (Corollaries 9/11) makes the *per-round*
//! overhead of the simulated cluster the quantity that gates wall-clock
//! scaling in K: CoCoA+'s outer-round count is K-independent, so anything
//! the runtime adds per round is pure loss. The original implementation
//! spawned K fresh OS threads per outer round; this module replaces that
//! with K long-lived worker threads spawned once at [`crate::coordinator::Trainer::new`]:
//!
//! * each thread owns its [`Worker`] (data block, α_[k], solver state);
//! * the leader broadcasts the round's `w` snapshot through a shared
//!   [`RwLock`] buffer (written only between rounds, read only during
//!   them — never contended) and kicks workers over bounded per-worker
//!   job channels;
//! * every worker fills a reusable [`WorkerResult`] scratch (allocated
//!   once at startup, ping-ponged leader↔worker each round) so the
//!   steady-state round loop performs **zero thread spawns and zero
//!   result allocations**;
//! * gather happens on one bounded reply channel; the leader applies the
//!   reduce in worker-id order, so pooled and sequential execution are
//!   bit-identical (see `rust/tests/determinism.rs`).
//!
//! A worker panic is caught on the worker thread and surfaced to the
//! leader as a [`PoolError`] naming the failed worker(s) — a failed round
//! is an error, never a hang, and the pool stays usable. Dropping the
//! executor closes the job channels and joins all threads.
//!
//! ### The `Eval` message — distributed duality-gap certificates
//!
//! Besides `Round`, the per-worker job channel carries an `Eval` message:
//! each worker computes its shard's [`CertPartial`] (partial primal-loss
//! sum and partial dual-conjugate sum, over its own zero-copy view and
//! its own α_[k]; the local margins feeding the loss sum are consumed on
//! the fly, never shipped) in parallel, and the leader reduces the K
//! partials plus the ‖w‖² term into
//! [`Certificates`](crate::objective::Certificates). What used to be a
//! serial O(nnz) leader pass at every certificate round is now gated by
//! the largest shard. Partials are combined in worker-id order and the
//! sequential executor runs the identical partial/combine code path, so
//! pooled and sequential gap trajectories remain bit-identical
//! (`rust/tests/determinism.rs`).
//!
//! ### Three executors, one contract
//!
//! [`Executor`] now has three implementations, selected by
//! [`ExecutorChoice`](crate::coordinator::ExecutorChoice):
//!
//! * [`PooledExecutor`] (this module) — K persistent threads, the default
//!   for K > 1;
//! * [`SequentialExecutor`] (this module) — in-process, one worker after
//!   another (`cfg.parallel = false`, K = 1, or non-`Send` local solvers
//!   like the PJRT-backed one);
//! * [`SocketExecutor`](crate::coordinator::socket::SocketExecutor) — K
//!   worker *processes* over Unix domain sockets or TCP, speaking the
//!   length-prefixed [`wire`](crate::coordinator::wire) format.
//!
//! All three honour the same contract: id-ordered gather, failed rounds
//! surface as [`PoolError`] naming workers (never a hang), and the leader
//! can keep driving rounds after a failure. Every caller is
//! runtime-agnostic and results stay bit-comparable across runtimes
//! (`rust/tests/determinism.rs`).

use crate::coordinator::worker::{Worker, WorkerResult};
use crate::objective::CertPartial;
use crate::subproblem::SubproblemSpec;
use crate::telemetry::{Recorder, Ring};
use crate::util::timer::Stopwatch;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SendError, SyncSender};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

/// One or more workers failed a round (panicked solver, dead thread).
#[derive(Clone, Debug)]
pub struct PoolError {
    /// (worker id, failure description), sorted by worker id.
    pub failed: Vec<(usize, String)>,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} worker(s) failed the round:", self.failed.len())?;
        for (id, msg) in &self.failed {
            write!(f, " [worker {id}: {msg}]")?;
        }
        Ok(())
    }
}

impl std::error::Error for PoolError {}

/// Measured timing of one fan-out/gather cycle, split so the simulated
/// cluster model sees pure compute and the runtime's own synchronization
/// cost is accounted separately (in `CommStats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundTiming {
    /// Max measured per-worker solve seconds — what gates a synchronous
    /// cluster round.
    pub max_compute_s: f64,
    /// Fan-out/gather wall seconds beyond the workers' own compute (for
    /// the pool: scheduling + channel + barrier overhead; thread-spawn
    /// cost would land here, and since spawning happens once at startup,
    /// it no longer distorts any per-round measurement).
    pub barrier_s: f64,
    /// Measured leader-side wire seconds (frame sends + reply body
    /// reads) for the round. Zero for the in-process executors — only
    /// the socket runtime moves bytes.
    pub wire_s: f64,
}

/// Executes the fan-out/local-solve/gather of one outer round over K
/// workers. Implementations own the workers.
pub trait Executor: Send {
    /// `"pooled"`, `"sequential"`, or `"socket"` — for labels and tests.
    fn kind(&self) -> &'static str;

    /// Worker 0's solver name (run labels).
    fn solver_name(&self) -> String;

    /// Run one round: broadcast `w`, let every worker solve its local
    /// subproblem and apply γ·Δα_[k] to its own dual state, gather the
    /// results. After `Ok`, `result(k)` holds worker k's update.
    fn run_round(&mut self, w: &[f64], gamma: f64) -> Result<RoundTiming, PoolError>;

    /// Distributed certificate evaluation: broadcast `w`, let every
    /// worker compute its shard's [`CertPartial`] against its own α_[k],
    /// and gather the K partials **in worker-id order** (so the leader's
    /// reduce is bit-reproducible across runtimes).
    fn eval_partials(&mut self, w: &[f64]) -> Result<Vec<CertPartial>, PoolError>;

    /// Worker k's result from the last successful round.
    fn result(&self, k: usize) -> &WorkerResult;

    /// Overwrite every worker's α_[k] view from the global α
    /// (checkpoint restore).
    fn load_alpha(&mut self, alpha: &[f64]);
}

/// Build the executor a config asks for. K = 1 always degenerates to the
/// sequential in-process path — a pool of one thread would add barrier
/// cost for nothing.
pub fn make_executor(
    workers: Vec<Worker>,
    spec: SubproblemSpec,
    parallel: bool,
    recorder: Recorder,
) -> Box<dyn Executor> {
    if parallel && workers.len() > 1 {
        Box::new(PooledExecutor::spawn(workers, spec, recorder))
    } else {
        Box::new(SequentialExecutor::new(workers, spec, recorder))
    }
}

/// Extract a human-readable message from a caught panic payload. Shared
/// with the socket executor's worker process (`coordinator::socket`).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".to_string())
}

// ---------------------------------------------------------------------
// Sequential executor
// ---------------------------------------------------------------------

/// In-process executor: runs the K local solves one after another on the
/// leader thread. Required for non-`Send`-friendly setups and exact
/// apples-to-apples comparisons; also what K = 1 degenerates to.
pub struct SequentialExecutor {
    workers: Vec<Worker>,
    results: Vec<WorkerResult>,
    spec: SubproblemSpec,
    /// One trace lane per worker (tid 1+k); the leader thread records
    /// each serial solve on the lane of the worker it stands in for.
    rings: Vec<Ring>,
    round: u64,
}

impl SequentialExecutor {
    pub fn new(workers: Vec<Worker>, spec: SubproblemSpec, recorder: Recorder) -> SequentialExecutor {
        let results = workers
            .iter()
            .map(|wk| WorkerResult::with_dims(wk.id, wk.block.n_local(), wk.block.d()))
            .collect();
        let rings = workers
            .iter()
            .map(|wk| recorder.ring(1 + wk.id as u32))
            .collect();
        SequentialExecutor {
            workers,
            results,
            spec,
            rings,
            round: 0,
        }
    }
}

impl Executor for SequentialExecutor {
    fn kind(&self) -> &'static str {
        "sequential"
    }

    fn solver_name(&self) -> String {
        self.workers
            .first()
            .map(|wk| wk.solver.name())
            .unwrap_or_default()
    }

    fn run_round(&mut self, w: &[f64], gamma: f64) -> Result<RoundTiming, PoolError> {
        let round_clock = Stopwatch::started();
        let spec = self.spec;
        let mut failed: Vec<(usize, String)> = Vec::new();
        let mut max_compute = 0.0f64;
        let mut total_compute = 0.0f64;
        let round = self.round;
        self.round += 1;
        for k in 0..self.workers.len() {
            let wk = &mut self.workers[k];
            let slot = &mut self.results[k];
            let t0 = self.rings[k].now();
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                wk.round_into(w, &spec, slot);
                wk.apply(gamma, &slot.update.delta_alpha);
            }));
            self.rings[k].complete("compute", "worker", t0, Some(("round", round as f64)));
            match outcome {
                Ok(()) => {
                    let c = self.results[k].compute_s;
                    max_compute = max_compute.max(c);
                    total_compute += c;
                }
                Err(payload) => failed.push((k, panic_message(payload.as_ref()))),
            }
        }
        if !failed.is_empty() {
            return Err(PoolError { failed });
        }
        // Workers ran serially, so the runtime's own overhead is the wall
        // time beyond the *sum* of the local solves.
        let barrier_s = (round_clock.elapsed_secs() - total_compute).max(0.0);
        Ok(RoundTiming {
            max_compute_s: max_compute,
            barrier_s,
            wire_s: 0.0,
        })
    }

    fn eval_partials(&mut self, w: &[f64]) -> Result<Vec<CertPartial>, PoolError> {
        // Same partial/combine code path as the pool, one worker at a
        // time in id order — bit-identical to the pooled reduction — and
        // the same error contract: a panicking evaluation surfaces as a
        // PoolError naming the worker, exactly as worker_loop's
        // catch_unwind does on the pooled runtime.
        let spec = self.spec;
        let mut failed: Vec<(usize, String)> = Vec::new();
        let mut partials = vec![CertPartial::default(); self.workers.len()];
        for (k, wk) in self.workers.iter().enumerate() {
            let t0 = self.rings[k].now();
            let outcome = catch_unwind(AssertUnwindSafe(|| wk.eval_partial(&spec, w)));
            self.rings[k].complete("cert", "worker", t0, None);
            match outcome {
                Ok(p) => partials[k] = p,
                Err(payload) => failed.push((k, panic_message(payload.as_ref()))),
            }
        }
        if !failed.is_empty() {
            return Err(PoolError { failed });
        }
        Ok(partials)
    }

    fn result(&self, k: usize) -> &WorkerResult {
        &self.results[k]
    }

    fn load_alpha(&mut self, alpha: &[f64]) {
        for wk in self.workers.iter_mut() {
            let start = wk.block.start();
            let len = wk.block.n_local();
            wk.alpha_local.copy_from_slice(&alpha[start..start + len]);
        }
    }
}

// ---------------------------------------------------------------------
// Pooled executor
// ---------------------------------------------------------------------

/// Messages the leader sends to a worker thread. FIFO per worker, so a
/// `LoadAlpha` enqueued before a `Round` is applied before it.
enum Job {
    /// Run one round against the shared `w` snapshot; fill and return the
    /// scratch.
    Round { scratch: WorkerResult, gamma: f64 },
    /// Compute this shard's certificate partial against the shared `w`
    /// snapshot and the worker-owned α_[k].
    Eval,
    /// Replace α_[k] with the provided local values.
    LoadAlpha(Vec<f64>),
}

/// Worker thread → leader. A `Round` reply carries the filled scratch
/// (preserved for reuse even when the solve panicked — the contents are
/// then meaningless but the buffer survives); an `Eval` reply carries the
/// shard's certificate partial by value (it is two floats — nothing to
/// ping-pong).
enum Reply {
    Round {
        scratch: WorkerResult,
        panic: Option<String>,
    },
    Eval {
        id: usize,
        partial: CertPartial,
        panic: Option<String>,
    },
}

fn worker_loop(
    mut wk: Worker,
    w_shared: Arc<RwLock<Vec<f64>>>,
    spec: SubproblemSpec,
    jobs: Receiver<Job>,
    replies: SyncSender<Reply>,
    mut ring: Ring,
) {
    let mut round: u64 = 0;
    while let Ok(job) = jobs.recv() {
        match job {
            Job::Round { mut scratch, gamma } => {
                let t0 = ring.now();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    {
                        let w = w_shared.read().expect("w broadcast lock poisoned");
                        wk.round_into(&w, &spec, &mut scratch);
                    }
                    // Line 5 of Algorithm 1: the worker owns its α_[k].
                    wk.apply(gamma, &scratch.update.delta_alpha);
                }));
                ring.complete("compute", "worker", t0, Some(("round", round as f64)));
                round += 1;
                let panic = outcome.err().map(|p| panic_message(p.as_ref()));
                if replies.send(Reply::Round { scratch, panic }).is_err() {
                    return; // leader gone — shut down
                }
            }
            Job::Eval => {
                let t0 = ring.now();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    let w = w_shared.read().expect("w broadcast lock poisoned");
                    wk.eval_partial(&spec, &w)
                }));
                ring.complete("cert", "worker", t0, None);
                let (partial, panic) = match outcome {
                    Ok(p) => (p, None),
                    Err(p) => (CertPartial::default(), Some(panic_message(p.as_ref()))),
                };
                let reply = Reply::Eval {
                    id: wk.id,
                    partial,
                    panic,
                };
                if replies.send(reply).is_err() {
                    return; // leader gone — shut down
                }
            }
            Job::LoadAlpha(alpha_local) => {
                wk.alpha_local.copy_from_slice(&alpha_local);
            }
        }
    }
}

/// K long-lived worker threads driven over bounded channels.
pub struct PooledExecutor {
    k: usize,
    /// Broadcast buffer for the round's w snapshot. The leader writes it
    /// (uncontended) between rounds; workers read it during rounds.
    w_shared: Arc<RwLock<Vec<f64>>>,
    job_txs: Vec<SyncSender<Job>>,
    reply_rx: Receiver<Reply>,
    /// Per-worker scratch, `take`n while a round is in flight.
    results: Vec<Option<WorkerResult>>,
    /// (n_k, d) per worker — to rebuild a scratch lost to a dead thread.
    dims: Vec<(usize, usize)>,
    /// `(start, len)` row range per worker in the shared layout (for
    /// `load_alpha` slice copies).
    parts: Vec<(usize, usize)>,
    solver_name: String,
    handles: Vec<JoinHandle<()>>,
    /// Leader-side trace lane (tid 0): broadcast and barrier spans.
    ring: Ring,
}

impl PooledExecutor {
    /// Spawn one long-lived thread per worker. This is the only place the
    /// runtime creates threads — `run_round` never does.
    pub fn spawn(workers: Vec<Worker>, spec: SubproblemSpec, recorder: Recorder) -> PooledExecutor {
        let k = workers.len();
        assert!(k > 0, "cannot build an empty pool");
        let d = workers[0].block.d();
        let solver_name = workers[0].solver.name();
        let dims: Vec<(usize, usize)> = workers
            .iter()
            .map(|wk| (wk.block.n_local(), wk.block.d()))
            .collect();
        let parts: Vec<(usize, usize)> = workers
            .iter()
            .map(|wk| (wk.block.start(), wk.block.n_local()))
            .collect();
        let w_shared = Arc::new(RwLock::new(vec![0.0; d]));
        let (reply_tx, reply_rx) = sync_channel::<Reply>(k);
        let mut job_txs = Vec::with_capacity(k);
        let mut handles = Vec::with_capacity(k);
        let mut results = Vec::with_capacity(k);
        for wk in workers {
            let id = wk.id;
            let (nk, dd) = dims[results.len()];
            results.push(Some(WorkerResult::with_dims(id, nk, dd)));
            let (job_tx, job_rx) = sync_channel::<Job>(1);
            let w = Arc::clone(&w_shared);
            let replies = reply_tx.clone();
            let ring = recorder.ring(1 + id as u32);
            let handle = std::thread::Builder::new()
                .name(format!("cocoa-worker-{id}"))
                .spawn(move || worker_loop(wk, w, spec, job_rx, replies, ring))
                .expect("failed to spawn pool worker thread");
            job_txs.push(job_tx);
            handles.push(handle);
        }
        PooledExecutor {
            k,
            w_shared,
            job_txs,
            reply_rx,
            results,
            dims,
            parts,
            solver_name,
            handles,
            ring: recorder.ring(0),
        }
    }
}

impl Executor for PooledExecutor {
    fn kind(&self) -> &'static str {
        "pooled"
    }

    fn solver_name(&self) -> String {
        self.solver_name.clone()
    }

    fn run_round(&mut self, w: &[f64], gamma: f64) -> Result<RoundTiming, PoolError> {
        let round_clock = Stopwatch::started();
        let t_bcast = self.ring.now();
        // Broadcast: publish the w snapshot. Workers are all idle between
        // rounds, so this write never contends.
        {
            let mut shared = self.w_shared.write().expect("w broadcast lock poisoned");
            shared.copy_from_slice(w);
        }
        // Fan out.
        let mut failed: Vec<(usize, String)> = Vec::new();
        let mut sent = 0usize;
        for k in 0..self.k {
            let scratch = self.results[k].take().unwrap_or_else(|| {
                let (nk, d) = self.dims[k];
                WorkerResult::with_dims(k, nk, d)
            });
            match self.job_txs[k].send(Job::Round { scratch, gamma }) {
                Ok(()) => sent += 1,
                Err(SendError(job)) => {
                    // Thread is gone; keep the scratch for a later retry.
                    if let Job::Round { scratch, .. } = job {
                        self.results[k] = Some(scratch);
                    }
                    failed.push((k, "worker thread terminated".to_string()));
                }
            }
        }
        self.ring.complete("broadcast", "executor", t_bcast, None);
        // Gather.
        let t_barrier = self.ring.now();
        let mut max_compute = 0.0f64;
        for _ in 0..sent {
            match self.reply_rx.recv() {
                Ok(Reply::Round { scratch, panic }) => {
                    let id = scratch.id;
                    match panic {
                        None => max_compute = max_compute.max(scratch.compute_s),
                        Some(msg) => failed.push((id, msg)),
                    }
                    self.results[id] = Some(scratch);
                }
                Ok(Reply::Eval { id, .. }) => {
                    // Cannot happen: the leader drains every reply before
                    // issuing the next job kind. Treat it as a failed
                    // round rather than corrupting state.
                    failed.push((id, "protocol error: eval reply during round".to_string()));
                }
                Err(_) => {
                    // Every reply sender is gone: name the workers whose
                    // round never came back (their scratch is still out).
                    for (id, slot) in self.results.iter().enumerate() {
                        if slot.is_none() {
                            failed.push((id, "worker thread died mid-round".to_string()));
                        }
                    }
                    break;
                }
            }
        }
        self.ring.complete("barrier", "executor", t_barrier, None);
        if !failed.is_empty() {
            failed.sort_by(|a, b| a.0.cmp(&b.0));
            return Err(PoolError { failed });
        }
        let barrier_s = (round_clock.elapsed_secs() - max_compute).max(0.0);
        Ok(RoundTiming {
            max_compute_s: max_compute,
            barrier_s,
            wire_s: 0.0,
        })
    }

    fn eval_partials(&mut self, w: &[f64]) -> Result<Vec<CertPartial>, PoolError> {
        // Broadcast the evaluation point (workers are idle — uncontended).
        {
            let mut shared = self.w_shared.write().expect("w broadcast lock poisoned");
            shared.copy_from_slice(w);
        }
        // Fan out: Eval is payload-free, the snapshot rides the broadcast.
        let mut failed: Vec<(usize, String)> = Vec::new();
        let mut sent = 0usize;
        let mut got = vec![false; self.k];
        let mut partials = vec![CertPartial::default(); self.k];
        for (k, tx) in self.job_txs.iter().enumerate() {
            match tx.send(Job::Eval) {
                Ok(()) => sent += 1,
                Err(SendError(_)) => {
                    // Accounted for here — the dead-channel sweep below
                    // must not report this worker a second time.
                    got[k] = true;
                    failed.push((k, "worker thread terminated".to_string()));
                }
            }
        }
        // Gather the K partials; `partials` is indexed by worker id, so
        // arrival order cannot perturb the leader's id-ordered reduce.
        let t_gather = self.ring.now();
        for _ in 0..sent {
            match self.reply_rx.recv() {
                Ok(Reply::Eval { id, partial, panic }) => {
                    match panic {
                        None => partials[id] = partial,
                        Some(msg) => failed.push((id, msg)),
                    }
                    got[id] = true;
                }
                Ok(Reply::Round { scratch, panic }) => {
                    let id = scratch.id;
                    self.results[id] = Some(scratch);
                    let msg = panic.unwrap_or_else(|| {
                        "protocol error: round reply during eval".to_string()
                    });
                    failed.push((id, msg));
                }
                Err(_) => {
                    for (id, &done) in got.iter().enumerate() {
                        if !done {
                            failed.push((id, "worker thread died mid-eval".to_string()));
                        }
                    }
                    break;
                }
            }
        }
        self.ring.complete("cert_gather", "executor", t_gather, None);
        if !failed.is_empty() {
            failed.sort_by(|a, b| a.0.cmp(&b.0));
            return Err(PoolError { failed });
        }
        Ok(partials)
    }

    fn result(&self, k: usize) -> &WorkerResult {
        self.results[k]
            .as_ref()
            .expect("no completed round result for this worker")
    }

    fn load_alpha(&mut self, alpha: &[f64]) {
        for (k, &(start, len)) in self.parts.iter().enumerate() {
            let local = alpha[start..start + len].to_vec();
            // FIFO per worker: applied before any later Round job. A dead
            // thread is surfaced by the next run_round, not here.
            let _ = self.job_txs[k].send(Job::LoadAlpha(local));
        }
    }
}

impl Drop for PooledExecutor {
    fn drop(&mut self) {
        // Closing every job channel makes each worker's `recv` fail, which
        // ends its loop; then join so no thread outlives the trainer.
        self.job_txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::make_solver;
    use crate::coordinator::SolverSpec;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;
    use crate::subproblem::LocalBlock;

    fn workers_and_spec(k: usize) -> (Vec<Worker>, SubproblemSpec) {
        let n = 48;
        let data = Arc::new(generate(&SynthConfig::new("pool", n, 6).seed(11)));
        let part = random_balanced(n, k, 3);
        let blocks = LocalBlock::split(&data, &part);
        let workers: Vec<Worker> = blocks
            .into_iter()
            .enumerate()
            .map(|(id, block)| {
                let solver = make_solver(
                    &SolverSpec::Sdca { h: 30 },
                    block.n_local(),
                    Worker::round_seed(7, 0, id),
                );
                Worker::new(id, block, solver)
            })
            .collect();
        let spec = SubproblemSpec {
            loss: Loss::Hinge,
            lambda: 0.05,
            n_global: n,
            sigma_prime: k as f64,
            k,
        };
        (workers, spec)
    }

    #[test]
    fn pooled_and_sequential_rounds_agree_bitwise() {
        let (wk_a, spec) = workers_and_spec(3);
        let (wk_b, _) = workers_and_spec(3);
        let mut seq = SequentialExecutor::new(wk_a, spec, Recorder::disabled());
        let mut pool = PooledExecutor::spawn(wk_b, spec, Recorder::disabled());
        let w = vec![0.0; 6];
        for _ in 0..3 {
            seq.run_round(&w, 1.0).unwrap();
            pool.run_round(&w, 1.0).unwrap();
            for k in 0..3 {
                assert_eq!(
                    seq.result(k).update.delta_alpha,
                    pool.result(k).update.delta_alpha,
                    "worker {k} Δα diverged between runtimes"
                );
                assert_eq!(seq.result(k).update.delta_w, pool.result(k).update.delta_w);
            }
        }
    }

    #[test]
    fn pooled_and_sequential_eval_partials_agree_bitwise() {
        let (wk_a, spec) = workers_and_spec(3);
        let (wk_b, _) = workers_and_spec(3);
        let mut seq = SequentialExecutor::new(wk_a, spec, Recorder::disabled());
        let mut pool = PooledExecutor::spawn(wk_b, spec, Recorder::disabled());
        let w: Vec<f64> = (0..6).map(|j| 0.05 * (j as f64 + 1.0)).collect();
        // interleave rounds and evals: partials must track the evolving
        // worker-owned α_[k] identically on both runtimes
        for _ in 0..3 {
            let ps = seq.eval_partials(&w).unwrap();
            let pp = pool.eval_partials(&w).unwrap();
            assert_eq!(ps.len(), 3);
            for k in 0..3 {
                assert_eq!(
                    ps[k].loss_sum.to_bits(),
                    pp[k].loss_sum.to_bits(),
                    "worker {k} loss partial diverged"
                );
                assert_eq!(
                    ps[k].conj_sum.to_bits(),
                    pp[k].conj_sum.to_bits(),
                    "worker {k} conjugate partial diverged"
                );
            }
            seq.run_round(&w, 1.0).unwrap();
            pool.run_round(&w, 1.0).unwrap();
        }
    }

    #[test]
    fn eval_partials_cover_all_rows_once() {
        let (workers, spec) = workers_and_spec(4);
        let n_total: usize = workers.iter().map(|wk| wk.block.n_local()).sum();
        assert_eq!(n_total, 48);
        let mut seq = SequentialExecutor::new(workers, spec, Recorder::disabled());
        // At α = 0, w = 0: hinge loss is 1 per row and ℓ*(0) = 0, so the
        // reduced partials must sum to exactly n — a row dropped or
        // double-counted by the shard views would show up immediately.
        let w = vec![0.0; 6];
        let partials = seq.eval_partials(&w).unwrap();
        let loss_total: f64 = partials.iter().map(|p| p.loss_sum).sum();
        let conj_total: f64 = partials.iter().map(|p| p.conj_sum).sum();
        assert_eq!(loss_total, 48.0);
        assert_eq!(conj_total, 0.0);
    }

    #[test]
    fn make_executor_degenerates_k1_to_sequential() {
        let (workers, spec) = workers_and_spec(1);
        let exec = make_executor(workers, spec, true, Recorder::disabled());
        assert_eq!(exec.kind(), "sequential");
        let (workers, spec) = workers_and_spec(2);
        let exec = make_executor(workers, spec, true, Recorder::disabled());
        assert_eq!(exec.kind(), "pooled");
        let (workers, spec) = workers_and_spec(2);
        let exec = make_executor(workers, spec, false, Recorder::disabled());
        assert_eq!(exec.kind(), "sequential");
    }

    #[test]
    fn pool_drop_joins_threads() {
        let (workers, spec) = workers_and_spec(4);
        let mut pool = PooledExecutor::spawn(workers, spec, Recorder::disabled());
        let w = vec![0.0; 6];
        pool.run_round(&w, 1.0).unwrap();
        drop(pool); // must not hang or leak — join happens here
    }

    #[test]
    fn load_alpha_reaches_workers_before_next_round() {
        let (workers, spec) = workers_and_spec(2);
        let mut pool = PooledExecutor::spawn(workers, spec, Recorder::disabled());
        let w = vec![0.0; 6];
        pool.run_round(&w, 1.0).unwrap();
        // Zero the dual state again; the next round must then reproduce
        // round 0 of a fresh pool with the same solver RNG position — we
        // only check it runs and the channel ordering holds.
        let alpha = vec![0.0; 48];
        pool.load_alpha(&alpha);
        pool.run_round(&w, 1.0).unwrap();
    }
}
