//! Run configuration for the CoCoA/CoCoA+ framework (Algorithm 1), with
//! the paper's named presets.

use crate::coordinator::comm::CommModel;
use crate::loss::Loss;
use crate::subproblem::sigma::safe_sigma_prime;
use crate::telemetry::Recorder;
use std::path::PathBuf;
use std::time::Duration;

/// How local updates are combined across workers (Eq. 14).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Aggregation {
    /// γ = 1/K — conservative averaging; with σ'=1 this is original CoCoA.
    Average,
    /// γ = 1 — additive aggregation; the CoCoA+ regime.
    Add,
    /// Any γ ∈ (0, 1].
    Gamma(f64),
}

impl Aggregation {
    pub fn gamma(&self, k: usize) -> f64 {
        match *self {
            Aggregation::Average => 1.0 / k as f64,
            Aggregation::Add => 1.0,
            Aggregation::Gamma(g) => g,
        }
    }
}

/// Which local solver each worker runs.
#[derive(Clone, Debug)]
pub enum SolverSpec {
    /// LOCALSDCA with a fixed number of inner iterations H.
    Sdca { h: usize },
    /// LOCALSDCA with H = epochs·n_k.
    SdcaEpochs { epochs: f64 },
    /// Cyclic coordinate descent, `epochs` sweeps.
    Cyclic { epochs: usize, shuffle: bool },
    /// Damped synchronous Jacobi updates.
    Jacobi { sweeps: usize, beta: f64 },
}

/// Which runtime executes the K local solves each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutorChoice {
    /// Honour `parallel`: pooled threads when true and K > 1, else
    /// sequential. This is the pre-existing behaviour and the default.
    Auto,
    /// In-process, one worker after another on the leader thread.
    Sequential,
    /// K long-lived OS threads (ignores `parallel = false`).
    Pooled,
    /// K worker *processes* over Unix domain sockets (or TCP via
    /// [`SocketOpts::tcp_addr`]).
    Socket,
}

impl ExecutorChoice {
    /// Parse a CLI spelling. Accepts a couple of aliases per runtime.
    pub fn parse(s: &str) -> Option<ExecutorChoice> {
        match s {
            "auto" => Some(ExecutorChoice::Auto),
            "sequential" | "seq" => Some(ExecutorChoice::Sequential),
            "pooled" | "threads" => Some(ExecutorChoice::Pooled),
            "socket" | "process" => Some(ExecutorChoice::Socket),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ExecutorChoice::Auto => "auto",
            ExecutorChoice::Sequential => "sequential",
            ExecutorChoice::Pooled => "pooled",
            ExecutorChoice::Socket => "socket",
        }
    }
}

/// Knobs for the socket (multi-process) executor.
#[derive(Clone, Debug)]
pub struct SocketOpts {
    /// Listen on TCP at this address (e.g. `"127.0.0.1:0"`) instead of a
    /// Unix domain socket.
    pub tcp_addr: Option<String>,
    /// Binary to spawn for `cocoa worker`. `None` → the `COCOA_WORKER_BIN`
    /// environment variable, then the current executable.
    pub worker_bin: Option<PathBuf>,
    /// How long workers get to connect and complete the hello/init/ready
    /// handshake.
    pub handshake_timeout: Duration,
    /// Per-round reply deadline; `None` waits forever. A worker that
    /// misses it fails the round with a `PoolError` naming it.
    pub round_timeout: Option<Duration>,
}

impl Default for SocketOpts {
    fn default() -> SocketOpts {
        SocketOpts {
            tcp_addr: None,
            worker_bin: None,
            handshake_timeout: Duration::from_secs(10),
            round_timeout: Some(Duration::from_secs(120)),
        }
    }
}

#[derive(Clone, Debug)]
pub struct CocoaConfig {
    /// Number of workers K.
    pub k: usize,
    /// Aggregation parameter γ.
    pub aggregation: Aggregation,
    /// Subproblem parameter σ'. `None` → the safe bound γK (Lemma 4).
    pub sigma_prime: Option<f64>,
    pub loss: Loss,
    pub lambda: f64,
    pub solver: SolverSpec,
    /// Stop after this many outer rounds.
    pub max_rounds: usize,
    /// Stop when the duality gap falls below this.
    pub gap_tol: f64,
    /// Evaluate certificates every `gap_every` rounds (they cost a full
    /// pass over the data).
    pub gap_every: usize,
    /// Abort and flag divergence when the gap exceeds this (unsafe σ'
    /// configurations in Fig. 3 really do diverge).
    pub divergence_gap: f64,
    /// Run workers on OS threads (true) or sequentially in-process (false;
    /// required by local solvers that are not Send, e.g. the PJRT-backed
    /// one, and useful for exact determinism).
    pub parallel: bool,
    pub seed: u64,
    /// Simulated cluster network for the paper's elapsed-time axes.
    pub comm: CommModel,
    /// Which runtime executes the K local solves (overrides `parallel`
    /// unless `Auto`).
    pub executor: ExecutorChoice,
    /// Socket-executor knobs; only consulted when `executor == Socket`.
    pub socket: SocketOpts,
    /// Flight recorder for the run; disabled by default (zero cost).
    pub trace: Recorder,
}

impl CocoaConfig {
    /// CoCoA+ with the safe σ' = γK (the paper's recommended default).
    pub fn cocoa_plus(k: usize, loss: Loss, lambda: f64, solver: SolverSpec) -> CocoaConfig {
        CocoaConfig {
            k,
            aggregation: Aggregation::Add,
            sigma_prime: None,
            loss,
            lambda,
            solver,
            max_rounds: 200,
            gap_tol: 1e-4,
            gap_every: 1,
            divergence_gap: 1e6,
            parallel: true,
            seed: 42,
            comm: CommModel::ec2_like(),
            executor: ExecutorChoice::Auto,
            socket: SocketOpts::default(),
            trace: Recorder::disabled(),
        }
    }

    /// Original CoCoA (Jaggi et al. 2014): γ = 1/K, σ' = 1 (Remark 12).
    pub fn cocoa(k: usize, loss: Loss, lambda: f64, solver: SolverSpec) -> CocoaConfig {
        CocoaConfig {
            aggregation: Aggregation::Average,
            sigma_prime: Some(1.0),
            ..CocoaConfig::cocoa_plus(k, loss, lambda, solver)
        }
    }

    /// DisDCA-p (Yang 2013) = CoCoA+ with SDCA, σ'=K, γ=1 (Lemma 18).
    pub fn disdca_p(k: usize, loss: Loss, lambda: f64, h: usize) -> CocoaConfig {
        CocoaConfig::cocoa_plus(k, loss, lambda, SolverSpec::Sdca { h })
    }

    /// Effective γ.
    pub fn gamma(&self) -> f64 {
        self.aggregation.gamma(self.k)
    }

    /// Effective σ' (explicit or the safe bound γK).
    pub fn effective_sigma_prime(&self) -> f64 {
        self.sigma_prime
            .unwrap_or_else(|| safe_sigma_prime(self.gamma(), self.k))
    }

    pub fn with_sigma_prime(mut self, sp: f64) -> Self {
        self.sigma_prime = Some(sp);
        self
    }

    pub fn with_rounds(mut self, r: usize) -> Self {
        self.max_rounds = r;
        self
    }

    pub fn with_gap_tol(mut self, tol: f64) -> Self {
        self.gap_tol = tol;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_parallel(mut self, parallel: bool) -> Self {
        self.parallel = parallel;
        self
    }

    pub fn with_gap_every(mut self, every: usize) -> Self {
        self.gap_every = every.max(1);
        self
    }

    pub fn with_executor(mut self, executor: ExecutorChoice) -> Self {
        self.executor = executor;
        self
    }

    /// Set the binary spawned for `cocoa worker` (tests and benches point
    /// this at `env!("CARGO_BIN_EXE_cocoa")`).
    pub fn with_socket_worker_bin<P: Into<PathBuf>>(mut self, bin: P) -> Self {
        self.socket.worker_bin = Some(bin.into());
        self
    }

    /// Attach a flight recorder; the Trainer and its executor trace
    /// their round phases into it.
    pub fn with_recorder(mut self, recorder: Recorder) -> Self {
        self.trace = recorder;
        self
    }

    /// Sanity-check the configuration against the theory's ranges.
    pub fn validate(&self) -> Result<(), String> {
        let g = self.gamma();
        if !(g > 0.0 && g <= 1.0) {
            return Err(format!("γ = {g} outside (0, 1]"));
        }
        if self.lambda <= 0.0 {
            return Err(format!("λ = {} must be positive", self.lambda));
        }
        let sp = self.effective_sigma_prime();
        if sp <= 0.0 {
            return Err(format!("σ' = {sp} must be positive"));
        }
        if self.k == 0 {
            return Err("K must be ≥ 1".into());
        }
        let safe = safe_sigma_prime(g, self.k);
        if sp < safe - 1e-12 {
            // Not an error (Fig. 3 explores this), but it voids the theory.
            crate::log_warn!(
                "σ' = {sp} below the safe bound γK = {safe}: convergence no longer guaranteed"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let plus = CocoaConfig::cocoa_plus(8, Loss::Hinge, 1e-4, SolverSpec::Sdca { h: 100 });
        assert_eq!(plus.gamma(), 1.0);
        assert_eq!(plus.effective_sigma_prime(), 8.0);

        let orig = CocoaConfig::cocoa(8, Loss::Hinge, 1e-4, SolverSpec::Sdca { h: 100 });
        assert_eq!(orig.gamma(), 0.125);
        assert_eq!(orig.effective_sigma_prime(), 1.0);
    }

    #[test]
    fn averaging_safe_bound_is_one() {
        // Lemma 4 for γ=1/K gives σ' = 1 — exactly the original CoCoA.
        let cfg = CocoaConfig {
            sigma_prime: None,
            ..CocoaConfig::cocoa(4, Loss::Hinge, 0.1, SolverSpec::SdcaEpochs { epochs: 1.0 })
        };
        assert_eq!(cfg.effective_sigma_prime(), 1.0);
    }

    #[test]
    fn validation() {
        let ok = CocoaConfig::cocoa_plus(4, Loss::Hinge, 0.1, SolverSpec::Sdca { h: 10 });
        assert!(ok.validate().is_ok());
        let bad = CocoaConfig {
            lambda: -1.0,
            ..ok.clone()
        };
        assert!(bad.validate().is_err());
        let bad_gamma = CocoaConfig {
            aggregation: Aggregation::Gamma(1.5),
            ..ok
        };
        assert!(bad_gamma.validate().is_err());
    }

    #[test]
    fn executor_choice_parses_aliases() {
        assert_eq!(ExecutorChoice::parse("auto"), Some(ExecutorChoice::Auto));
        assert_eq!(ExecutorChoice::parse("seq"), Some(ExecutorChoice::Sequential));
        assert_eq!(ExecutorChoice::parse("threads"), Some(ExecutorChoice::Pooled));
        assert_eq!(ExecutorChoice::parse("socket"), Some(ExecutorChoice::Socket));
        assert_eq!(ExecutorChoice::parse("frobnicate"), None);
        assert_eq!(ExecutorChoice::Socket.as_str(), "socket");
    }

    #[test]
    fn builder_chain() {
        let cfg = CocoaConfig::cocoa_plus(2, Loss::Hinge, 0.1, SolverSpec::Sdca { h: 5 })
            .with_sigma_prime(3.0)
            .with_rounds(7)
            .with_gap_tol(1e-6)
            .with_seed(9)
            .with_gap_every(3);
        assert_eq!(cfg.effective_sigma_prime(), 3.0);
        assert_eq!(cfg.max_rounds, 7);
        assert_eq!(cfg.gap_every, 3);
    }
}
