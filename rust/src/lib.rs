//! # cocoa — Adding vs. Averaging in Distributed Primal-Dual Optimization
//!
//! A production-grade reproduction of **CoCoA+** (Ma, Smith, Jaggi, Jordan,
//! Richtárik, Takáč — ICML 2015): a communication-efficient framework for
//! distributed regularized empirical-loss minimization in which per-round
//! local updates are **added** (γ = 1, σ' = K) rather than conservatively
//! **averaged** (γ = 1/K, σ' = 1 — the original CoCoA), yielding outer
//! iteration counts independent of the number of machines K.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L3** — this crate: the coordinator (Algorithm 1), local solvers,
//!   baselines, datasets, experiment harness;
//! * **L2** — `python/compile/model.py`: the local SDCA epoch and
//!   duality-gap graphs in JAX, AOT-lowered to HLO text;
//! * **L1** — `python/compile/kernels/`: Pallas kernels for the SDCA block
//!   sweep and the tiled matvecs, called from L2.
//! The [`runtime`] module loads the AOT artifacts via PJRT so the same
//! [`solver::LocalSolver`] interface runs native-Rust or XLA compute.
//!
//! Quickstart:
//! ```no_run
//! use cocoa::prelude::*;
//! let data = cocoa::data::synth::generate(
//!     &cocoa::data::synth::SynthConfig::new("demo", 1000, 50).seed(1));
//! let part = cocoa::data::partition::random_balanced(1000, 8, 1);
//! let problem = Problem::new(data, Loss::Hinge, 1e-3);
//! let cfg = CocoaConfig::cocoa_plus(8, Loss::Hinge, 1e-3,
//!     SolverSpec::SdcaEpochs { epochs: 1.0 });
//! let mut trainer = Trainer::new(problem, part, cfg);
//! let history = trainer.run();
//! println!("final duality gap: {:.3e}", history.final_gap());
//! ```

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod loss;
pub mod objective;
pub mod report;
pub mod runtime;
pub mod solver;
pub mod subproblem;
pub mod testing;
pub mod util;

/// The most common imports in one place.
pub mod prelude {
    pub use crate::coordinator::{Aggregation, CocoaConfig, History, SolverSpec, Trainer};
    pub use crate::data::{Dataset, Partition};
    pub use crate::loss::Loss;
    pub use crate::objective::Problem;
    pub use crate::solver::LocalSolver;
}
