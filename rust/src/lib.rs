//! # cocoa — Adding vs. Averaging in Distributed Primal-Dual Optimization
//!
//! A production-grade reproduction of **CoCoA+** (Ma, Smith, Jaggi, Jordan,
//! Richtárik, Takáč — ICML 2015): a communication-efficient framework for
//! distributed regularized empirical-loss minimization in which per-round
//! local updates are **added** (γ = 1, σ' = K) rather than conservatively
//! **averaged** (γ = 1/K, σ' = 1 — the original CoCoA), yielding outer
//! iteration counts independent of the number of machines K.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L3** — this crate: the coordinator (Algorithm 1) on a persistent
//!   worker-pool runtime, local solvers, baselines, datasets, experiment
//!   harness;
//! * **L2** — `python/compile/model.py`: the local SDCA epoch and
//!   duality-gap graphs in JAX, AOT-lowered to HLO text;
//! * **L1** — `python/compile/kernels/`: Pallas kernels for the SDCA block
//!   sweep and the tiled matvecs, called from L2.
//! The `runtime` module (feature `xla`; requires the PJRT bindings crate,
//! not vendored in the offline toolchain) loads the AOT artifacts via
//! PJRT so the same [`solver::LocalSolver`] interface runs native-Rust or
//! XLA compute.
//!
//! ## Shared data plane
//!
//! The dataset is a **single shared object**: [`objective::Problem`]
//! holds it behind an `Arc`, and each worker's
//! [`subproblem::LocalBlock`] is a zero-copy row-range view
//! ([`linalg::CsrShard`]) into it — no per-worker matrix clones and no
//! separate leader copy (resident data is 1× the dataset, down from ≈2×).
//! An arbitrary partition is realized by reordering the dataset **once**
//! into the permuted-contiguous [`data::ShardLayout`]: every part becomes
//! a `(start, len)` row range — the whole shard addressing is K such
//! pairs, with no per-row index lists on the round path — and
//! [`data::RowPermutation`] maps back to the caller's row order. A
//! partition that is already contiguous permutes nothing, and the ingest
//! path that does permute consumes the caller's dataset in place
//! ([`data::Dataset::permute_rows`] via `Arc::try_unwrap`) so peak
//! memory stays near one dataset even while reordering. Per-shard
//! contents are unchanged by the layout, so solver trajectories match
//! the index-list semantics exactly.
//!
//! ## Execution model
//!
//! [`coordinator::Trainer::new`] spawns the cluster **once**, on one of
//! three interchangeable runtimes ([`coordinator::ExecutorChoice`]):
//!
//! * **Pooled threads** ([`coordinator::pool::PooledExecutor`], the
//!   default for K > 1): K long-lived worker threads, each owning its
//!   data-shard view, its α_[k] slice, and its solver state. The leader
//!   publishes a `w` snapshot to a shared broadcast buffer, kicks workers
//!   over bounded channels, and gathers Δ-updates into per-worker scratch
//!   that ping-pongs between leader and workers — zero thread spawns and
//!   zero result allocations per steady-state round.
//! * **Sequential in-process**
//!   ([`coordinator::pool::SequentialExecutor`]; `cfg.parallel = false`,
//!   K = 1, or non-thread-safe solvers such as the PJRT-backed one): the
//!   same rounds, one worker after another on the leader thread.
//! * **Socket processes**
//!   ([`coordinator::socket::SocketExecutor`]; `--executor socket`): K
//!   real worker *processes* (`cocoa worker`) connected over Unix domain
//!   sockets (TCP optional), exchanging rounds in a dependency-free
//!   length-prefixed wire format ([`coordinator::wire`]) whose binary f64
//!   sections preserve every bit. Dead workers, handshake mismatches,
//!   and round timeouts surface as [`coordinator::PoolError`]s naming
//!   the workers — a failed round is an error, never a hang.
//!
//! The socket leader broadcasts each round's frame to all K workers from
//! concurrent sender threads (one per connection), so the last worker no
//! longer waits behind K−1 serializations before its copy even starts;
//! the per-worker `send` spans land on each worker's trace lane under a
//! single leader-lane `broadcast` umbrella.
//!
//! All three produce bit-identical trajectories (seeded per-worker solver
//! streams + worker-id-ordered reduce + bit-exact shard transport), which
//! `rust/tests/determinism.rs` locks in as a three-way invariant.
//!
//! ## Kernels
//!
//! The hot inner products and AXPYs route through [`linalg::simd`]:
//! runtime-dispatched AVX2 on x86-64 with a portable 4-lane scalar
//! fallback, both sides computing in the **same fixed lane and
//! reduction order** (multiply-then-add, never FMA) so results are
//! bit-identical whichever path runs — determinism never depends on the
//! CPU. `COCOA_NO_SIMD=1` pins a process to the scalar path;
//! [`linalg::simd::force_scalar`] does the same in-process for tests.
//! The CSR kernels add a gather-free dense-row fast path and a
//! cache-blocked multi-row margin sweep
//! ([`linalg::CsrMatrix::rows_dot`]) used by the certificate pass and
//! batch prediction. `benches/bench_hotpath.rs` tracks the payoff
//! against the committed `BENCH_<pr>.json` snapshot via
//! `benches/bench_compare.rs`.
//!
//! ## Distributed duality-gap certificates
//!
//! The stopping certificate (§2, eq. 4) is no longer a serial full-data
//! pass on the leader: at certificate cadence the round protocol sends an
//! `Eval` message and every worker reduces its own shard in parallel to a
//! partial primal-loss sum and partial dual-conjugate sum, its local
//! margins consumed on the fly ([`objective::CertPartial`],
//! [`objective::cert_partial`]) — and the
//! leader combines the K partials with the ‖w‖² term
//! ([`objective::Problem::certificates_from_partials`]). Central
//! evaluation ([`objective::Problem::certificates`]) is the one-shard
//! case of the same code path, and the sequential executor reduces the
//! identical partials, so gap trajectories stay bit-identical across
//! runtimes while the serial O(nnz) bottleneck becomes K-way parallel.
//!
//! ## Time accounting
//!
//! Measured per-worker compute (max over workers — what gates a
//! synchronous cluster round) feeds the simulated cluster clock in
//! [`coordinator::comm`]; the runtime's own fan-out/gather barrier and
//! the leader's reduce are measured into
//! [`coordinator::comm::CommStats`] (`barrier_s` / `reduce_s`) so
//! compute-time curves no longer absorb scheduler overhead the paper's
//! cluster would not have.
//!
//! ## Unified training API
//!
//! Every optimizer — the CoCoA/CoCoA+ [`coordinator::Trainer`] and all
//! five baselines (mini-batch SGD, mini-batch SDCA, one-shot averaging,
//! consensus ADMM, serial SDCA) — implements the [`driver::Method`]
//! trait (`step` / `eval` / `comm_vectors_per_round` / `w` / `label`),
//! and a single [`driver::Driver`] owns the outer loop: the stopping
//! policy ([`driver::StopPolicy`] — gap tolerance, round budget,
//! divergence abort, dual stall, and the Fig.-2 dual-target ε_D rule),
//! the certificate cadence, the simulated cluster clock with
//! [`coordinator::comm::CommModel`] charging, and pluggable
//! [`driver::Observer`]s (streaming CSV, progress logging,
//! checkpoint-every-N, best-gap tracking). The experiment harness, the
//! CLI (`cocoa train --method <name>`), and the conformance suite all
//! drive optimizers exclusively through this API, so a new method, stop
//! rule, or metric sink is a one-file change.
//!
//! Quickstart:
//! ```no_run
//! use cocoa::prelude::*;
//! let data = cocoa::data::synth::generate(
//!     &cocoa::data::synth::SynthConfig::new("demo", 1000, 50).seed(1));
//! let part = cocoa::data::partition::random_balanced(1000, 8, 1);
//! let problem = Problem::new(data, Loss::Hinge, 1e-3);
//! let cfg = CocoaConfig::cocoa_plus(8, Loss::Hinge, 1e-3,
//!     SolverSpec::SdcaEpochs { epochs: 1.0 });
//! let mut trainer = Trainer::new(problem, part, cfg);
//! // The method-agnostic run loop: swap `trainer` for any other Method
//! // (MiniBatchSgd, Admm, …) and the loop, clock, and stopping policy
//! // stay the same.
//! let mut driver = Driver::new(
//!     StopPolicy::new(200).with_gap_tol(1e-4));
//! let history = driver.run(&mut trainer);
//! println!("final duality gap: {:.3e} ({:?})", history.final_gap(), history.stop);
//! ```
//!
//! Baselines are also constructible by name through
//! [`driver::registry::build_method`] — the same path `cocoa train
//! --method cocoa-plus|cocoa|mb-sgd|mb-sdca|one-shot|admm|serial-sdca`
//! uses.
//!
//! ## Serving
//!
//! A trained model is one command away from an HTTP prediction service:
//! `cocoa train … --checkpoint-out model.json` captures the full
//! primal-dual state, and `cocoa serve --checkpoint model.json --addr
//! 127.0.0.1:8080` serves it ([`serve`]) — `POST /predict` scores sparse
//! feature vectors with the training-time kernel bit-for-bit, `/reload`
//! hot-swaps checkpoints, and `/retrain` warm-starts the [`driver::Driver`]
//! from the served α on drifted data without dropping traffic. The HTTP
//! layer is hand-rolled on `std::net` with the same hostile-input
//! discipline as the socket executor's wire format.
//!
//! ## Observability
//!
//! Every runtime carries a [`telemetry::Recorder`] — a dependency-free
//! flight recorder whose per-actor [`telemetry::Ring`] buffers stream
//! Chrome trace-event JSON (Perfetto / `chrome://tracing`) without ever
//! materializing the document. `cocoa train --trace-out trace.json`
//! captures the Driver's rounds, each executor's
//! broadcast/compute/barrier/reduce phases per worker, and the socket
//! executor's per-frame wire time; `cocoa serve --trace-out` captures
//! the request path; `cocoa trace-check` validates the result, and
//! `cocoa trace-summary` renders it as a per-phase wall-clock budget
//! table. Measured
//! socket wire time flows into [`coordinator::comm::CommStats`] next to
//! the simulated communication model, and `cocoa train` prints a
//! measured-vs-simulated validation report from it. Tracing is strictly
//! observe-only: the three-way determinism suite stays bit-identical
//! with the recorder on. The serve layer's counters and histograms are
//! generalized into [`telemetry::metrics`], one registry behind both
//! `GET /metrics` and the training CLI summary.
//!
//! ## Static invariants (`cocoa-lint`)
//!
//! The contracts this crate-level doc keeps promising — panic-free
//! request/wire surfaces, bit-identical trajectories across executors,
//! justified `unsafe`, deadlock-free lock nesting in the serve layer —
//! are machine-checked, not aspirational. The workspace member `lint/`
//! (`cargo run -p cocoa-lint`) walks `rust/src` with a dependency-free
//! lexer and enforces the rule families (`no_panic`, `determinism`,
//! `unsafe_safety`, `lock_order`, `arith_overflow`) as a required CI
//! gate, with Miri and nightly ThreadSanitizer lanes behind it. The rule catalog, the
//! declared lock-order ranking, and the reasoned inline waiver syntax
//! (`lint:allow`) are documented in `ANALYSIS.md` at the repo root.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod experiments;
pub mod linalg;
pub mod loss;
pub mod objective;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod subproblem;
pub mod telemetry;
pub mod testing;
pub mod util;

/// The most common imports in one place.
pub mod prelude {
    pub use crate::coordinator::{
        Aggregation, CocoaConfig, ExecutorChoice, History, SolverSpec, StopReason, Trainer,
    };
    pub use crate::data::{Dataset, Partition};
    pub use crate::driver::{
        BuildOpts, Driver, Method, MethodName, Observer, StepStats, StopPolicy,
    };
    pub use crate::loss::Loss;
    pub use crate::objective::Problem;
    pub use crate::solver::LocalSolver;
}
