//! Figure 3: the effect of the subproblem parameter σ' on CoCoA+ with
//! additive aggregation (γ=1), rcv1 analogue, K=8.
//!
//! Paper: σ' sweeps {1, 2, 3, 4, 6, 8}; the safe bound is σ' = γK = 8;
//! convergence speeds up as σ' decreases toward ~K/2, and diverges for
//! σ' ≤ 2. Reproduction targets: (i) the safe bound converges, (ii) some
//! σ' < K is at least as fast, (iii) sufficiently small σ' diverges or
//! clearly stalls.

use crate::coordinator::{CocoaConfig, SolverSpec, StopReason, Trainer};
use crate::data::partition::random_balanced;
use crate::experiments::ExpContext;
use crate::loss::Loss;
use crate::objective::Problem;
use crate::report::ascii_plot::{render, PlotCfg, Series};
use crate::report::{self};

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    let k = 8usize;
    // The σ' trade-off of Fig. 3 lives in the weakly regularized regime
    // (λn small): large σ' over-damps, small σ' over-shoots. λn ≈ 0.3
    // reproduces the paper's frontier at any --scale.
    let lambda = 0.3 / (ctx.dataset("rcv1").n() as f64);
    let (sigmas, rounds): (Vec<f64>, usize) = if ctx.quick {
        (vec![1.0, 4.0, 8.0], 40)
    } else {
        (vec![1.0, 2.0, 3.0, 4.0, 6.0, 8.0], 150)
    };
    let data = ctx.dataset("rcv1");
    let n = data.n();
    out.push_str(&format!(
        "fig3: rcv1-like n={n} d={} K={k} γ=1 λ={lambda:.0e}; safe σ'=γK={k}\n",
        data.d()
    ));

    let target_gap = 1e-2;
    let mut series = Vec::new();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    out.push_str(&format!(
        "{:>6} {:>12} {:>12} {:>12} {:>10}\n",
        "σ'", "final gap", "vecs→tgt", "time→tgt(s)", "status"
    ));
    let markers = ['1', '2', '3', '4', '6', '8'];
    for (si, &sp) in sigmas.iter().enumerate() {
        let part = random_balanced(n, k, ctx.seed);
        let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
        let cfg = CocoaConfig::cocoa_plus(
            k,
            Loss::Hinge,
            lambda,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_sigma_prime(sp)
        .with_rounds(rounds)
        .with_gap_tol(target_gap * 1e-2)
        .with_seed(ctx.seed)
        .with_parallel(true);
        let mut trainer = Trainer::new(problem, part, cfg);
        // Trainer::run == Driver::from_cocoa_config(&cfg).run(..)
        let hist = trainer.run();
        let hit = hist.time_to_gap(target_gap);
        let first_gap = hist.records.first().map(|r| r.gap).unwrap_or(f64::INFINITY);
        let status = match hist.stop {
            StopReason::Diverged => "DIVERGED",
            _ if hit.is_some() => "converged",
            // gap grew well past its round-0 value: the unsafe-σ' blow-up
            // of Fig. 3 even if it hasn't tripped the hard abort yet
            _ if hist.final_gap() > first_gap.max(1.0) * 5.0 => "DIVERGING",
            _ => "slow",
        };
        out.push_str(&format!(
            "{:>6} {:>12.4e} {:>12} {:>12} {:>10}\n",
            sp,
            hist.final_gap(),
            hit.map(|(_, _, v)| v.to_string()).unwrap_or("-".into()),
            hit.map(|(_, t, _)| format!("{t:.3}")).unwrap_or("-".into()),
            status
        ));
        for r in &hist.records {
            csv_rows.push(vec![
                sp,
                r.round as f64,
                r.comm_vectors as f64,
                r.sim_time_s,
                r.gap,
            ]);
        }
        series.push(Series::new(
            &format!("σ'={sp}"),
            hist.records.iter().map(|r| r.comm_vectors as f64).collect(),
            hist.records.iter().map(|r| r.gap).collect(),
            markers[si % markers.len()],
        ));
    }

    out.push_str(&render(
        "fig3: gap vs communicated vectors per σ' (log-log)",
        &series,
        &PlotCfg::default(),
    ));

    let csv = report::csv::to_csv(
        &["sigma_prime", "round", "vectors", "sim_time_s", "gap"],
        &csv_rows,
    );
    if let Ok(p) = report::write_result("fig3.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig3_safe_sigma_converges_small_sigma_worse() {
        let ctx = ExpContext {
            scale: 3000.0,
            quick: true,
            seed: 7,
        };
        let out = run(&ctx);
        // Safe row (σ'=8) must not be DIVERGED.
        let safe_row = out
            .lines()
            .find(|l| l.trim_start().starts_with("8 "))
            .expect("σ'=8 row");
        assert!(!safe_row.contains("DIVERGED"), "{out}");
    }
}
