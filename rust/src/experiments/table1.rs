//! Table 1: the ratio of the worst-case bound n²/K to the true partition
//! constant σ = Σ_k σ_k n_k (Eq. 18–19), on the paper's dataset analogues.
//!
//! Paper rows: news20, real-sim, rcv1 at K ∈ {16…512}; covtype at
//! K ∈ {256…8192}. Values there sit between ~10 and ~42 and decay slowly
//! with K — i.e. the safe bound is one-to-two orders pessimistic. Our
//! synthetic analogues are smaller (K is capped at n/2), so the absolute
//! ratios differ, but the two qualitative claims are checked: ratio ≫ 1,
//! and non-increasing in K.

use crate::data::partition::random_balanced;
use crate::experiments::ExpContext;
use crate::report;
use crate::subproblem::sigma::partition_sigma;

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();

    let spec: Vec<(&str, Vec<usize>)> = if ctx.quick {
        vec![("rcv1", vec![16, 64]), ("covtype", vec![16, 64])]
    } else {
        vec![
            ("news", vec![16, 32, 64, 128, 256, 512]),
            ("real-sim", vec![16, 32, 64, 128, 256, 512]),
            ("rcv1", vec![16, 32, 64, 128, 256, 512]),
            ("covtype", vec![16, 32, 64, 128, 256, 512]),
        ]
    };

    out.push_str(&format!(
        "{:<10} {:>6} {:>12} {:>12} {:>10}\n",
        "dataset", "K", "n²/K", "σ", "ratio"
    ));
    for (ds_name, ks) in &spec {
        let data = ctx.dataset(ds_name);
        let n = data.n();
        for &k in ks {
            if k > n / 2 {
                out.push_str(&format!(
                    "{:<10} {:>6}   (skipped: K > n/2 at this scale, n={})\n",
                    ds_name, k, n
                ));
                continue;
            }
            let part = random_balanced(n, k, ctx.seed);
            let ps = partition_sigma(&data, &part, ctx.seed);
            let bound = (n * n) as f64 / k as f64;
            let ratio = ps.table1_ratio(n);
            out.push_str(&format!(
                "{:<10} {:>6} {:>12.1} {:>12.1} {:>10.3}\n",
                ds_name, k, bound, ps.sigma_sum, ratio
            ));
            csv_rows.push(vec![
                super::dataset_id(ds_name),
                k as f64,
                bound,
                ps.sigma_sum,
                ratio,
            ]);
        }
        out.push('\n');
    }

    let csv = crate::report::csv::to_csv(
        &["dataset_id", "k", "bound_n2_over_k", "sigma", "ratio"],
        &csv_rows,
    );
    if let Ok(p) = report::write_result("table1.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }

    // Check the headline claims programmatically and say so in the output.
    let all_ge_one = csv_rows.iter().all(|r| r[4] >= 0.99);
    out.push_str(&format!(
        "claim ratio >= 1 everywhere (bound valid): {}\n",
        if all_ge_one { "HOLDS" } else { "VIOLATED" }
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table1_runs_and_holds() {
        let ctx = ExpContext {
            scale: 2000.0,
            quick: true,
            seed: 1,
        };
        let out = run(&ctx);
        assert!(out.contains("ratio"));
        assert!(out.contains("HOLDS"), "table1 bound claim failed:\n{out}");
    }
}
