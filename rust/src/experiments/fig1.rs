//! Figure 1: duality gap vs #communicated vectors and vs elapsed time,
//! CoCoA (γ=1/K, σ'=1) against CoCoA+ (γ=1, σ'=γK), on the covtype
//! analogue (K=4) and the rcv1 analogue (K=8), swept over
//! λ ∈ {1e-4, 1e-5, 1e-6} and three local-work levels H.
//!
//! The paper's H ∈ {1e4, 1e5, 1e6} on n ≈ 5·10⁵ corresponds to roughly
//! {0.1, 1, 10} local epochs; we sweep the epoch-equivalents so the
//! compute/communication ratio matches at any --scale. Reproduction
//! targets: CoCoA+ reaches any fixed gap with fewer communicated vectors
//! *and* less simulated time in every (λ, H) cell, with the margin growing
//! for larger λ and smaller H.

use crate::coordinator::{CocoaConfig, SolverSpec, Trainer};
use crate::data::partition::random_balanced;
use crate::experiments::ExpContext;
use crate::loss::Loss;
use crate::objective::Problem;
use crate::report::ascii_plot::{render, PlotCfg, Series};
use crate::report::{self};

struct Cell {
    dataset: String,
    k: usize,
    lambda: f64,
    epochs: f64,
    plus_vectors: Option<f64>,
    avg_vectors: Option<f64>,
    plus_time: Option<f64>,
    avg_time: Option<f64>,
}

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    // λ is quoted at the paper's full dataset size; the scale-invariant
    // quantity is λ·n, so at --scale s the equivalent λ is λ_paper·s.
    // (Strong convexity of the *problem* is λn-determined.)
    let lam_scale = ctx.scale.max(1.0);
    let (lambdas, epoch_grid, rounds): (Vec<f64>, Vec<f64>, usize) = if ctx.quick {
        (vec![1e-4 * lam_scale], vec![1.0], 60)
    } else {
        (
            vec![1e-4 * lam_scale, 1e-5 * lam_scale, 1e-6 * lam_scale],
            vec![0.1, 1.0, 10.0],
            200,
        )
    };
    let datasets: Vec<(&str, usize)> = if ctx.quick {
        vec![("covtype", 4)]
    } else {
        vec![("covtype", 4), ("rcv1", 8)]
    };

    // Gap level whose first crossing we compare (relative to the gap at 0,
    // which is ≤ 1 for hinge).
    let target_gap = 1e-2;
    let mut cells: Vec<Cell> = Vec::new();
    let mut all_csv: Vec<Vec<f64>> = Vec::new();

    for (ds_name, k) in &datasets {
        let data = ctx.dataset(ds_name);
        let n = data.n();
        for &lambda in &lambdas {
            for &epochs in &epoch_grid {
                let mut histories = Vec::new();
                for plus in [true, false] {
                    let part = random_balanced(n, *k, ctx.seed);
                    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
                    let solver = SolverSpec::SdcaEpochs { epochs };
                    let cfg = if plus {
                        CocoaConfig::cocoa_plus(*k, Loss::Hinge, lambda, solver)
                    } else {
                        CocoaConfig::cocoa(*k, Loss::Hinge, lambda, solver)
                    }
                    .with_rounds(rounds)
                    .with_gap_tol(target_gap * 1e-2)
                    .with_seed(ctx.seed)
                    .with_parallel(true);
                    let mut trainer = Trainer::new(problem, part, cfg);
                    // Trainer::run == Driver::from_cocoa_config(&cfg).run(..)
                    let hist = trainer.run();
                    // CSV: method, lambda, epochs, round, vectors, time, gap
                    for r in &hist.records {
                        all_csv.push(vec![
                            if plus { 1.0 } else { 0.0 },
                            lambda,
                            epochs,
                            r.round as f64,
                            r.comm_vectors as f64,
                            r.sim_time_s,
                            r.gap,
                        ]);
                    }
                    histories.push((plus, hist));
                }

                let find = |plus: bool| {
                    histories
                        .iter()
                        .find(|(p, _)| *p == plus)
                        .and_then(|(_, h)| h.time_to_gap(target_gap))
                };
                let plus_hit = find(true);
                let avg_hit = find(false);
                cells.push(Cell {
                    dataset: ds_name.to_string(),
                    k: *k,
                    lambda,
                    epochs,
                    plus_vectors: plus_hit.map(|(_, _, v)| v as f64),
                    avg_vectors: avg_hit.map(|(_, _, v)| v as f64),
                    plus_time: plus_hit.map(|(_, t, _)| t),
                    avg_time: avg_hit.map(|(_, t, _)| t),
                });

                // One ASCII chart per cell (gap vs vectors, log-log).
                let series: Vec<Series> = histories
                    .iter()
                    .map(|(plus, h)| {
                        Series::new(
                            if *plus { "CoCoA+" } else { "CoCoA" },
                            h.records.iter().map(|r| r.comm_vectors as f64).collect(),
                            h.records.iter().map(|r| r.gap).collect(),
                            if *plus { '+' } else { 'o' },
                        )
                    })
                    .collect();
                let chart = render(
                    &format!(
                        "fig1 {ds_name} K={k} λ={lambda:.0e} H={epochs}·n_k  (gap vs vectors)"
                    ),
                    &series,
                    &PlotCfg::default(),
                );
                out.push_str(&chart);
                out.push('\n');
            }
        }
    }

    // Summary table of first crossings.
    out.push_str(&format!(
        "\nfirst crossing of gap ≤ {target_gap:.0e}:\n{:<9} {:>3} {:>8} {:>6} | {:>12} {:>12} | {:>11} {:>11}\n",
        "dataset", "K", "λ", "H·n_k", "vecs CoCoA+", "vecs CoCoA", "t+ (s)", "t (s)"
    ));
    let mut wins = 0usize;
    let mut decided = 0usize;
    for c in &cells {
        let fmt_opt = |v: Option<f64>| match v {
            Some(x) => format!("{x:.2}"),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<9} {:>3} {:>8.0e} {:>6} | {:>12} {:>12} | {:>11} {:>11}\n",
            c.dataset,
            c.k,
            c.lambda,
            c.epochs,
            fmt_opt(c.plus_vectors),
            fmt_opt(c.avg_vectors),
            fmt_opt(c.plus_time),
            fmt_opt(c.avg_time),
        ));
        match (c.plus_vectors, c.avg_vectors) {
            (Some(p), Some(a)) => {
                decided += 1;
                if p <= a {
                    wins += 1;
                }
            }
            (Some(_), None) => {
                decided += 1;
                wins += 1; // CoCoA never got there at all
            }
            _ => {}
        }
    }
    out.push_str(&format!(
        "CoCoA+ first-or-only to target in {wins}/{decided} decided cells \
         (paper: all cells)\n"
    ));

    let csv = report::csv::to_csv(
        &["is_plus", "lambda", "epochs", "round", "vectors", "sim_time_s", "gap"],
        &all_csv,
    );
    if let Ok(p) = report::write_result("fig1.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig1_cocoa_plus_wins() {
        let ctx = ExpContext {
            scale: 3000.0,
            quick: true,
            seed: 3,
        };
        let out = run(&ctx);
        assert!(out.contains("first crossing"));
        // the decided-cells summary line must show a strict majority for +
        let line = out
            .lines()
            .find(|l| l.contains("decided cells"))
            .expect("summary line");
        // parse "in W/D decided"
        let frag = line.split("in ").nth(1).unwrap();
        let mut it = frag.split(['/', ' ']);
        let w: usize = it.next().unwrap().parse().unwrap();
        let d: usize = it.next().unwrap().parse().unwrap();
        assert!(d > 0 && w * 2 >= d, "CoCoA+ won only {w}/{d}:\n{out}");
    }
}
