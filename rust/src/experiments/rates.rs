//! Corollaries 9/11 rate check (a beyond-the-figures extension): measure
//! the number of outer rounds to a fixed dual suboptimality as K grows,
//! for averaging (γ=1/K, σ'=1) vs adding (γ=1, σ'=K), on both a
//! non-smooth (hinge, Cor. 9) and a smooth (smoothed hinge, Cor. 11) loss.
//!
//! Theory predicts T ∝ K for averaging and T independent of K for adding
//! (worst case). Measured rounds are reported next to the prediction, and
//! the measured local quality Θ (solver/theta.rs) is shown so the
//! constants can be sanity-checked against the bounds.

use crate::baselines::serial_sdca;
use crate::coordinator::{CocoaConfig, SolverSpec, StopReason, Trainer};
use crate::data::partition::random_balanced;
use crate::driver::{Driver, StopPolicy};
use crate::experiments::ExpContext;
use crate::loss::Loss;
use crate::objective::Problem;
use crate::report;
use crate::solver::theta::estimate_theta;
use crate::solver::LocalSolveCtx;
use crate::subproblem::{LocalBlock, SubproblemSpec};

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    let ks: Vec<usize> = if ctx.quick {
        vec![2, 8]
    } else {
        vec![2, 4, 8, 16]
    };
    let lambda = 1e-2;
    let eps_d = 1e-3;
    let max_rounds = if ctx.quick { 150 } else { 600 };
    let losses = [
        ("hinge (Cor. 9, non-smooth)", Loss::Hinge),
        (
            "smoothed hinge (Cor. 11, smooth)",
            Loss::SmoothedHinge { mu: 0.5 },
        ),
    ];
    let data = ctx.dataset("covtype");
    let n = data.n();
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();

    for (label, loss) in losses {
        let problem = Problem::new(data.clone(), loss, lambda);
        let d_star = serial_sdca::estimate_d_star(&problem, ctx.seed);
        out.push_str(&format!("\n{label}: n={n} λ={lambda} ε_D={eps_d} D*≈{d_star:.6}\n"));
        out.push_str(&format!(
            "{:>4} {:>14} {:>14} {:>8} {:>8}\n",
            "K", "rounds (add)", "rounds (avg)", "Θ(add)", "Θ(avg)"
        ));
        for &k in &ks {
            let rounds_for = |plus: bool| -> Option<usize> {
                let part = random_balanced(n, k, ctx.seed);
                let problem = Problem::new(data.clone(), loss, lambda);
                let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
                let cfg = if plus {
                    CocoaConfig::cocoa_plus(k, loss, lambda, solver)
                } else {
                    CocoaConfig::cocoa(k, loss, lambda, solver)
                }
                .with_seed(ctx.seed)
                .with_parallel(true);
                let mut trainer = Trainer::new(problem, part, cfg);
                // Rounds to the ε_D dual target, via the Driver's
                // dual-target stop rule (gap stopping disabled).
                let mut driver = Driver::new(
                    StopPolicy::new(max_rounds)
                        .with_gap_tol(f64::NEG_INFINITY)
                        .with_divergence_gap(f64::INFINITY)
                        .with_dual_target(d_star, eps_d),
                );
                let hist = driver.run(&mut trainer);
                (hist.stop == StopReason::DualTargetReached).then(|| hist.rounds_run())
            };
            // Θ of a 1-epoch SDCA pass on the first block of each regime.
            let theta_for = |sigma_prime: f64| -> f64 {
                let part = random_balanced(n, k, ctx.seed);
                let block = LocalBlock::from_partition(&data, &part.parts[0]);
                let spec = SubproblemSpec {
                    loss,
                    lambda,
                    n_global: n,
                    sigma_prime,
                    k,
                };
                let w = vec![0.0; data.d()];
                let alpha = vec![0.0; block.n_local()];
                let ctx2 = LocalSolveCtx {
                    block: &block,
                    spec: &spec,
                    w: &w,
                    alpha_local: &alpha,
                };
                let mut s =
                    crate::solver::sdca::SdcaSolver::new(block.n_local(), ctx.seed);
                estimate_theta(&mut s, &ctx2, 40, ctx.seed).theta
            };
            let r_add = rounds_for(true);
            let r_avg = rounds_for(false);
            let th_add = theta_for(k as f64);
            let th_avg = theta_for(1.0);
            let fmt = |v: Option<usize>| v.map(|r| r.to_string()).unwrap_or("-".into());
            out.push_str(&format!(
                "{:>4} {:>14} {:>14} {:>8.3} {:>8.3}\n",
                k,
                fmt(r_add),
                fmt(r_avg),
                th_add,
                th_avg
            ));
            csv_rows.push(vec![
                if loss.smoothness_mu().is_some() { 1.0 } else { 0.0 },
                k as f64,
                r_add.map(|r| r as f64).unwrap_or(f64::NAN),
                r_avg.map(|r| r as f64).unwrap_or(f64::NAN),
                th_add,
                th_avg,
            ]);
        }
        // Shape check: adding's rounds should grow much slower than K.
        let rows: Vec<&Vec<f64>> = csv_rows
            .iter()
            .filter(|r| {
                (r[0] > 0.5) == loss.smoothness_mu().is_some() && r[2].is_finite() && r[3].is_finite()
            })
            .collect();
        if rows.len() >= 2 {
            let first = rows[0];
            let last = rows[rows.len() - 1];
            let k_growth = last[1] / first[1];
            let add_growth = last[2] / first[2];
            let avg_growth = last[3] / first[3];
            out.push_str(&format!(
                "K grew {k_growth:.0}×: rounds(add) grew {add_growth:.2}×, rounds(avg) grew {avg_growth:.2}× \
                 (theory: ~1× vs ~{k_growth:.0}×)\n"
            ));
        }
    }

    let csv = report::csv::to_csv(
        &["is_smooth", "k", "rounds_add", "rounds_avg", "theta_add", "theta_avg"],
        &csv_rows,
    );
    if let Ok(p) = report::write_result("rates.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_rates_runs() {
        let ctx = ExpContext {
            scale: 4000.0,
            quick: true,
            seed: 9,
        };
        let out = run(&ctx);
        assert!(out.contains("Cor. 9"));
        assert!(out.contains("rounds (add)"));
    }
}
