//! Paper-experiment harness: one module per table/figure of the
//! evaluation section, each regenerating the corresponding rows/series
//! (CSV under `results/` + ASCII plots + stdout summary).
//!
//! | module   | paper artifact | claim it reproduces                         |
//! |----------|----------------|---------------------------------------------|
//! | table1   | Table 1        | (n²/K)/σ ≫ 1 and shrinking with K           |
//! | table2   | Table 2        | dataset signatures (n, d, sparsity)          |
//! | fig1     | Figure 1       | CoCoA+ beats CoCoA per-comm & per-second across λ, H |
//! | fig2     | Figure 2       | strong scaling: time-to-ε flat in K (CoCoA+) vs degrading (CoCoA) vs mini-batch SGD |
//! | fig3     | Figure 3       | σ' sweep at γ=1: fastest below γK, divergent when too small |
//! | rates    | Cor. 9/11      | measured round counts vs the theoretical K-(in)dependence |
//! | ablation | (extension)    | full (γ, σ') grid: the safe diagonal σ'=γK and the divergence frontier |
//!
//! Absolute times differ from the 2015 Spark/EC2 testbed by construction;
//! the *shapes* (ordering, crossovers, divergences, scaling slopes) are
//! the reproduction targets. See EXPERIMENTS.md for recorded outputs.

pub mod ablation;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod rates;
pub mod table1;
pub mod table2;

use crate::data::Dataset;
use crate::util::cli::Args;

/// Shared experiment knobs (from the CLI).
#[derive(Clone, Debug)]
pub struct ExpContext {
    /// Downscale factor applied to the paper's dataset sizes.
    pub scale: f64,
    /// Quick mode: fewer grid cells / rounds, for CI and smoke runs.
    pub quick: bool,
    pub seed: u64,
}

impl ExpContext {
    pub fn from_args(args: &Args) -> ExpContext {
        ExpContext {
            scale: args.get_f64("scale", 500.0),
            quick: args.get_bool("quick", false),
            seed: args.get_u64("seed", 42),
        }
    }

    pub fn dataset(&self, which: &str) -> Dataset {
        crate::data::synth::paper_dataset(which, self.scale, self.seed)
    }
}

/// Stable numeric id for a dataset name (CSV column encoding).
pub fn dataset_id(name: &str) -> f64 {
    match name {
        "news" => 0.0,
        "real-sim" => 1.0,
        "rcv1" => 2.0,
        "covtype" => 3.0,
        "epsilon" => 4.0,
        _ => -1.0,
    }
}

/// CLI entry: `cocoa experiment <name> [--quick] [--scale s] [--seed s]`.
pub fn run_from_cli(args: &Args) -> i32 {
    let ctx = ExpContext::from_args(args);
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let t0 = std::time::Instant::now();
    let result = match which {
        "table1" => table1::run(&ctx),
        "table2" => table2::run(&ctx),
        "fig1" => fig1::run(&ctx),
        "fig2" => fig2::run(&ctx),
        "fig3" => fig3::run(&ctx),
        "rates" => rates::run(&ctx),
        "ablation" => ablation::run(&ctx),
        "all" => {
            let mut out = String::new();
            for (name, f) in [
                ("table2", table2::run as fn(&ExpContext) -> String),
                ("table1", table1::run),
                ("fig1", fig1::run),
                ("fig2", fig2::run),
                ("fig3", fig3::run),
                ("rates", rates::run),
                ("ablation", ablation::run),
            ] {
                crate::log_info!("=== experiment {name} ===");
                out.push_str(&format!("\n===== {name} =====\n"));
                out.push_str(&f(&ctx));
            }
            out
        }
        other => {
            eprintln!("unknown experiment {other:?} (table1|table2|fig1|fig2|fig3|rates|ablation|all)");
            return 2;
        }
    };
    println!("{result}");
    println!("[experiment {which} done in {:.1}s]", t0.elapsed().as_secs_f64());
    let _ = crate::report::write_result(&format!("{which}_summary.txt"), &result);
    0
}
