//! Ablation: the full (γ, σ') design grid between the paper's two named
//! regimes. Lemma 4 says σ' ≥ γK is safe for *any* γ ∈ (0, 1]; the named
//! presets are just the corners (γ=1/K, σ'=1) and (γ=1, σ'=K). This sweep
//! maps the whole frontier: for each γ we run σ' ∈ {½γK, γK, 2γK} and
//! report rounds-to-ε + divergence, validating that
//!   (i) the safe diagonal σ' = γK converges for every γ,
//!  (ii) convergence speeds up monotonically with γ along the diagonal
//!       (the continuous version of "adding beats averaging"),
//! (iii) below the diagonal is where all divergence lives.

use crate::coordinator::{Aggregation, CocoaConfig, SolverSpec, StopReason, Trainer};
use crate::data::partition::random_balanced;
use crate::experiments::ExpContext;
use crate::loss::Loss;
use crate::objective::Problem;
use crate::report;

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    let k = 8usize;
    let data = ctx.dataset("covtype");
    let n = data.n();
    let lambda = 0.3 / n as f64; // weakly regularized: the interesting regime
    let tol = 1e-2;
    let rounds = if ctx.quick { 160 } else { 250 };
    let gammas: Vec<f64> = if ctx.quick {
        vec![1.0 / k as f64, 0.5, 1.0]
    } else {
        vec![1.0 / k as f64, 0.25, 0.5, 0.75, 1.0]
    };
    let multipliers = [0.5, 1.0, 2.0]; // σ' as multiple of the safe γK

    out.push_str(&format!(
        "ablation: covtype-like n={n} d={} K={k} λn={:.2}; grid γ × σ'/(γK)\n",
        data.d(),
        lambda * n as f64
    ));
    out.push_str(&format!(
        "{:>6} {:>8} {:>8} {:>14} {:>10}\n",
        "γ", "σ'", "σ'/γK", "rounds→tgt", "status"
    ));

    let mut csv_rows: Vec<Vec<f64>> = Vec::new();
    let mut diagonal: Vec<(f64, Option<usize>)> = Vec::new();
    for &gamma in &gammas {
        for &mult in &multipliers {
            let sigma_prime = mult * gamma * k as f64;
            let part = random_balanced(n, k, ctx.seed);
            let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
            let cfg = CocoaConfig {
                aggregation: Aggregation::Gamma(gamma),
                ..CocoaConfig::cocoa_plus(
                    k,
                    Loss::Hinge,
                    lambda,
                    SolverSpec::SdcaEpochs { epochs: 1.0 },
                )
            }
            .with_sigma_prime(sigma_prime)
            .with_rounds(rounds)
            .with_gap_tol(tol)
            .with_seed(ctx.seed);
            let mut t = Trainer::new(problem, part, cfg);
            // Trainer::run == Driver::from_cocoa_config(&cfg).run(..)
            let hist = t.run();
            let hit = hist.time_to_gap(tol).map(|(r, _, _)| r + 1);
            let first_gap = hist.records.first().map(|r| r.gap).unwrap_or(f64::INFINITY);
            let status = match hist.stop {
                StopReason::Diverged => "DIVERGED",
                _ if hit.is_some() => "converged",
                _ if hist.final_gap() > first_gap.max(1.0) * 5.0 => "DIVERGING",
                _ => "slow",
            };
            out.push_str(&format!(
                "{:>6.3} {:>8.2} {:>8.1} {:>14} {:>10}\n",
                gamma,
                sigma_prime,
                mult,
                hit.map(|r| r.to_string()).unwrap_or("-".into()),
                status
            ));
            csv_rows.push(vec![
                gamma,
                sigma_prime,
                mult,
                hit.map(|r| r as f64).unwrap_or(f64::NAN),
                if status.starts_with("DIVERG") { 1.0 } else { 0.0 },
            ]);
            if mult == 1.0 {
                diagonal.push((gamma, hit));
            }
        }
    }

    // Claim checks.
    let diag_all_converged = diagonal.iter().all(|(_, hit)| hit.is_some());
    out.push_str(&format!(
        "\nsafe diagonal σ'=γK converges for every γ: {}\n",
        if diag_all_converged { "HOLDS" } else { "VIOLATED" }
    ));
    if diagonal.len() >= 2 && diag_all_converged {
        let first = diagonal.first().unwrap();
        let last = diagonal.last().unwrap();
        out.push_str(&format!(
            "rounds along the diagonal: γ={:.3} → {} rounds; γ={:.3} → {} rounds ({})\n",
            first.0,
            first.1.unwrap(),
            last.0,
            last.1.unwrap(),
            if last.1.unwrap() <= first.1.unwrap() {
                "more aggressive γ is faster — HOLDS"
            } else {
                "NOT OBSERVED at this scale"
            }
        ));
    }

    let csv = report::csv::to_csv(
        &["gamma", "sigma_prime", "safe_multiple", "rounds_to_tgt", "diverged"],
        &csv_rows,
    );
    if let Ok(p) = report::write_result("ablation.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_ablation_safe_diagonal_holds() {
        let ctx = ExpContext {
            scale: 2000.0,
            quick: true,
            seed: 11,
        };
        let out = run(&ctx);
        assert!(
            out.contains("safe diagonal σ'=γK converges for every γ: HOLDS"),
            "{out}"
        );
    }
}
