//! Figure 2: strong scaling — time to reach an ε_D-accurate dual solution
//! as the number of machines K grows, data size fixed.
//!
//! Methods: CoCoA+ (γ=1, σ'=K), CoCoA (γ=1/K, σ'=1), and distributed
//! mini-batch SGD. The paper's result on 100 machines: CoCoA+ ~2× faster
//! than CoCoA on epsilon and ~7× on rcv1, with mini-batch SGD an order
//! slower; CoCoA degrades roughly linearly in K while CoCoA+ is flat or
//! improving. We reproduce the *scaling shape* on the synthetic analogues:
//! the CoCoA+/CoCoA time ratio must grow with K, and SGD must trail both.
//!
//! ε_D-accuracy needs D(α*): estimated once per dataset by a long serial
//! SDCA run (baselines::serial_sdca), exactly as one would calibrate the
//! paper's y-axis.

use crate::baselines::minibatch_sgd::{MiniBatchSgd, MiniBatchSgdConfig};
use crate::baselines::serial_sdca;
use crate::coordinator::{CocoaConfig, SolverSpec, StopReason, Trainer};
use crate::data::partition::random_balanced;
use crate::driver::{Driver, StopPolicy};
use crate::experiments::ExpContext;
use crate::loss::Loss;
use crate::objective::Problem;
use crate::report::ascii_plot::{render, PlotCfg, Series};
use crate::report::{self};

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    let (ks, datasets, rounds): (Vec<usize>, Vec<&str>, usize) = if ctx.quick {
        (vec![2, 4, 8], vec!["epsilon"], 150)
    } else {
        (vec![2, 4, 8, 16, 32], vec!["epsilon", "rcv1"], 400)
    };
    let lambda = 1e-3;
    let eps_d = 1e-3; // dual suboptimality target
    let mut csv_rows: Vec<Vec<f64>> = Vec::new();

    for ds_name in &datasets {
        let data = ctx.dataset(ds_name);
        let n = data.n();
        let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
        let d_star = serial_sdca::estimate_d_star(&problem, ctx.seed);
        out.push_str(&format!(
            "\n{ds_name}: n={n} d={} D(α*)≈{d_star:.8}\n",
            data.d()
        ));
        out.push_str(&format!(
            "{:>4} {:>14} {:>14} {:>14} {:>9}\n",
            "K", "CoCoA+ t(s)", "CoCoA t(s)", "mb-SGD t(s)", "+/avg"
        ));

        let mut xs = Vec::new();
        let (mut t_plus_s, mut t_avg_s, mut t_sgd_s) = (Vec::new(), Vec::new(), Vec::new());
        // Measured per-round overhead of the persistent-pool runtime
        // (barrier + reduce) across all CoCoA/CoCoA+ runs — reported so
        // scaling curves can be sanity-checked against runtime cost.
        let mut overhead_us: Vec<f64> = Vec::new();
        for &k in &ks {
            if k > n / 4 {
                continue;
            }
            let mut time_for = |plus: bool| -> Option<f64> {
                let part = random_balanced(n, k, ctx.seed);
                let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
                let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
                let cfg = if plus {
                    CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, solver)
                } else {
                    CocoaConfig::cocoa(k, Loss::Hinge, lambda, solver)
                }
                .with_seed(ctx.seed)
                .with_parallel(true);
                let mut trainer = Trainer::new(problem, part, cfg);
                // Dual-target ε_D stopping is a Driver rule now: per-round
                // certificates, stop once D(α*) − D(α) ≤ ε_D, gap ignored.
                let mut driver = Driver::new(
                    StopPolicy::new(rounds)
                        .with_gap_tol(f64::NEG_INFINITY)
                        .with_divergence_gap(f64::INFINITY)
                        .with_dual_target(d_star, eps_d),
                );
                let hist = driver.run(&mut trainer);
                overhead_us.push(trainer.comm_stats().runtime_overhead_per_round_s() * 1e6);
                if hist.stop == StopReason::DualTargetReached {
                    hist.records.last().map(|r| r.sim_time_s)
                } else {
                    None
                }
            };
            let t_plus = time_for(true);
            let t_avg = time_for(false);

            // mini-batch SGD to the matching primal target P* ≈ D(α*)+ε.
            let t_sgd = {
                let part = random_balanced(n, k, ctx.seed);
                let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
                let mut cfg = MiniBatchSgdConfig::new(k);
                cfg.max_rounds = rounds * 20;
                cfg.gap_every = 20;
                cfg.gap_tol = eps_d;
                cfg.seed = ctx.seed;
                let mut sgd = MiniBatchSgd::new(problem, part, cfg);
                let h = sgd.run(Some(d_star));
                h.time_to_gap(eps_d).map(|(_, t, _)| t)
            };

            let fmt = |v: Option<f64>| v.map(|t| format!("{t:.3}")).unwrap_or("-".into());
            let ratio = match (t_plus, t_avg) {
                (Some(p), Some(a)) if p > 0.0 => format!("{:.2}x", a / p),
                _ => "-".into(),
            };
            out.push_str(&format!(
                "{:>4} {:>14} {:>14} {:>14} {:>9}\n",
                k,
                fmt(t_plus),
                fmt(t_avg),
                fmt(t_sgd),
                ratio
            ));
            csv_rows.push(vec![
                super::dataset_id(ds_name),
                k as f64,
                t_plus.unwrap_or(f64::NAN),
                t_avg.unwrap_or(f64::NAN),
                t_sgd.unwrap_or(f64::NAN),
            ]);
            xs.push(k as f64);
            t_plus_s.push(t_plus.unwrap_or(f64::NAN));
            t_avg_s.push(t_avg.unwrap_or(f64::NAN));
            t_sgd_s.push(t_sgd.unwrap_or(f64::NAN));
        }

        if !overhead_us.is_empty() {
            let mean = overhead_us.iter().sum::<f64>() / overhead_us.len() as f64;
            out.push_str(&format!(
                "pool runtime overhead: {mean:.1}µs/round mean over {} runs (excluded from compute axis)\n",
                overhead_us.len()
            ));
        }

        let chart = render(
            &format!("fig2 {ds_name}: time to ε_D={eps_d:.0e} vs K (log-log)"),
            &[
                Series::new("CoCoA+", xs.clone(), t_plus_s.clone(), '+'),
                Series::new("CoCoA", xs.clone(), t_avg_s.clone(), 'o'),
                Series::new("mb-SGD", xs.clone(), t_sgd_s.clone(), 's'),
            ],
            &PlotCfg::default(),
        );
        out.push_str(&chart);

        // Scaling-shape check: ratio at max K ≥ ratio at min K.
        if xs.len() >= 2 {
            let first_ratio = t_avg_s[0] / t_plus_s[0];
            let last_ratio = t_avg_s[xs.len() - 1] / t_plus_s[xs.len() - 1];
            out.push_str(&format!(
                "CoCoA/CoCoA+ time ratio: {:.2}x at K={} → {:.2}x at K={}  ({})\n",
                first_ratio,
                xs[0],
                last_ratio,
                xs[xs.len() - 1],
                if last_ratio >= first_ratio * 0.8 {
                    "scaling advantage HOLDS"
                } else {
                    "scaling advantage NOT OBSERVED"
                }
            ));
        }
    }

    let csv = report::csv::to_csv(
        &["dataset_id", "k", "t_cocoa_plus", "t_cocoa", "t_minibatch_sgd"],
        &csv_rows,
    );
    if let Ok(p) = report::write_result("fig2.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig2_produces_scaling_table() {
        let ctx = ExpContext {
            scale: 4000.0,
            quick: true,
            seed: 5,
        };
        let out = run(&ctx);
        assert!(out.contains("time to ε_D"), "{out}");
        assert!(out.contains("CoCoA+"));
    }
}
