//! Table 2: dataset statistics (n, d, sparsity). For the synthetic
//! analogues this verifies the generators hit the paper's signatures at
//! the configured downscale — the *shape* inputs every other experiment
//! depends on.

use crate::experiments::ExpContext;
use crate::report;

/// Paper's Table 2 (plus the two appendix datasets used by Table 1).
const PAPER: &[(&str, usize, usize, f64)] = &[
    ("covtype", 522_911, 54, 0.2222),
    ("epsilon", 400_000, 2_000, 1.0),
    ("rcv1", 677_399, 47_236, 0.0016),
    ("news", 19_996, 1_355_191, 0.0003),
    ("real-sim", 72_309, 20_958, 0.0025),
];

pub fn run(ctx: &ExpContext) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>9} {:>9} {:>9} | {:>10} {:>8} {:>9}  (paper @ scale 1)\n",
        "dataset", "n", "d", "density", "paper n", "paper d", "density"
    ));
    let mut rows = Vec::new();
    let names: Vec<&str> = if ctx.quick {
        vec!["covtype", "rcv1"]
    } else {
        PAPER.iter().map(|r| r.0).collect()
    };
    for name in names {
        let (pname, pn, pd, pdens) = PAPER.iter().find(|r| r.0 == name).unwrap();
        let data = ctx.dataset(name);
        out.push_str(&format!(
            "{:<10} {:>9} {:>9} {:>9.4} | {:>10} {:>8} {:>9.4}\n",
            pname,
            data.n(),
            data.d(),
            data.density(),
            pn,
            pd,
            pdens
        ));
        rows.push(vec![
            data.n() as f64,
            data.d() as f64,
            data.density(),
            *pn as f64,
            *pd as f64,
            *pdens,
        ]);
    }
    let csv = crate::report::csv::to_csv(
        &["n", "d", "density", "paper_n", "paper_d", "paper_density"],
        &rows,
    );
    if let Ok(p) = report::write_result("table2.csv", &csv) {
        out.push_str(&format!("[csv: {}]\n", p.display()));
    }
    out.push_str(&format!("(scale = {}; real LibSVM files drop in via --data)\n", ctx.scale));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_table2_reports_signatures() {
        let ctx = ExpContext {
            scale: 2000.0,
            quick: true,
            seed: 1,
        };
        let out = run(&ctx);
        assert!(out.contains("covtype"));
        assert!(out.contains("rcv1"));
    }

    #[test]
    fn generated_sparsity_tracks_paper_within_factor() {
        let ctx = ExpContext {
            scale: 1000.0,
            quick: false,
            seed: 2,
        };
        // covtype ~22% dense: generator should land within 2x.
        let cov = ctx.dataset("covtype");
        assert!((0.1..0.5).contains(&cov.density()), "{}", cov.density());
        // epsilon fully dense.
        let eps = ctx.dataset("epsilon");
        assert!((eps.density() - 1.0).abs() < 1e-9);
    }
}
