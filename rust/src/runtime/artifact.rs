//! The AOT artifact manifest: the contract between `python/compile/aot.py`
//! (producer) and the PJRT runtime (consumer).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io error reading {path}: {source}")]
    Io {
        path: String,
        source: std::io::Error,
    },
    #[error("manifest parse error: {0}")]
    Parse(String),
    #[error("manifest missing field {0:?}")]
    Missing(&'static str),
    #[error("no artifact of kind {0:?} in manifest")]
    NoSuchKind(String),
}

/// Tensor signature of one positional input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled computation.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub loss: String,
    pub file: String,
    pub dims: BTreeMap<String, usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

impl ArtifactEntry {
    pub fn dim(&self, key: &str) -> Option<usize> {
        self.dims.get(key).copied()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dtype: String,
    pub entries: Vec<ArtifactEntry>,
}

fn tensor_specs(j: &Json, field: &'static str) -> Result<Vec<TensorSpec>, ManifestError> {
    let arr = j
        .get(field)
        .and_then(|v| v.as_arr())
        .ok_or(ManifestError::Missing(field))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or(ManifestError::Missing("tensor.name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or(ManifestError::Missing("tensor.shape"))?
                .iter()
                .map(|s| s.as_usize().ok_or(ManifestError::Missing("tensor.shape[i]")))
                .collect::<Result<Vec<_>, _>>()?;
            let dtype = t
                .get("dtype")
                .and_then(|v| v.as_str())
                .ok_or(ManifestError::Missing("tensor.dtype"))?
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|source| ManifestError::Io {
            path: path.display().to_string(),
            source,
        })?;
        let j = Json::parse(&text).map_err(ManifestError::Parse)?;
        let dtype = j
            .get("dtype")
            .and_then(|v| v.as_str())
            .ok_or(ManifestError::Missing("dtype"))?
            .to_string();
        let entries_json = j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or(ManifestError::Missing("entries"))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let get_str = |k: &'static str| -> Result<String, ManifestError> {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or(ManifestError::Missing(k))
            };
            let mut dims = BTreeMap::new();
            if let Some(Json::Obj(m)) = e.get("dims") {
                for (k, v) in m {
                    if let Some(x) = v.as_usize() {
                        dims.insert(k.clone(), x);
                    }
                }
            }
            entries.push(ArtifactEntry {
                name: get_str("name")?,
                kind: get_str("kind")?,
                loss: get_str("loss")?,
                file: get_str("file")?,
                dims,
                inputs: tensor_specs(e, "inputs")?,
                outputs: tensor_specs(e, "outputs")?,
                sha256: get_str("sha256").unwrap_or_default(),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype,
            entries,
        })
    }

    /// First entry of a given kind (optionally filtered by loss).
    pub fn find(&self, kind: &str) -> Result<&ArtifactEntry, ManifestError> {
        self.entries
            .iter()
            .find(|e| e.kind == kind)
            .ok_or_else(|| ManifestError::NoSuchKind(kind.to_string()))
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

/// Locate the artifacts directory: `COCOA_ARTIFACTS_DIR`, else ./artifacts,
/// walking up a few parents (tests run from target subdirs).
pub fn default_artifacts_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("COCOA_ARTIFACTS_DIR") {
        let p = PathBuf::from(d);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    for _ in 0..4 {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            break;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
          "version": 1, "dtype": "f64",
          "entries": [{
            "name": "t1", "kind": "local_sdca", "loss": "hinge",
            "file": "t1.hlo.txt", "dims": {"m": 4, "d": 2, "h": 8},
            "inputs": [{"name": "x", "shape": [4, 2], "dtype": "f64"}],
            "outputs": [{"name": "da", "shape": [4], "dtype": "f64"}],
            "sha256": "00"
          }]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_fixture() {
        let dir = std::env::temp_dir().join("cocoa_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dtype, "f64");
        assert_eq!(m.entries.len(), 1);
        let e = m.find("local_sdca").unwrap();
        assert_eq!(e.dim("m"), Some(4));
        assert_eq!(e.inputs[0].shape, vec![4, 2]);
        assert_eq!(e.inputs[0].elements(), 8);
        assert!(m.hlo_path(e).ends_with("t1.hlo.txt"));
        assert!(m.find("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        let err = Manifest::load(Path::new("/nonexistent/xyz")).unwrap_err();
        assert!(matches!(err, ManifestError::Io { .. }));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must parse and contain both kinds.
        if let Some(dir) = default_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("local_sdca").is_ok());
            assert!(m.find("duality_gap").is_ok());
        }
    }
}
