//! The XLA-backed local solver: the same [`LocalSolver`] contract as the
//! native Rust SDCA, but the inner loop executes the AOT-compiled
//! L2/L1 graph (`local_sdca` → Pallas SDCA kernel) through PJRT.
//!
//! The coordinate index sequence is generated here with the *same* PCG
//! stream the native solver uses, so `XlaSdcaSolver` and
//! [`crate::solver::sdca::SdcaSolver`] produce bit-comparable trajectories
//! (asserted by `rust/tests/xla_runtime.rs`).
//!
//! Shapes are monomorphic: the worker's block is zero-padded to the
//! artifact's (m, d); padding rows carry q_i = 0 and are predicated out
//! inside the kernel.

use crate::runtime::artifact::{ArtifactEntry, Manifest};
use crate::runtime::pjrt::{
    literal_f64_matrix, literal_f64_vec, literal_i32_vec, to_f64_vec, Executable, PjrtRuntime,
};
use crate::solver::{LocalSolveCtx, LocalSolver, LocalUpdate};
use crate::subproblem::LocalBlock;
use crate::util::rng::Pcg32;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;

/// Shared runtime + compiled executable, reused across workers.
pub struct XlaSdcaProgram {
    pub exe: Executable,
    pub m: usize,
    pub d: usize,
    pub h: usize,
}

impl XlaSdcaProgram {
    pub fn load(rt: &PjrtRuntime, manifest: &Manifest) -> Result<XlaSdcaProgram> {
        let entry = manifest.find("local_sdca")?;
        Self::load_entry(rt, manifest, entry)
    }

    pub fn load_entry(
        rt: &PjrtRuntime,
        manifest: &Manifest,
        entry: &ArtifactEntry,
    ) -> Result<XlaSdcaProgram> {
        let exe = rt.load_hlo_text(&manifest.hlo_path(entry))?;
        Ok(XlaSdcaProgram {
            exe,
            m: entry.dim("m").context("manifest missing dim m")?,
            d: entry.dim("d").context("manifest missing dim d")?,
            h: entry.dim("h").context("manifest missing dim h")?,
        })
    }
}

/// Per-worker XLA solver instance. Holds the padded dense copies of the
/// block (packed once) and the PCG stream for index generation.
pub struct XlaSdcaSolver {
    program: Arc<XlaSdcaProgram>,
    /// Rounds of H steps per outer round (the artifact's h is the unit).
    pub repeats: usize,
    rng: Pcg32,
    n_local: usize,
    x_lit: xla::Literal,
    y_pad: Vec<f64>,
    qi_pad: Vec<f64>,
    lambda_n: f64,
    sigma_prime: f64,
}

impl XlaSdcaSolver {
    /// Pack a worker's block against the compiled program.
    ///
    /// `lambda_n` = λ·n_global and `sigma_prime` must match the trainer's
    /// SubproblemSpec (they are baked into the executed scalars each call,
    /// not into the artifact).
    pub fn new(
        program: Arc<XlaSdcaProgram>,
        block: &LocalBlock,
        lambda_n: f64,
        sigma_prime: f64,
        seed: u64,
    ) -> Result<XlaSdcaSolver> {
        let (m, d) = (program.m, program.d);
        ensure!(
            block.n_local() <= m,
            "block has {} rows but artifact is compiled for m={}; \
             rebuild artifacts with a larger --m",
            block.n_local(),
            m
        );
        ensure!(
            block.d() <= d,
            "block has {} features but artifact d={}",
            block.d(),
            d
        );
        // Zero-pad the dense copy: rows beyond n_local stay zero with q=0.
        let mut x_dense = vec![0.0f64; m * d];
        for i in 0..block.n_local() {
            let (idx, vals) = block.x().row(i);
            for (j, &c) in idx.iter().enumerate() {
                x_dense[i * d + c as usize] = vals[j];
            }
        }
        let mut y_pad = vec![1.0f64; m];
        y_pad[..block.n_local()].copy_from_slice(block.y());
        let mut qi_pad = vec![0.0f64; m];
        qi_pad[..block.n_local()].copy_from_slice(block.norms_sq());
        let x_lit = literal_f64_matrix(&x_dense, m, d)?;
        Ok(XlaSdcaSolver {
            program,
            repeats: 1,
            rng: Pcg32::new(seed, 101), // same stream tag as SdcaSolver
            n_local: block.n_local(),
            x_lit,
            y_pad,
            qi_pad,
            lambda_n,
            sigma_prime,
        })
    }

    pub fn with_repeats(mut self, repeats: usize) -> Self {
        self.repeats = repeats.max(1);
        self
    }

    /// Total inner steps per outer round.
    pub fn steps_per_round(&self) -> usize {
        self.program.h * self.repeats
    }

    fn call_once(
        &self,
        w: &[f64],
        alpha_pad: &[f64],
        indices: &[i32],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let (m, d, h) = (self.program.m, self.program.d, self.program.h);
        ensure!(indices.len() == h);
        ensure!(alpha_pad.len() == m);
        let mut w_pad = vec![0.0f64; d];
        w_pad[..w.len()].copy_from_slice(w);
        let out = self.program.exe.call(&[
            self.x_lit.clone(),
            literal_f64_vec(&self.y_pad),
            literal_f64_vec(alpha_pad),
            literal_f64_vec(&w_pad),
            literal_f64_vec(&self.qi_pad),
            literal_i32_vec(indices),
            literal_f64_vec(&[self.lambda_n, self.sigma_prime]),
        ])?;
        ensure!(out.len() == 2, "local_sdca must return (Δα, Δw)");
        Ok((to_f64_vec(&out[0])?, to_f64_vec(&out[1])?))
    }
}

impl LocalSolver for XlaSdcaSolver {
    fn name(&self) -> String {
        format!(
            "xla_sdca(H={}x{},m={},d={})",
            self.program.h, self.repeats, self.program.m, self.program.d
        )
    }

    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        debug_assert_eq!(ctx.block.n_local(), self.n_local);
        debug_assert!((ctx.spec.lambda * ctx.spec.n_global as f64 - self.lambda_n).abs() < 1e-12);
        let (m, h) = (self.program.m, self.program.h);
        let d_model = self.program.d;
        let d_block = ctx.block.d();
        out.reset(self.n_local, d_block);

        let mut alpha_pad = vec![0.0f64; m];
        alpha_pad[..self.n_local].copy_from_slice(ctx.alpha_local);
        let mut w_cur: Vec<f64> = ctx.w.to_vec();

        for _ in 0..self.repeats {
            // Same index-generation contract as the native SdcaSolver:
            // uniform over the *real* rows only.
            let indices: Vec<i32> = (0..h)
                .map(|_| self.rng.gen_range(self.n_local) as i32)
                .collect();
            let (da, dw) = self
                .call_once(&w_cur, &alpha_pad, &indices)
                .expect("XLA local_sdca execution failed");
            for i in 0..self.n_local {
                alpha_pad[i] += da[i];
                out.delta_alpha[i] += da[i];
            }
            for j in 0..d_block {
                out.delta_w[j] += dw[j];
                // chained repeats continue from the locally updated image
                w_cur[j] += self.sigma_prime * dw[j];
            }
            let _ = d_model;
        }
        out.steps = h * self.repeats;
    }

    fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 101);
    }
}

// SAFETY: every field is either plainly `Send` (PCG state, padded f64
// buffers, scalars) or justified here:
// * `program: Arc<XlaSdcaProgram>` — the shared compiled program is held
//   behind an `Arc` (atomic refcount) precisely so clones of one program
//   may be *moved* to different worker threads; `PjRtLoadedExecutable`
//   wraps a thread-safe PJRT CPU executable (TfrtCpuClient supports
//   concurrent Execute calls). An `Rc` here would be unsound: solvers
//   built from one program and moved onto pool threads would race the
//   non-atomic refcount on drop.
// * `x_lit: xla::Literal` — an owned host buffer; it is only read, and
//   only by whichever thread owns the solver (the coordinator moves whole
//   workers, never shares one).
// We still default all XLA runs to `parallel=false`; this impl exists so
// the type satisfies the `LocalSolver: Send` bound.
unsafe impl Send for XlaSdcaSolver {}
