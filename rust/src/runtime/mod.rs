//! Runtime bridge: AOT artifacts (HLO text + manifest) loaded and executed
//! via PJRT from the Rust coordinator. Python is never on this path — it
//! produced the artifacts once at build time (`make artifacts`).

pub mod artifact;
pub mod pjrt;
pub mod xla_solver;

use crate::runtime::artifact::Manifest;
use crate::runtime::pjrt::{
    literal_f64_matrix, literal_f64_vec, to_f64_scalar, to_f64_vec, Executable, PjrtRuntime,
};
use anyhow::{ensure, Context, Result};

pub use xla_solver::{XlaSdcaProgram, XlaSdcaSolver};

/// The duality-gap certificate evaluator backed by the AOT graph.
pub struct XlaGapEvaluator {
    exe: Executable,
    pub n: usize,
    pub d: usize,
}

pub struct XlaCertificates {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    pub w: Vec<f64>,
}

impl XlaGapEvaluator {
    pub fn load(rt: &PjrtRuntime, manifest: &Manifest) -> Result<XlaGapEvaluator> {
        let entry = manifest.find("duality_gap")?;
        let exe = rt.load_hlo_text(&manifest.hlo_path(entry))?;
        Ok(XlaGapEvaluator {
            exe,
            n: entry.dim("n").context("manifest missing dim n")?,
            d: entry.dim("d").context("manifest missing dim d")?,
        })
    }

    /// Evaluate certificates for a (dense, row-major, possibly smaller)
    /// problem; inputs are zero-padded to the artifact's (n, d).
    pub fn certificates(
        &self,
        x_dense: &[f64],
        rows: usize,
        cols: usize,
        y: &[f64],
        alpha: &[f64],
        lambda: f64,
    ) -> Result<XlaCertificates> {
        ensure!(rows <= self.n, "problem rows {rows} exceed artifact n {}", self.n);
        ensure!(cols <= self.d, "problem cols {cols} exceed artifact d {}", self.d);
        ensure!(x_dense.len() == rows * cols);
        let mut x_pad = vec![0.0f64; self.n * self.d];
        for i in 0..rows {
            x_pad[i * self.d..i * self.d + cols].copy_from_slice(&x_dense[i * cols..(i + 1) * cols]);
        }
        let mut y_pad = vec![1.0f64; self.n];
        y_pad[..rows].copy_from_slice(y);
        let mut alpha_pad = vec![0.0f64; self.n];
        alpha_pad[..rows].copy_from_slice(alpha);
        let mut mask = vec![0.0f64; self.n];
        for m in mask.iter_mut().take(rows) {
            *m = 1.0;
        }
        let out = self.exe.call(&[
            literal_f64_matrix(&x_pad, self.n, self.d)?,
            literal_f64_vec(&y_pad),
            literal_f64_vec(&alpha_pad),
            literal_f64_vec(&mask),
            literal_f64_vec(&[lambda]),
        ])?;
        ensure!(out.len() == 4, "duality_gap must return 4 outputs");
        let mut w = to_f64_vec(&out[3])?;
        w.truncate(cols);
        Ok(XlaCertificates {
            primal: to_f64_scalar(&out[0])?,
            dual: to_f64_scalar(&out[1])?,
            gap: to_f64_scalar(&out[2])?,
            w,
        })
    }
}

/// Load every artifact in the manifest, execute each once with benign
/// inputs, and report. Used by `cocoa artifacts-check`.
pub fn smoke_test(manifest: &Manifest) -> Result<String> {
    let rt = PjrtRuntime::cpu()?;
    let mut report = format!("platform: {}\n", rt.platform());

    // duality_gap: α = 0 on unit rows ⇒ P = 1, D = 0, gap = 1 (hinge).
    let gap = XlaGapEvaluator::load(&rt, manifest)?;
    let rows = gap.n.min(32);
    let cols = gap.d.min(8);
    let mut x = vec![0.0f64; rows * cols];
    for i in 0..rows {
        x[i * cols + i % cols] = 1.0;
    }
    let y: Vec<f64> = (0..rows).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let alpha = vec![0.0f64; rows];
    let certs = gap.certificates(&x, rows, cols, &y, &alpha, 1e-2)?;
    ensure!(
        (certs.primal - 1.0).abs() < 1e-9 && certs.dual.abs() < 1e-9,
        "duality_gap smoke mismatch: P={} D={}",
        certs.primal,
        certs.dual
    );
    report.push_str(&format!(
        "duality_gap(n={},d={}): P(0)={:.3} D(0)={:.3} gap={:.3}  OK\n",
        gap.n, gap.d, certs.primal, certs.dual, certs.gap
    ));

    // local_sdca: one call on the same toy block must improve the dual.
    use crate::data::Dataset;
    use crate::linalg::CsrMatrix;
    use crate::subproblem::LocalBlock;
    let program = std::sync::Arc::new(XlaSdcaProgram::load(&rt, manifest)?);
    let data = Dataset::new("smoke", CsrMatrix::from_dense(rows, cols, &x), y.clone());
    let rows_idx: Vec<usize> = (0..rows).collect();
    let block = LocalBlock::from_partition(&data, &rows_idx);
    let lambda = 1e-2;
    let lambda_n = lambda * rows as f64;
    let mut solver = XlaSdcaSolver::new(program, &block, lambda_n, 1.0, 7)?;
    use crate::solver::{LocalSolveCtx, LocalSolver};
    use crate::subproblem::SubproblemSpec;
    let spec = SubproblemSpec {
        loss: crate::loss::Loss::Hinge,
        lambda,
        n_global: rows,
        sigma_prime: 1.0,
        k: 1,
    };
    let w0 = vec![0.0f64; cols];
    let alpha0 = vec![0.0f64; rows];
    let ctx = LocalSolveCtx {
        block: &block,
        spec: &spec,
        w: &w0,
        alpha_local: &alpha0,
    };
    let update = solver.solve(&ctx);
    let alpha1: Vec<f64> = alpha0
        .iter()
        .zip(&update.delta_alpha)
        .map(|(a, d)| a + d)
        .collect();
    let after = gap.certificates(&x, rows, cols, &y, &alpha1, lambda)?;
    ensure!(
        after.gap < certs.gap,
        "local_sdca smoke did not shrink the gap: {} → {}",
        certs.gap,
        after.gap
    );
    report.push_str(&format!(
        "local_sdca(H={}): gap {:.4} → {:.4} after one round  OK\n",
        solver.steps_per_round(),
        certs.gap,
        after.gap
    ));
    Ok(report)
}
