//! PJRT bridge: load HLO-text artifacts, compile them once on the CPU
//! client, execute them from the L3 hot path.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts are lowered with
//! `return_tuple=True`, so outputs always unwrap as a tuple.

use anyhow::{Context, Result};
use std::path::Path;

/// A process-wide PJRT CPU client (creating one per executable would leak
/// threads and startup cost).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().to_string())
                .unwrap_or_default(),
        })
    }
}

/// A compiled computation plus its provenance.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn call(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = literal.to_tuple().context("decomposing output tuple")?;
        Ok(parts)
    }
}

/// Pack a row-major f64 matrix into a literal of shape `[rows, cols]`.
pub fn literal_f64_matrix(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "matrix size mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .context("reshaping matrix literal")
}

/// Pack an f64 vector literal.
pub fn literal_f64_vec(data: &[f64]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Pack an i32 vector literal.
pub fn literal_i32_vec(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Unpack a literal into Vec<f64>.
pub fn to_f64_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    lit.to_vec::<f64>().context("reading f64 literal")
}

/// Unpack a scalar f64.
pub fn to_f64_scalar(lit: &xla::Literal) -> Result<f64> {
    lit.get_first_element::<f64>()
        .context("reading f64 scalar literal")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{default_artifacts_dir, Manifest};

    /// End-to-end PJRT smoke: requires `make artifacts` to have run (the
    /// Makefile guarantees it before `cargo test`). Skips gracefully in
    /// environments without the artifacts.
    #[test]
    fn load_and_run_duality_gap_artifact() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let manifest = Manifest::load(&dir).unwrap();
        let entry = manifest.find("duality_gap").unwrap();
        let n = entry.dim("n").unwrap();
        let d = entry.dim("d").unwrap();
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(&manifest.hlo_path(entry)).unwrap();

        // alpha = 0 on a trivial dataset: P - D = (1/n)Σℓ(0) = 1 for hinge.
        let mut x = vec![0.0f64; n * d];
        for i in 0..n {
            x[i * d + i % d] = 1.0; // unit rows
        }
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let alpha = vec![0.0f64; n];
        let mask = vec![1.0f64; n];
        let lam = vec![1e-2f64];
        let out = exe
            .call(&[
                literal_f64_matrix(&x, n, d).unwrap(),
                literal_f64_vec(&y),
                literal_f64_vec(&alpha),
                literal_f64_vec(&mask),
                literal_f64_vec(&lam),
            ])
            .unwrap();
        assert_eq!(out.len(), 4);
        let primal = to_f64_scalar(&out[0]).unwrap();
        let dual = to_f64_scalar(&out[1]).unwrap();
        let gap = to_f64_scalar(&out[2]).unwrap();
        assert!((primal - 1.0).abs() < 1e-12, "P(0) = {primal}");
        assert!(dual.abs() < 1e-12, "D(0) = {dual}");
        assert!((gap - 1.0).abs() < 1e-12, "gap = {gap}");
        let w = to_f64_vec(&out[3]).unwrap();
        assert_eq!(w.len(), d);
        assert!(w.iter().all(|v| v.abs() < 1e-12));
    }
}
