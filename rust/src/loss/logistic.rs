//! Logistic loss ℓ(z) = log(1 + exp(−yz)). (1/4)-smooth (μ = 4 in the
//! paper's (1/μ)-smooth convention is wrong way round: ℓ'' ≤ 1/4, i.e. the
//! derivative is (1/4)-Lipschitz, so ℓ is (1/μ)-smooth with μ = 4) and
//! 1-Lipschitz.
//!
//! Conjugate (b := yα ∈ [0, 1]): ℓ*(−α) = b·log b + (1−b)·log(1−b)
//! (with 0·log 0 := 0); +∞ outside. No closed-form coordinate maximizer —
//! we run a safeguarded Newton method on the strictly concave 1-D problem.

/// Numerically stable log(1 + exp(−m)).
#[inline]
fn log1p_exp_neg(m: f64) -> f64 {
    if m > 0.0 {
        (-m).exp().ln_1p()
    } else {
        -m + m.exp().ln_1p()
    }
}

/// Primal loss value.
#[inline]
pub fn value(z: f64, y: f64) -> f64 {
    log1p_exp_neg(y * z)
}

/// x·log x with the 0·log 0 = 0 convention.
#[inline]
fn xlogx(x: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        x * x.ln()
    }
}

/// ℓ*(−α); +∞ when yα ∉ [0, 1].
#[inline]
pub fn conjugate_neg(alpha: f64, y: f64) -> f64 {
    let b = y * alpha;
    if (-1e-12..=1.0 + 1e-12).contains(&b) {
        let b = b.clamp(0.0, 1.0);
        xlogx(b) + xlogx(1.0 - b)
    } else {
        f64::INFINITY
    }
}

/// σ(m) = 1 / (1 + exp(−m)), overflow-free on both tails. This is the
/// serving link for logistic models — P(y = +1 | x) at score m = wᵀx —
/// and the building block of [`subgradient`].
#[inline]
pub fn sigmoid(m: f64) -> f64 {
    if m <= 0.0 {
        let e = m.exp();
        e / (1.0 + e)
    } else {
        1.0 / (1.0 + (-m).exp())
    }
}

/// ℓ'(z) = −y / (1 + exp(yz)) = −y·σ(−yz).
#[inline]
pub fn subgradient(z: f64, y: f64) -> f64 {
    -y * sigmoid(-(y * z))
}

/// u with −u ∈ ∂ℓ(z).
#[inline]
pub fn dual_witness(z: f64, y: f64) -> f64 {
    -subgradient(z, y)
}

/// Maximize φ(δ) = −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ² by safeguarded Newton
/// in b-space (b = y(α+δ) ∈ (0,1)):
///   φ(b) = −b·ln b − (1−b)·ln(1−b) − (yb − α)·xv − (coef/2)(b − yα)²
///   φ'(b) = −ln(b/(1−b)) − y·xv − coef·(b − yα)
///   φ''(b) = −1/(b(1−b)) − coef  < 0.
#[inline]
pub fn coordinate_delta(alpha: f64, y: f64, xv: f64, coef: f64) -> f64 {
    debug_assert!(coef > 0.0);
    let b0 = (y * alpha).clamp(1e-12, 1.0 - 1e-12);
    let g = |b: f64| -((b / (1.0 - b)).ln()) - y * xv - coef * (b - y * alpha);

    // Bracket the root of g (g is strictly decreasing; g(0+)=+inf, g(1-)=-inf).
    let (mut lo, mut hi) = (1e-12, 1.0 - 1e-12);
    let mut b = b0;
    for _ in 0..100 {
        let gb = g(b);
        if gb > 0.0 {
            lo = b;
        } else {
            hi = b;
        }
        // Newton step
        let h = -1.0 / (b * (1.0 - b)) - coef;
        let mut b_new = b - gb / h;
        // Safeguard: fall back to bisection when Newton leaves the bracket.
        if !(b_new > lo && b_new < hi) || !b_new.is_finite() {
            b_new = 0.5 * (lo + hi);
        }
        if (b_new - b).abs() < 1e-14 {
            b = b_new;
            break;
        }
        b = b_new;
    }
    y * b - alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_coordinate_opt;

    #[test]
    fn stable_primal_values() {
        assert!((value(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
        // large margins: loss → 0, no overflow
        assert!(value(1000.0, 1.0) < 1e-10);
        assert!(value(-1000.0, 1.0) > 999.0);
    }

    #[test]
    fn sigmoid_is_stable_and_symmetric() {
        assert_eq!(sigmoid(0.0), 0.5);
        // extreme scores saturate without overflow/NaN
        assert_eq!(sigmoid(1000.0), 1.0);
        assert!(sigmoid(-1000.0) >= 0.0 && sigmoid(-1000.0) < 1e-300);
        for zi in -40..=40 {
            let z = zi as f64 * 0.25;
            let s = sigmoid(z);
            assert!((0.0..=1.0).contains(&s));
            assert!((s + sigmoid(-z) - 1.0).abs() < 1e-15, "σ(z)+σ(−z)≠1 at z={z}");
            // agrees with the naive formula where it is safe
            assert!((s - 1.0 / (1.0 + (-z).exp())).abs() < 1e-15);
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let h = 1e-6;
        for zi in -8..=8 {
            let z = zi as f64 * 0.45;
            for &y in &[1.0, -1.0] {
                let fd = (value(z + h, y) - value(z - h, y)) / (2.0 * h);
                let an = subgradient(z, y);
                assert!((fd - an).abs() < 1e-5, "z={z} fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn conjugate_boundary_values() {
        // b=0 and b=1 give ℓ* = 0 (entropy vanishes).
        assert_eq!(conjugate_neg(0.0, 1.0), 0.0);
        assert!((conjugate_neg(1.0, 1.0)).abs() < 1e-9);
        assert!((conjugate_neg(0.5, 1.0) + std::f64::consts::LN_2).abs() < 1e-12);
        assert!(conjugate_neg(1.2, 1.0).is_infinite());
    }

    #[test]
    fn fenchel_young() {
        for &y in &[1.0, -1.0] {
            for zi in -5..=5 {
                let z = zi as f64 * 0.6;
                for bi in 0..=20 {
                    let alpha = y * bi as f64 / 20.0;
                    let lhs = value(z, y) + conjugate_neg(alpha, y);
                    assert!(lhs + 1e-9 >= -alpha * z);
                }
            }
        }
    }

    #[test]
    fn coordinate_delta_is_argmax() {
        assert_coordinate_opt(conjugate_neg, coordinate_delta, &[1.0, -1.0]);
    }

    #[test]
    fn newton_converges_from_boundary_start() {
        // α at the dual boundary (b≈0) must still move.
        let d = coordinate_delta(0.0, 1.0, -2.0, 0.5);
        assert!(d > 0.0);
        let b = d; // y=1
        assert!((0.0..1.0).contains(&b));
    }
}
