//! Squared loss ℓ(z) = ½(z − y)² (ridge regression). 1-smooth (μ = 1),
//! not globally Lipschitz.
//!
//! Conjugate: ℓ*(u) = ½u² + uy, so ℓ*(−α) = ½α² − αy (feasible everywhere).

/// Primal loss value.
#[inline]
pub fn value(z: f64, y: f64) -> f64 {
    0.5 * (z - y) * (z - y)
}

/// ℓ*(−α).
#[inline]
pub fn conjugate_neg(alpha: f64, y: f64) -> f64 {
    0.5 * alpha * alpha - alpha * y
}

/// ℓ'(z) = z − y.
#[inline]
pub fn subgradient(z: f64, y: f64) -> f64 {
    z - y
}

/// u with −u ∈ ∂ℓ(z).
#[inline]
pub fn dual_witness(z: f64, y: f64) -> f64 {
    y - z
}

/// Maximizer of −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ², unconstrained quadratic:
/// δ* = (y − α − xv) / (1 + coef).
#[inline]
pub fn coordinate_delta(alpha: f64, y: f64, xv: f64, coef: f64) -> f64 {
    debug_assert!(coef > 0.0);
    (y - alpha - xv) / (1.0 + coef)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_coordinate_opt;

    #[test]
    fn values_and_derivative() {
        assert_eq!(value(3.0, 1.0), 2.0);
        assert_eq!(subgradient(3.0, 1.0), 2.0);
        assert_eq!(dual_witness(3.0, 1.0), -2.0);
    }

    #[test]
    fn fenchel_young_equality_at_optimum() {
        // For smooth losses FY holds with equality at α = −ℓ'(z).
        for zi in -5..=5 {
            let z = zi as f64 * 0.7;
            let y = 1.5;
            let alpha = -(z - y);
            let gap = value(z, y) + conjugate_neg(alpha, y) + alpha * z;
            assert!(gap.abs() < 1e-10, "gap {gap}");
        }
    }

    #[test]
    fn coordinate_delta_is_argmax() {
        assert_coordinate_opt(conjugate_neg, coordinate_delta, &[1.0, -1.0, 0.3, 2.0]);
    }
}
