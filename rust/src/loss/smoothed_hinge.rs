//! Smoothed hinge loss (Shalev-Shwartz & Zhang 2013c §5):
//!
//!   ℓ(z) = 0                      if yz ≥ 1
//!        = 1 − yz − μ/2           if yz ≤ 1 − μ
//!        = (1 − yz)²/(2μ)         otherwise
//!
//! (1/μ)-smooth and 1-Lipschitz; this is the smooth-loss representative
//! used to exercise Theorem 10 / Corollary 11.
//!
//! Conjugate (b := yα ∈ [0, 1]): ℓ*(−α) = −b + (μ/2)·b².

/// Primal loss value with smoothing parameter mu.
#[inline]
pub fn value(z: f64, y: f64, mu: f64) -> f64 {
    let m = y * z;
    if m >= 1.0 {
        0.0
    } else if m <= 1.0 - mu {
        1.0 - m - mu / 2.0
    } else {
        (1.0 - m) * (1.0 - m) / (2.0 * mu)
    }
}

/// ℓ*(−α); +∞ outside the box.
#[inline]
pub fn conjugate_neg(alpha: f64, y: f64, mu: f64) -> f64 {
    let b = y * alpha;
    if (-1e-12..=1.0 + 1e-12).contains(&b) {
        -b + 0.5 * mu * b * b
    } else {
        f64::INFINITY
    }
}

/// Derivative of ℓ at z (smooth, so unique).
#[inline]
pub fn subgradient(z: f64, y: f64, mu: f64) -> f64 {
    let m = y * z;
    if m >= 1.0 {
        0.0
    } else if m <= 1.0 - mu {
        -y
    } else {
        -y * (1.0 - m) / mu
    }
}

/// u with −u ∈ ∂ℓ(z).
#[inline]
pub fn dual_witness(z: f64, y: f64, mu: f64) -> f64 {
    -subgradient(z, y, mu)
}

/// Closed-form maximizer of −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ².
/// In b-space the objective is b − (μ/2)b² − (yb − α)xv − (coef/2)(b − yα)²
/// (using y² = 1), a concave quadratic: stationary point then clip to [0,1].
#[inline]
pub fn coordinate_delta(alpha: f64, y: f64, xv: f64, coef: f64, mu: f64) -> f64 {
    debug_assert!(coef > 0.0 && mu > 0.0);
    let b = y * alpha;
    let b_unc = (1.0 - y * xv + coef * b) / (mu + coef);
    let b_new = b_unc.clamp(0.0, 1.0);
    y * b_new - alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_coordinate_opt;

    const MU: f64 = 0.5;

    #[test]
    fn piecewise_values_continuous() {
        // Continuity at the knots m = 1 and m = 1-μ.
        let eps = 1e-9;
        let at = |m: f64| value(m, 1.0, MU);
        assert!((at(1.0 - eps) - at(1.0 + eps)).abs() < 1e-6);
        assert!((at(1.0 - MU - eps) - at(1.0 - MU + eps)).abs() < 1e-6);
    }

    #[test]
    fn reduces_to_hinge_as_mu_to_zero() {
        for zi in -6..=6 {
            let z = zi as f64 * 0.5;
            let h = crate::loss::hinge::value(z, 1.0);
            let s = value(z, 1.0, 1e-9);
            assert!((h - s).abs() < 1e-6, "z={z} hinge={h} smooth={s}");
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let h = 1e-6;
        for zi in -8..=8 {
            let z = zi as f64 * 0.37 + 0.01;
            for &y in &[1.0, -1.0] {
                let fd = (value(z + h, y, MU) - value(z - h, y, MU)) / (2.0 * h);
                let an = subgradient(z, y, MU);
                assert!((fd - an).abs() < 1e-4, "z={z} y={y} fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn fenchel_young() {
        for &y in &[1.0, -1.0] {
            for zi in -6..=6 {
                let z = zi as f64 * 0.4;
                for bi in 0..=10 {
                    let alpha = y * bi as f64 / 10.0;
                    let lhs = value(z, y, MU) + conjugate_neg(alpha, y, MU);
                    assert!(lhs + 1e-9 >= -alpha * z);
                }
            }
        }
    }

    #[test]
    fn coordinate_delta_is_argmax() {
        assert_coordinate_opt(
            |a, y| conjugate_neg(a, y, MU),
            |a, y, xv, coef| coordinate_delta(a, y, xv, coef, MU),
            &[1.0, -1.0],
        );
    }

    #[test]
    fn lipschitz_bound_holds() {
        // |ℓ'| ≤ 1 everywhere.
        for zi in -40..=40 {
            let z = zi as f64 * 0.1;
            assert!(subgradient(z, 1.0, MU).abs() <= 1.0 + 1e-12);
        }
    }
}
