//! Hinge loss ℓ(z) = max(0, 1 − yz), the paper's experimental workload
//! (binary SVM). L-Lipschitz with L = 1; non-smooth.
//!
//! Dual: with b := yα, the conjugate is ℓ*(−α) = −b for b ∈ [0, 1] and +∞
//! otherwise (Shalev-Shwartz & Zhang 2013). Feasible dual iterates keep
//! yα_i ∈ [0, 1].

/// Primal loss value.
#[inline]
pub fn value(z: f64, y: f64) -> f64 {
    (1.0 - y * z).max(0.0)
}

/// ℓ*(−α). Returns +∞ when yα ∉ [0,1].
#[inline]
pub fn conjugate_neg(alpha: f64, y: f64) -> f64 {
    let b = y * alpha;
    if (-1e-12..=1.0 + 1e-12).contains(&b) {
        -b
    } else {
        f64::INFINITY
    }
}

/// A subgradient of ℓ at z: −y·1{yz < 1}.
#[inline]
pub fn subgradient(z: f64, y: f64) -> f64 {
    if y * z < 1.0 {
        -y
    } else {
        0.0
    }
}

/// An element u with −u ∈ ∂ℓ(z) (Eq. 17 of the paper).
#[inline]
pub fn dual_witness(z: f64, y: f64) -> f64 {
    -subgradient(z, y)
}

/// Closed-form maximizer of the 1-D local subproblem (Eq. 49):
///   max_δ  −ℓ*(−(α+δ)) − δ·xv − (coef/2)·δ²
/// where xv = x_iᵀv (v = local primal image) and coef = σ'‖x_i‖²/(λn).
/// Returns δ*.
#[inline]
pub fn coordinate_delta(alpha: f64, y: f64, xv: f64, coef: f64) -> f64 {
    debug_assert!(coef > 0.0);
    let b = y * alpha;
    // Unconstrained optimum in b-space, then clip to the box [0, 1].
    let b_unc = b + (1.0 - y * xv) / coef;
    let b_new = b_unc.clamp(0.0, 1.0);
    y * b_new - alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_coordinate_opt;

    #[test]
    fn primal_values() {
        assert_eq!(value(0.0, 1.0), 1.0);
        assert_eq!(value(2.0, 1.0), 0.0);
        assert_eq!(value(-1.0, 1.0), 2.0);
        assert_eq!(value(-2.0, -1.0), 0.0);
    }

    #[test]
    fn conjugate_feasibility() {
        assert_eq!(conjugate_neg(0.5, 1.0), -0.5);
        assert_eq!(conjugate_neg(-0.5, -1.0), -0.5);
        assert!(conjugate_neg(1.5, 1.0).is_infinite());
        assert!(conjugate_neg(-0.1, 1.0).is_infinite());
    }

    #[test]
    fn fenchel_young_inequality() {
        // ℓ(z) + ℓ*(−α) ≥ −αz for all feasible α.
        for &y in &[1.0, -1.0] {
            for zi in -10..=10 {
                let z = zi as f64 * 0.3;
                for bi in 0..=10 {
                    let alpha = y * (bi as f64 / 10.0);
                    let lhs = value(z, y) + conjugate_neg(alpha, y);
                    assert!(lhs + 1e-9 >= -alpha * z, "FY violated: y={y} z={z} a={alpha}");
                }
            }
        }
    }

    #[test]
    fn coordinate_delta_is_argmax() {
        assert_coordinate_opt(|a, y| conjugate_neg(a, y), coordinate_delta, &[1.0, -1.0]);
    }

    #[test]
    fn delta_keeps_feasible() {
        for &y in &[1.0, -1.0] {
            for ai in 0..=10 {
                let alpha = y * ai as f64 / 10.0;
                let d = coordinate_delta(alpha, y, 0.3, 2.0);
                let b = y * (alpha + d);
                assert!((-1e-12..=1.0 + 1e-12).contains(&b), "b={b}");
            }
        }
    }
}
