//! Absolute (L1) regression loss ℓ(z) = |z − y| — the "non-smooth
//! regression variant" the paper's abstract extends the theory to.
//! 1-Lipschitz, non-smooth (Theorem 8 territory, like hinge).
//!
//! Conjugate: ℓ*(u) = uy + I[|u| ≤ 1], so ℓ*(−α) = −αy for α ∈ [−1, 1]
//! and +∞ otherwise.

/// Primal loss value.
#[inline]
pub fn value(z: f64, y: f64) -> f64 {
    (z - y).abs()
}

/// ℓ*(−α); +∞ when |α| > 1.
#[inline]
pub fn conjugate_neg(alpha: f64, y: f64) -> f64 {
    if (-1.0 - 1e-12..=1.0 + 1e-12).contains(&alpha) {
        -alpha * y
    } else {
        f64::INFINITY
    }
}

/// A subgradient of ℓ at z: sign(z − y) (0 at the kink).
#[inline]
pub fn subgradient(z: f64, y: f64) -> f64 {
    if z > y {
        1.0
    } else if z < y {
        -1.0
    } else {
        0.0
    }
}

/// u with −u ∈ ∂ℓ(z).
#[inline]
pub fn dual_witness(z: f64, y: f64) -> f64 {
    -subgradient(z, y)
}

/// Maximizer of −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ² with box |α+δ| ≤ 1:
/// unconstrained stationary point α+δ = α + (y − xv)/coef, clipped.
#[inline]
pub fn coordinate_delta(alpha: f64, y: f64, xv: f64, coef: f64) -> f64 {
    debug_assert!(coef > 0.0);
    let a_unc = alpha + (y - xv) / coef;
    a_unc.clamp(-1.0, 1.0) - alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::test_util::assert_coordinate_opt;

    #[test]
    fn primal_and_subgradient() {
        assert_eq!(value(3.0, 1.0), 2.0);
        assert_eq!(value(-1.0, 1.0), 2.0);
        assert_eq!(subgradient(3.0, 1.0), 1.0);
        assert_eq!(subgradient(-3.0, 1.0), -1.0);
        assert_eq!(subgradient(1.0, 1.0), 0.0);
    }

    #[test]
    fn conjugate_box() {
        assert_eq!(conjugate_neg(0.5, 2.0), -1.0);
        assert_eq!(conjugate_neg(-1.0, 2.0), 2.0);
        assert!(conjugate_neg(1.5, 0.0).is_infinite());
    }

    #[test]
    fn fenchel_young() {
        for zi in -8..=8 {
            let z = zi as f64 * 0.4;
            let y = 0.7;
            for ai in -10..=10 {
                let alpha = ai as f64 / 10.0;
                let lhs = value(z, y) + conjugate_neg(alpha, y);
                assert!(lhs + 1e-9 >= -alpha * z, "z={z} a={alpha}");
            }
        }
    }

    #[test]
    fn coordinate_delta_is_argmax() {
        // labels here are regression targets, not ±1
        assert_coordinate_opt(conjugate_neg, coordinate_delta, &[0.5, -1.2, 2.0]);
    }

    #[test]
    fn delta_respects_box() {
        for ai in [-1.0, -0.3, 0.0, 0.8, 1.0] {
            let d = coordinate_delta(ai, 5.0, -3.0, 0.1);
            assert!((ai + d).abs() <= 1.0 + 1e-12);
        }
    }
}
