//! Loss-function library: the convex loss classes of the paper (§2).
//!
//! Each concrete loss lives in its own module as free functions over
//! (z, y); [`Loss`] is a zero-cost enum dispatcher used by the objective,
//! the solvers, and the baselines. The paper's experiments use `Hinge`
//! (L-Lipschitz, non-smooth — Theorem 8 territory); `SmoothedHinge`,
//! `Logistic`, and `Squared` exercise the smooth-loss rates (Theorem 10).

pub mod absolute;
pub mod hinge;
pub mod logistic;
pub mod smoothed_hinge;
pub mod squared;

/// Hard ±1 decision for a raw score z = wᵀx — the serving-side
/// classification rule. Strictly positive scores are the positive class;
/// a zero score carries no evidence and falls to −1, consistent with
/// [`misclassified`]'s convention that a zero margin is never counted as
/// a correct classification.
#[inline]
pub fn classify(z: f64) -> f64 {
    if z > 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Training-side 0/1 miss rule for true label y ∈ {−1, +1}: a row is
/// correct only when the score lands strictly on the label's side
/// (yz > 0). This and [`classify`] are the crate's one sign/threshold
/// rule — `Dataset::classification_error` (hence every
/// `Method::train_error`) and the serving path both resolve the z = 0
/// boundary here rather than re-deriving it.
#[inline]
pub fn misclassified(z: f64, y: f64) -> bool {
    y * z <= 0.0
}

/// Which convex loss to use. All methods are `#[inline]` match-dispatched,
/// so the SDCA inner loop pays no dynamic-dispatch cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Loss {
    /// max(0, 1 − yz); L = 1.
    Hinge,
    /// Smoothed hinge with parameter μ (1/μ-smooth, 1-Lipschitz).
    SmoothedHinge { mu: f64 },
    /// log(1 + e^{−yz}); 1-Lipschitz, (1/4)-smooth.
    Logistic,
    /// ½(z − y)²; 1-smooth, not Lipschitz.
    Squared,
    /// |z − y| (L1 regression); 1-Lipschitz, non-smooth.
    Absolute,
}

impl Loss {
    pub fn parse(name: &str) -> Option<Loss> {
        match name {
            "hinge" | "svm" => Some(Loss::Hinge),
            "smoothed_hinge" | "smooth-hinge" => Some(Loss::SmoothedHinge { mu: 0.5 }),
            "logistic" | "logreg" => Some(Loss::Logistic),
            "squared" | "ridge" | "ls" => Some(Loss::Squared),
            "absolute" | "l1" | "lad" => Some(Loss::Absolute),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Loss::Hinge => "hinge",
            Loss::SmoothedHinge { .. } => "smoothed_hinge",
            Loss::Logistic => "logistic",
            Loss::Squared => "squared",
            Loss::Absolute => "absolute",
        }
    }

    /// ℓ(z; y).
    #[inline]
    pub fn value(&self, z: f64, y: f64) -> f64 {
        match *self {
            Loss::Hinge => hinge::value(z, y),
            Loss::SmoothedHinge { mu } => smoothed_hinge::value(z, y, mu),
            Loss::Logistic => logistic::value(z, y),
            Loss::Squared => squared::value(z, y),
            Loss::Absolute => absolute::value(z, y),
        }
    }

    /// ℓ*(−α; y); +∞ when dual-infeasible.
    #[inline]
    pub fn conjugate_neg(&self, alpha: f64, y: f64) -> f64 {
        match *self {
            Loss::Hinge => hinge::conjugate_neg(alpha, y),
            Loss::SmoothedHinge { mu } => smoothed_hinge::conjugate_neg(alpha, y, mu),
            Loss::Logistic => logistic::conjugate_neg(alpha, y),
            Loss::Squared => squared::conjugate_neg(alpha, y),
            Loss::Absolute => absolute::conjugate_neg(alpha, y),
        }
    }

    /// A subgradient of ℓ at z.
    #[inline]
    pub fn subgradient(&self, z: f64, y: f64) -> f64 {
        match *self {
            Loss::Hinge => hinge::subgradient(z, y),
            Loss::SmoothedHinge { mu } => smoothed_hinge::subgradient(z, y, mu),
            Loss::Logistic => logistic::subgradient(z, y),
            Loss::Squared => squared::subgradient(z, y),
            Loss::Absolute => absolute::subgradient(z, y),
        }
    }

    /// u with −u ∈ ∂ℓ(z) — the dual witness of Eq. (17).
    #[inline]
    pub fn dual_witness(&self, z: f64, y: f64) -> f64 {
        match *self {
            Loss::Hinge => hinge::dual_witness(z, y),
            Loss::SmoothedHinge { mu } => smoothed_hinge::dual_witness(z, y, mu),
            Loss::Logistic => logistic::dual_witness(z, y),
            Loss::Squared => squared::dual_witness(z, y),
            Loss::Absolute => absolute::dual_witness(z, y),
        }
    }

    /// Maximizer δ* of the 1-D data-local subproblem
    /// −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ², coef = σ'‖x_i‖²/(λn).
    #[inline]
    pub fn coordinate_delta(&self, alpha: f64, y: f64, xv: f64, coef: f64) -> f64 {
        match *self {
            Loss::Hinge => hinge::coordinate_delta(alpha, y, xv, coef),
            Loss::SmoothedHinge { mu } => smoothed_hinge::coordinate_delta(alpha, y, xv, coef, mu),
            Loss::Logistic => logistic::coordinate_delta(alpha, y, xv, coef),
            Loss::Squared => squared::coordinate_delta(alpha, y, xv, coef),
            Loss::Absolute => absolute::coordinate_delta(alpha, y, xv, coef),
        }
    }

    /// The serving link: map a raw score z = wᵀx to the loss's natural
    /// prediction — a hard ±1 label for the hinge family, the calibrated
    /// probability P(y = +1 | x) for logistic, and the score itself for
    /// the regression losses.
    #[inline]
    pub fn predict(&self, z: f64) -> f64 {
        match self {
            Loss::Hinge | Loss::SmoothedHinge { .. } => classify(z),
            Loss::Logistic => logistic::sigmoid(z),
            Loss::Squared | Loss::Absolute => z,
        }
    }

    /// Whether [`Loss::predict`] outputs class decisions/probabilities
    /// (true) rather than real-valued regression targets (false).
    pub fn is_classification(&self) -> bool {
        !matches!(self, Loss::Squared | Loss::Absolute)
    }

    /// Lipschitz constant L (Definition 1), if the loss is Lipschitz.
    pub fn lipschitz(&self) -> Option<f64> {
        match self {
            Loss::Hinge | Loss::SmoothedHinge { .. } | Loss::Logistic | Loss::Absolute => {
                Some(1.0)
            }
            Loss::Squared => None,
        }
    }

    /// μ such that ℓ is (1/μ)-smooth (Definition 2), if smooth.
    pub fn smoothness_mu(&self) -> Option<f64> {
        match *self {
            Loss::Hinge | Loss::Absolute => None,
            Loss::SmoothedHinge { mu } => Some(mu),
            Loss::Logistic => Some(4.0),
            Loss::Squared => Some(1.0),
        }
    }

    /// Whether α = 0 is dual-feasible (true for all implemented losses).
    pub fn zero_feasible(&self) -> bool {
        true
    }
}

#[cfg(test)]
pub(crate) mod test_util {
    /// Check the closed-form coordinate maximizer against a dense grid
    /// search of the 1-D objective φ(δ) = −ℓ*(−(α+δ)) − δ·xv − (coef/2)δ².
    pub fn assert_coordinate_opt(
        conj: impl Fn(f64, f64) -> f64,
        delta_fn: impl Fn(f64, f64, f64, f64) -> f64,
        ys: &[f64],
    ) {
        let phi = |alpha: f64, y: f64, xv: f64, coef: f64, d: f64| -> f64 {
            let c = conj(alpha + d, y);
            if c.is_infinite() {
                return f64::NEG_INFINITY;
            }
            -c - d * xv - 0.5 * coef * d * d
        };
        for &y in ys {
            for &alpha0 in &[0.0, 0.3 * y, 0.9 * y] {
                for &xv in &[-1.5, -0.2, 0.0, 0.4, 2.0] {
                    for &coef in &[0.1, 1.0, 10.0] {
                        let d_star = delta_fn(alpha0, y, xv, coef);
                        let f_star = phi(alpha0, y, xv, coef, d_star);
                        assert!(f_star.is_finite(), "optimizer left feasible set");
                        // grid search over a wide range
                        let mut best = f64::NEG_INFINITY;
                        for gi in -2000..=2000 {
                            let d = gi as f64 * 0.002;
                            best = best.max(phi(alpha0, y, xv, coef, d));
                        }
                        assert!(
                            f_star + 1e-5 >= best,
                            "closed form {f_star} < grid {best} (y={y} a={alpha0} xv={xv} coef={coef})"
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Loss::parse("hinge"), Some(Loss::Hinge));
        assert_eq!(Loss::parse("ridge"), Some(Loss::Squared));
        assert!(Loss::parse("unknown").is_none());
    }

    #[test]
    fn class_constants() {
        assert_eq!(Loss::Hinge.lipschitz(), Some(1.0));
        assert_eq!(Loss::Hinge.smoothness_mu(), None);
        assert_eq!(Loss::Squared.lipschitz(), None);
        assert_eq!(Loss::Squared.smoothness_mu(), Some(1.0));
        assert_eq!(Loss::Logistic.smoothness_mu(), Some(4.0));
    }

    #[test]
    fn loss_at_zero_bounded_by_one() {
        // Paper assumption (5): ℓ_i(0) ≤ 1 for classification losses with
        // |y| = 1 (squared loss satisfies it for |y| ≤ √2).
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
        ] {
            for &y in &[1.0, -1.0] {
                assert!(loss.value(0.0, y) <= 1.0 + 1e-12);
            }
        }
        assert!(Loss::Squared.value(0.0, 1.0) <= 1.0);
    }

    #[test]
    fn classify_and_misclassified_share_one_boundary() {
        assert_eq!(classify(0.7), 1.0);
        assert_eq!(classify(-0.7), -1.0);
        assert_eq!(classify(f64::MIN_POSITIVE), 1.0);
        // zero score carries no evidence → negative class
        assert_eq!(classify(0.0), -1.0);
        assert_eq!(classify(-0.0), -1.0);
        for &z in &[-2.0, -0.0, 0.0, 1e-300, 3.5] {
            for &y in &[1.0, -1.0] {
                // the two views of the same rule: wrong ⟺ label disagrees
                // or the margin is exactly zero
                assert_eq!(
                    misclassified(z, y),
                    classify(z) != y || z == 0.0,
                    "z={z} y={y}"
                );
            }
        }
    }

    #[test]
    fn predict_links_per_loss() {
        // hinge family: hard ±1 decision
        for loss in [Loss::Hinge, Loss::SmoothedHinge { mu: 0.5 }] {
            assert_eq!(loss.predict(2.5), 1.0);
            assert_eq!(loss.predict(-0.1), -1.0);
            assert_eq!(loss.predict(0.0), -1.0);
            assert!(loss.is_classification());
        }
        // logistic: calibrated probability, monotone, agrees with classify
        // on the strict side of the boundary (p > ½ ⟺ +1)
        assert_eq!(Loss::Logistic.predict(0.0), 0.5);
        assert!(Loss::Logistic.predict(3.0) > 0.5);
        assert!(Loss::Logistic.predict(-3.0) < 0.5);
        assert!((Loss::Logistic.predict(1.0) - 1.0 / (1.0 + (-1.0f64).exp())).abs() < 1e-15);
        assert!(Loss::Logistic.is_classification());
        for zi in -10..=10 {
            let z = zi as f64 * 0.4;
            let p = Loss::Logistic.predict(z);
            assert!((0.0..=1.0).contains(&p));
            assert_eq!(p > 0.5, classify(z) == 1.0 && z != 0.0);
        }
        // regression losses: identity link
        for loss in [Loss::Squared, Loss::Absolute] {
            for &z in &[-4.25, 0.0, 17.5] {
                assert_eq!(loss.predict(z), z);
            }
            assert!(!loss.is_classification());
        }
    }

    #[test]
    fn dual_witness_is_feasible() {
        // The witness u from Eq. (17) must itself be dual-feasible
        // (conjugate finite) for Lipschitz losses.
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
        ] {
            for zi in -10..=10 {
                let z = zi as f64 * 0.33;
                for &y in &[1.0, -1.0] {
                    let u = loss.dual_witness(z, y);
                    assert!(
                        loss.conjugate_neg(u, y).is_finite(),
                        "{} witness infeasible at z={z} y={y}",
                        loss.name()
                    );
                }
            }
        }
    }
}
