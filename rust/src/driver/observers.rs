//! Pluggable per-round hooks for the [`Driver`](super::Driver): metric
//! sinks that used to be copy-pasted into every experiment loop. An
//! observer sees each evaluated [`RoundRecord`] (plus the current model
//! w) and the final [`History`].

use crate::coordinator::history::{History, RoundRecord};
use crate::telemetry::writer::JsonWriter;
use std::cell::RefCell;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Receives every evaluated round and the finished history. All hooks
/// default to no-ops so implementations override only what they need.
pub trait Observer {
    fn on_record(&mut self, record: &RoundRecord, w: &[f64]) {
        let _ = (record, w);
    }
    fn on_finish(&mut self, history: &History) {
        let _ = history;
    }
}

/// Streams history rows to a CSV file as they are evaluated (header
/// first, one row per certificate evaluation, flushed at the end), so a
/// long run's series is inspectable while it is still going.
pub struct CsvStream {
    path: PathBuf,
    out: Option<std::io::BufWriter<std::fs::File>>,
}

impl CsvStream {
    /// Create (or truncate) `path`, creating parent directories, and
    /// write the header.
    pub fn create(path: impl Into<PathBuf>) -> std::io::Result<CsvStream> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = std::io::BufWriter::new(std::fs::File::create(&path)?);
        out.write_all(History::csv_header().as_bytes())?;
        Ok(CsvStream {
            path,
            out: Some(out),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Observer for CsvStream {
    fn on_record(&mut self, record: &RoundRecord, _w: &[f64]) {
        if let Some(out) = self.out.as_mut() {
            if out
                .write_all(History::csv_row(record).as_bytes())
                .is_err()
            {
                crate::log_warn!("csv stream to {} failed; disabling", self.path.display());
                self.out = None;
            }
        }
    }

    fn on_finish(&mut self, history: &History) {
        if let Some(out) = self.out.as_mut() {
            // Trailing comment lines carry the run's identity and outcome;
            // History::from_csv accepts them anywhere in the file.
            let _ = writeln!(out, "# label={}", history.label);
            let _ = writeln!(out, "# stop={}", history.stop.as_str());
            let _ = out.flush();
        }
    }
}

/// Logs progress at `log_info` level every `every`-th evaluated round,
/// plus a summary line when the run finishes.
pub struct ProgressLog {
    every: usize,
    seen: usize,
}

impl ProgressLog {
    pub fn new(every: usize) -> ProgressLog {
        ProgressLog {
            every: every.max(1),
            seen: 0,
        }
    }
}

impl Observer for ProgressLog {
    fn on_record(&mut self, record: &RoundRecord, _w: &[f64]) {
        if self.seen % self.every == 0 {
            crate::log_info!(
                "round {:>4}: gap {:.3e}  P {:.6e}  D {:.6e}  t_sim {:.3}s",
                record.round,
                record.gap,
                record.primal,
                record.dual,
                record.sim_time_s
            );
        }
        self.seen += 1;
    }

    fn on_finish(&mut self, history: &History) {
        crate::log_info!(
            "{}: stop={:?} after {} rounds, final gap {:.3e}",
            history.label,
            history.stop,
            history.rounds_run(),
            history.final_gap()
        );
    }
}

/// Writes a JSON snapshot `{gap, round, w}` of the shared model every
/// `every`-th evaluated round (overwriting — the file always holds the
/// latest snapshot), so a long run can be warm-restarted or inspected.
/// The snapshot is *streamed* straight to the file: w can be large
/// (d entries), and the old materialize-then-write path briefly held
/// the whole document in memory next to the model itself.
pub struct CheckpointEvery {
    every: usize,
    seen: usize,
    path: PathBuf,
}

impl CheckpointEvery {
    pub fn new(every: usize, path: impl Into<PathBuf>) -> CheckpointEvery {
        CheckpointEvery {
            every: every.max(1),
            seen: 0,
            path: path.into(),
        }
    }

    /// Stream the snapshot (keys in alphabetical order — byte-identical
    /// to what the BTreeMap-backed `Json` serializer produced).
    fn write_snapshot(&self, record: &RoundRecord, w: &[f64]) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let out = std::io::BufWriter::new(std::fs::File::create(&self.path)?);
        let mut j = JsonWriter::new(out);
        j.begin_obj()?;
        j.key("gap")?;
        j.num(record.gap)?;
        j.key("round")?;
        j.num(record.round as f64)?;
        j.key("w")?;
        j.begin_arr()?;
        for &v in w {
            j.num(v)?;
        }
        j.end()?;
        j.end()?;
        j.into_inner().flush()
    }
}

impl Observer for CheckpointEvery {
    fn on_record(&mut self, record: &RoundRecord, w: &[f64]) {
        if self.seen % self.every == 0 {
            if let Err(e) = self.write_snapshot(record, w) {
                crate::log_warn!("checkpoint to {} failed: {e}", self.path.display());
            }
        }
        self.seen += 1;
    }
}

/// The best (smallest) gap seen so far, with its round and simulated
/// time — readable after the run through a cloned handle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BestGap {
    pub round: usize,
    pub gap: f64,
    pub sim_time_s: f64,
}

/// Tracks the best gap across a run. The tracker is a cheap cloneable
/// handle: keep one clone, hand the other to the [`Driver`], and read
/// [`BestGapTracker::best`] after the run.
#[derive(Clone, Default)]
pub struct BestGapTracker {
    inner: Rc<RefCell<Option<BestGap>>>,
}

impl BestGapTracker {
    pub fn new() -> BestGapTracker {
        BestGapTracker::default()
    }

    pub fn best(&self) -> Option<BestGap> {
        *self.inner.borrow()
    }
}

impl Observer for BestGapTracker {
    fn on_record(&mut self, record: &RoundRecord, _w: &[f64]) {
        let mut slot = self.inner.borrow_mut();
        let better = slot.map_or(true, |b| record.gap < b.gap);
        if better {
            *slot = Some(BestGap {
                round: record.round,
                gap: record.gap,
                sim_time_s: record.sim_time_s,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, gap: f64) -> RoundRecord {
        RoundRecord {
            round,
            comm_vectors: round * 2,
            sim_time_s: round as f64 * 0.5,
            compute_s: round as f64 * 0.25,
            primal: 1.0,
            dual: 1.0 - gap,
            gap,
        }
    }

    #[test]
    fn best_gap_tracker_keeps_minimum() {
        let tracker = BestGapTracker::new();
        let mut handle = tracker.clone();
        handle.on_record(&rec(0, 0.5), &[]);
        handle.on_record(&rec(1, 0.1), &[]);
        handle.on_record(&rec(2, 0.3), &[]);
        let best = tracker.best().unwrap();
        assert_eq!(best.round, 1);
        assert!((best.gap - 0.1).abs() < 1e-12);
    }

    #[test]
    fn csv_stream_writes_parseable_rows_with_outcome_trailer() {
        use crate::coordinator::history::StopReason;
        let path = std::env::temp_dir().join("cocoa_obs_stream_test.csv");
        let mut s = CsvStream::create(&path).unwrap();
        s.on_record(&rec(0, 0.5), &[]);
        s.on_record(&rec(1, 0.25), &[]);
        let mut done = History::new("streamed-series");
        done.stop = StopReason::GapReached;
        s.on_finish(&done);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,"));
        assert_eq!(text.lines().count(), 5); // header + 2 rows + 2 trailers
        let parsed = History::from_csv(&text).unwrap();
        assert_eq!(parsed.records.len(), 2);
        assert!((parsed.records[1].gap - 0.25).abs() < 1e-15);
        assert_eq!(parsed.label, "streamed-series");
        assert_eq!(parsed.stop, StopReason::GapReached);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_every_writes_latest_snapshot() {
        let path = std::env::temp_dir().join("cocoa_obs_ckpt_test.json");
        let mut c = CheckpointEvery::new(2, &path);
        c.on_record(&rec(0, 0.5), &[1.0, 2.0]); // seen 0 → write
        c.on_record(&rec(1, 0.4), &[3.0, 4.0]); // skipped
        c.on_record(&rec(2, 0.3), &[5.0, 6.0]); // seen 2 → overwrite
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("round").unwrap().as_f64(), Some(2.0));
        let w = j.get("w").unwrap().as_arr().unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].as_f64(), Some(5.0));
        // streaming writer parity with the materializing serializer
        use crate::util::json::{jarr, jnum, jobj};
        let expect = jobj(vec![
            ("round", jnum(2.0)),
            ("gap", jnum(0.3)),
            ("w", jarr(vec![jnum(5.0), jnum(6.0)])),
        ]);
        assert_eq!(text, expect.to_string_compact());
        std::fs::remove_file(&path).ok();
    }
}
