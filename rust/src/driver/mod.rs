//! The unified training API: every optimizer in this crate — the CoCoA+
//! [`crate::coordinator::Trainer`] and all five baselines — implements the
//! [`Method`] trait, and a single [`Driver`] owns everything their
//! hand-rolled loops used to duplicate:
//!
//! * the **stopping policy** ([`StopPolicy`]): duality-gap tolerance,
//!   round budget, divergence abort, dual-progress stall, and the Fig.-2
//!   dual-target criterion (stop when D(α*) − D(α) ≤ ε_D);
//! * the **certificate cadence** (`gap_every`): certificates cost a pass
//!   over the data (K-way parallel for the pooled trainer, serial for
//!   single-machine methods), so they are evaluated every N rounds;
//! * the **simulated cluster clock**: per round the Driver charges the
//!   method's measured compute seconds plus the
//!   [`CommModel`](crate::coordinator::comm::CommModel) network time
//!   (only on rounds that actually communicate);
//! * pluggable [`Observer`]s (streaming CSV, progress logging,
//!   checkpoint-every-N, best-gap tracking — see [`observers`]).
//!
//! The Driver's loop body is byte-for-byte the accounting the paper's
//! comparison needs: identical communication and time treatment for every
//! method, so CoCoA+ vs CoCoA vs mini-batch curves are produced by the
//! *same* code path. `rust/tests/determinism.rs` locks in that routing
//! `Trainer::run` through the Driver preserves bit-identical trajectories.

pub mod observers;
pub mod registry;

pub use observers::{BestGapTracker, CheckpointEvery, CsvStream, Observer, ProgressLog};
pub use registry::{build_method, BuildOpts, MethodName};

use crate::coordinator::comm::CommModel;
use crate::coordinator::config::CocoaConfig;
use crate::coordinator::history::{History, RoundRecord, StopReason};
use crate::objective::Certificates;
use crate::telemetry::{Recorder, Ring};

/// What one outer round of a [`Method`] reports back to the [`Driver`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Measured local-compute seconds for the round (max over workers —
    /// the quantity that gates a synchronous cluster round).
    pub compute_s: f64,
    /// Vectors communicated this round (0 for serial methods and for
    /// no-op rounds, e.g. one-shot averaging after its single round).
    pub comm_vectors: usize,
}

/// A distributed (or serial reference) optimizer that the [`Driver`] can
/// run: one synchronous outer round per [`Method::step`], primal/dual
/// certificates on demand via [`Method::eval`].
pub trait Method {
    /// Execute one outer round and report its cost.
    fn step(&mut self) -> StepStats;

    /// Primal/dual certificates at the current iterate. Takes `&mut self`
    /// because evaluation may *drive the cluster*: the CoCoA trainer
    /// fans the certificate out to its worker pool as a shard-partial
    /// reduction (each worker sums its own primal losses and dual
    /// conjugates) instead of a serial full-data pass on the leader.
    /// Methods without a dual certificate (mini-batch SGD, ADMM) report
    /// `dual = f64::NEG_INFINITY` and use the `gap` slot for primal
    /// suboptimality against an externally supplied target (or the raw
    /// primal value when none is known) — the paper's §6 point that
    /// primal-only methods cannot certify their own accuracy.
    fn eval(&mut self) -> Certificates;

    /// Vectors a full communicating round moves (the paper's Fig.-1
    /// x-axis unit): one per worker for the distributed methods, 0 for
    /// serial ones.
    fn comm_vectors_per_round(&self) -> usize;

    /// The current shared primal model.
    fn w(&self) -> &[f64];

    /// Human-readable series label (method, K, γ, σ', solver, …).
    fn label(&self) -> String;

    /// Simulated cluster network used for the elapsed-time axis.
    fn comm_model(&self) -> CommModel;

    /// Dimension of the communicated vectors (defaults to `w().len()`).
    fn dim(&self) -> usize {
        self.w().len()
    }

    /// Optional runtime diagnostics printed by the CLI after a run
    /// (e.g. the Trainer's executor kind and pool overhead).
    fn runtime_notes(&self) -> Option<String> {
        None
    }

    /// Training 0/1 classification error of the current model on the
    /// method's own dataset, when it can evaluate one.
    fn train_error(&self) -> Option<f64> {
        None
    }

    /// A serializable snapshot of the optimizer state, for methods whose
    /// full state is checkpointable (the CoCoA trainer: α *is* the
    /// complete state). `None` for baselines that keep no restorable dual
    /// state — `cocoa train --checkpoint-out` reports those as such
    /// instead of writing a half-checkpoint.
    fn checkpoint(&self) -> Option<crate::coordinator::checkpoint::Checkpoint> {
        None
    }

    /// Optional measured-vs-simulated communication validation report,
    /// printed by the CLI after a run. `Some` only for methods that
    /// measured real wire time (the Trainer on the socket executor).
    fn comm_report(&self) -> Option<String> {
        None
    }
}

/// The Fig.-2 stopping rule: stop once the dual suboptimality
/// D(α*) − D(α) falls below `eps`, given an externally estimated optimum
/// `d_star` (calibrated by a long serial-SDCA run).
#[derive(Clone, Copy, Debug)]
pub struct DualTarget {
    pub d_star: f64,
    pub eps: f64,
}

/// Stop when the dual has not improved by more than `min_delta` for
/// `patience` consecutive certificate evaluations.
#[derive(Clone, Copy, Debug)]
pub struct DualStall {
    pub patience: usize,
    pub min_delta: f64,
}

/// When a [`Driver`] run ends. All rules are checked at certificate
/// cadence, in this order: divergence, gap tolerance, dual target,
/// dual stall; the round budget bounds everything.
#[derive(Clone, Copy, Debug)]
pub struct StopPolicy {
    /// Hard bound on outer rounds.
    pub max_rounds: usize,
    /// Stop when the duality gap falls below this. Use
    /// `f64::NEG_INFINITY` to disable gap stopping.
    pub gap_tol: f64,
    /// Abort and flag divergence when the gap exceeds this (an infinite
    /// gap trips any finite threshold). Use `f64::INFINITY` to disable —
    /// useful for methods whose gap may legitimately be infinite, e.g.
    /// one-shot averaging with a dual-infeasible scaled α. NaN gaps
    /// always abort.
    pub divergence_gap: f64,
    /// Optional Fig.-2 dual-target criterion.
    pub dual_target: Option<DualTarget>,
    /// Optional dual-progress stall criterion.
    pub dual_stall: Option<DualStall>,
}

impl Default for StopPolicy {
    fn default() -> StopPolicy {
        StopPolicy {
            max_rounds: 200,
            gap_tol: 1e-4,
            divergence_gap: 1e6,
            dual_target: None,
            dual_stall: None,
        }
    }
}

impl StopPolicy {
    pub fn new(max_rounds: usize) -> StopPolicy {
        StopPolicy {
            max_rounds,
            ..StopPolicy::default()
        }
    }

    pub fn with_gap_tol(mut self, tol: f64) -> StopPolicy {
        self.gap_tol = tol;
        self
    }

    pub fn with_divergence_gap(mut self, gap: f64) -> StopPolicy {
        self.divergence_gap = gap;
        self
    }

    pub fn with_dual_target(mut self, d_star: f64, eps: f64) -> StopPolicy {
        self.dual_target = Some(DualTarget { d_star, eps });
        self
    }

    pub fn with_dual_stall(mut self, patience: usize, min_delta: f64) -> StopPolicy {
        self.dual_stall = Some(DualStall {
            patience,
            min_delta,
        });
        self
    }
}

/// The method-agnostic outer loop: steps a [`Method`], keeps the
/// simulated cluster clock and communication totals, evaluates
/// certificates on a cadence, applies the [`StopPolicy`], and notifies
/// [`Observer`]s.
pub struct Driver {
    pub stop: StopPolicy,
    /// Evaluate certificates every `gap_every` rounds (they cost a full
    /// pass over the data). The final round is always evaluated.
    pub gap_every: usize,
    observers: Vec<Box<dyn Observer>>,
    /// Driver-lane (tid 0) flight-recorder ring: one "round" span per
    /// outer round and one "eval" span per certificate evaluation.
    ring: Ring,
}

impl Driver {
    pub fn new(stop: StopPolicy) -> Driver {
        Driver {
            stop,
            gap_every: 1,
            observers: Vec::new(),
            ring: Ring::disabled(),
        }
    }

    /// The policy a [`CocoaConfig`] encodes (gap tolerance, round budget,
    /// divergence abort, certificate cadence) — what `Trainer::run` uses.
    /// The config's flight recorder is attached, so `--trace-out` runs
    /// get driver-level round/eval spans above the executor's phases.
    pub fn from_cocoa_config(cfg: &CocoaConfig) -> Driver {
        Driver::new(
            StopPolicy::new(cfg.max_rounds)
                .with_gap_tol(cfg.gap_tol)
                .with_divergence_gap(cfg.divergence_gap),
        )
        .with_gap_every(cfg.gap_every)
        .with_recorder(&cfg.trace)
    }

    pub fn with_gap_every(mut self, every: usize) -> Driver {
        self.gap_every = every.max(1);
        self
    }

    /// Attach a flight recorder; the driver records its outer-loop
    /// round/eval spans on the leader lane (tid 0).
    pub fn with_recorder(mut self, recorder: &Recorder) -> Driver {
        self.ring = recorder.ring(0);
        self
    }

    pub fn with_observer(mut self, obs: Box<dyn Observer>) -> Driver {
        self.observers.push(obs);
        self
    }

    /// Run `method` under this driver's policy and return the history.
    pub fn run(&mut self, method: &mut dyn Method) -> History {
        let label = method.label();
        let comm = method.comm_model();
        let mut hist = History::new(&label);
        let mut cum_compute = 0.0f64;
        let mut cum_sim = 0.0f64;
        let mut vectors = 0usize;
        let mut best_dual = f64::NEG_INFINITY;
        let mut stalled_evals = 0usize;
        let mut stop = StopReason::MaxRounds;

        'rounds: for t in 0..self.stop.max_rounds {
            let t_round = self.ring.now();
            let stats = method.step();
            self.ring
                .complete("round", "driver", t_round, Some(("round", t as f64)));
            cum_compute += stats.compute_s;
            cum_sim += stats.compute_s;
            if stats.comm_vectors > 0 {
                // Network time is charged only on rounds that communicate
                // (one-shot averaging's no-op rounds stay free).
                cum_sim += comm.round_time(method.dim());
            }
            vectors += stats.comm_vectors;

            if t % self.gap_every == 0 || t + 1 == self.stop.max_rounds {
                let t_eval = self.ring.now();
                let certs = method.eval();
                self.ring
                    .complete("eval", "driver", t_eval, Some(("round", t as f64)));
                let rec = RoundRecord {
                    round: t,
                    comm_vectors: vectors,
                    sim_time_s: cum_sim,
                    compute_s: cum_compute,
                    primal: certs.primal,
                    dual: certs.dual,
                    gap: certs.gap,
                };
                hist.push(rec);
                for obs in &mut self.observers {
                    obs.on_record(&rec, method.w());
                }
                crate::log_debug!(
                    "round {t}: P={:.6e} D={:.6e} gap={:.6e}",
                    certs.primal,
                    certs.dual,
                    certs.gap
                );

                if certs.gap.is_nan() || certs.gap > self.stop.divergence_gap {
                    stop = StopReason::Diverged;
                    crate::log_warn!("{label}: diverged at round {t} (gap={})", certs.gap);
                    break 'rounds;
                }
                if certs.gap <= self.stop.gap_tol {
                    stop = StopReason::GapReached;
                    break 'rounds;
                }
                if let Some(dt) = self.stop.dual_target {
                    if certs.dual.is_finite() && dt.d_star - certs.dual <= dt.eps {
                        stop = StopReason::DualTargetReached;
                        break 'rounds;
                    }
                }
                if let Some(ds) = self.stop.dual_stall {
                    if certs.dual.is_finite() {
                        if certs.dual > best_dual + ds.min_delta {
                            best_dual = certs.dual;
                            stalled_evals = 0;
                        } else {
                            stalled_evals += 1;
                            if stalled_evals >= ds.patience {
                                stop = StopReason::DualStalled;
                                crate::log_warn!(
                                    "{label}: dual stalled at round {t} (best D={best_dual:.6e})"
                                );
                                break 'rounds;
                            }
                        }
                    }
                }
            }
        }

        hist.stop = stop;
        for obs in &mut self.observers {
            obs.on_finish(&hist);
        }
        hist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic method with a geometric gap trajectory: gap_{t+1} =
    /// shrink·gap_t, dual = 1 − gap. shrink > 1 models divergence,
    /// shrink = 1 models a stall.
    struct Toy {
        gap: f64,
        shrink: f64,
        w: Vec<f64>,
    }

    impl Toy {
        fn new(shrink: f64) -> Toy {
            Toy {
                gap: 1.0,
                shrink,
                w: vec![0.0; 4],
            }
        }
    }

    impl Method for Toy {
        fn step(&mut self) -> StepStats {
            self.gap *= self.shrink;
            StepStats {
                compute_s: 1e-3,
                comm_vectors: 2,
            }
        }
        fn eval(&mut self) -> Certificates {
            Certificates {
                primal: 1.0,
                dual: 1.0 - self.gap,
                gap: self.gap,
            }
        }
        fn comm_vectors_per_round(&self) -> usize {
            2
        }
        fn w(&self) -> &[f64] {
            &self.w
        }
        fn label(&self) -> String {
            "toy".to_string()
        }
        fn comm_model(&self) -> CommModel {
            CommModel::disabled()
        }
    }

    #[test]
    fn stops_on_gap_tolerance() {
        let mut d = Driver::new(StopPolicy::new(100).with_gap_tol(1e-2));
        let h = d.run(&mut Toy::new(0.5));
        assert_eq!(h.stop, StopReason::GapReached);
        assert!(h.final_gap() <= 1e-2);
        assert!(h.rounds_run() < 100);
    }

    #[test]
    fn stops_on_round_budget() {
        let mut d = Driver::new(StopPolicy::new(5).with_gap_tol(f64::NEG_INFINITY));
        let h = d.run(&mut Toy::new(0.5));
        assert_eq!(h.stop, StopReason::MaxRounds);
        assert_eq!(h.rounds_run(), 5);
    }

    #[test]
    fn stops_on_divergence() {
        let mut d = Driver::new(
            StopPolicy::new(100)
                .with_gap_tol(f64::NEG_INFINITY)
                .with_divergence_gap(10.0),
        );
        let h = d.run(&mut Toy::new(2.0));
        assert_eq!(h.stop, StopReason::Diverged);
        assert!(h.diverged());
    }

    #[test]
    fn stops_on_dual_target() {
        // dual = 1 − gap → suboptimality vs d* = 1 is exactly the gap.
        let mut d = Driver::new(
            StopPolicy::new(100)
                .with_gap_tol(f64::NEG_INFINITY)
                .with_dual_target(1.0, 1e-3),
        );
        let h = d.run(&mut Toy::new(0.5));
        assert_eq!(h.stop, StopReason::DualTargetReached);
        assert!(1.0 - h.final_dual() <= 1e-3);
    }

    #[test]
    fn stops_on_dual_stall() {
        // shrink = 1 → the dual never moves; first eval sets the best,
        // the next `patience` evals count as stalled.
        let mut d = Driver::new(
            StopPolicy::new(100)
                .with_gap_tol(f64::NEG_INFINITY)
                .with_dual_stall(3, 0.0),
        );
        let h = d.run(&mut Toy::new(1.0));
        assert_eq!(h.stop, StopReason::DualStalled);
        assert_eq!(h.rounds_run(), 4); // 1 improving eval + 3 stalled
    }

    #[test]
    fn certificate_cadence_and_final_round() {
        let mut d = Driver::new(StopPolicy::new(7).with_gap_tol(f64::NEG_INFINITY))
            .with_gap_every(3);
        let h = d.run(&mut Toy::new(0.9));
        let rounds: Vec<usize> = h.records.iter().map(|r| r.round).collect();
        assert_eq!(rounds, vec![0, 3, 6]);
    }

    #[test]
    fn clock_and_vectors_accumulate() {
        let mut d = Driver::new(StopPolicy::new(4).with_gap_tol(f64::NEG_INFINITY));
        let h = d.run(&mut Toy::new(0.9));
        let last = h.records.last().unwrap();
        assert_eq!(last.comm_vectors, 8); // 2 vectors × 4 rounds
        assert!((last.compute_s - 4e-3).abs() < 1e-12);
        // comm model disabled → sim clock is pure compute
        assert!((last.sim_time_s - last.compute_s).abs() < 1e-15);
        for pair in h.records.windows(2) {
            assert!(pair[1].sim_time_s > pair[0].sim_time_s);
        }
    }

    #[test]
    fn from_cocoa_config_mirrors_trainer_policy() {
        use crate::coordinator::{CocoaConfig, SolverSpec};
        use crate::loss::Loss;
        let cfg = CocoaConfig::cocoa_plus(4, Loss::Hinge, 0.1, SolverSpec::Sdca { h: 5 })
            .with_rounds(17)
            .with_gap_tol(1e-7)
            .with_gap_every(4);
        let d = Driver::from_cocoa_config(&cfg);
        assert_eq!(d.stop.max_rounds, 17);
        assert_eq!(d.stop.gap_tol, 1e-7);
        assert_eq!(d.gap_every, 4);
        assert!(d.stop.dual_target.is_none());
    }
}
