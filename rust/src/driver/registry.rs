//! Name-indexed construction of every optimizer behind one flag surface:
//! `cocoa train --method <name>` and the conformance suite both build
//! methods through here, so adding an optimizer is a one-file change
//! (implement [`Method`], add a [`MethodName`] arm).

use crate::baselines::admm::{Admm, AdmmConfig};
use crate::baselines::minibatch_sdca::{MiniBatchSdca, MiniBatchSdcaConfig};
use crate::baselines::minibatch_sgd::{MiniBatchSgd, MiniBatchSgdConfig};
use crate::baselines::one_shot::{OneShot as OneShotAveraging, OneShotConfig};
use crate::baselines::serial_sdca::{SerialSdca, SerialSdcaConfig};
use crate::coordinator::{CocoaConfig, ExecutorChoice, SolverSpec, Trainer};
use crate::data::Partition;
use crate::driver::Method;
use crate::objective::Problem;
use crate::telemetry::Recorder;

/// Every optimizer reachable from the CLI and the conformance suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodName {
    /// CoCoA+ (γ=1, σ'=K): the paper's adding regime.
    CocoaPlus,
    /// Original CoCoA (γ=1/K, σ'=1): conservative averaging.
    Cocoa,
    /// Distributed mini-batch subgradient descent (Fig. 2's third curve).
    MbSgd,
    /// Distributed mini-batch SDCA with safe 1/(K·b) scaling.
    MbSdca,
    /// One-shot averaging of independently solved local ERMs.
    OneShot,
    /// Consensus ADMM (Forero et al. 2010).
    Admm,
    /// Serial single-machine SDCA (the K=1 reference).
    SerialSdca,
}

impl MethodName {
    pub const ALL: [MethodName; 7] = [
        MethodName::CocoaPlus,
        MethodName::Cocoa,
        MethodName::MbSgd,
        MethodName::MbSdca,
        MethodName::OneShot,
        MethodName::Admm,
        MethodName::SerialSdca,
    ];

    /// The CLI spelling (also used to name output files).
    pub fn as_str(&self) -> &'static str {
        match self {
            MethodName::CocoaPlus => "cocoa-plus",
            MethodName::Cocoa => "cocoa",
            MethodName::MbSgd => "mb-sgd",
            MethodName::MbSdca => "mb-sdca",
            MethodName::OneShot => "one-shot",
            MethodName::Admm => "admm",
            MethodName::SerialSdca => "serial-sdca",
        }
    }

    /// Parse a CLI spelling (plus a few aliases kept for back-compat
    /// with the old `--variant plus|avg` flag).
    pub fn parse(s: &str) -> Option<MethodName> {
        match s {
            "cocoa-plus" | "cocoa+" | "plus" | "add" => Some(MethodName::CocoaPlus),
            "cocoa" | "avg" | "average" => Some(MethodName::Cocoa),
            "mb-sgd" | "minibatch-sgd" => Some(MethodName::MbSgd),
            "mb-sdca" | "minibatch-sdca" => Some(MethodName::MbSdca),
            "one-shot" | "oneshot" => Some(MethodName::OneShot),
            "admm" => Some(MethodName::Admm),
            "serial-sdca" | "sdca" => Some(MethodName::SerialSdca),
            _ => None,
        }
    }

    /// `cocoa-plus|cocoa|mb-sgd|…` — for help/usage strings.
    pub fn usage() -> String {
        MethodName::ALL
            .iter()
            .map(|m| m.as_str())
            .collect::<Vec<_>>()
            .join("|")
    }
}

/// The shared knob surface `cocoa train` exposes; each method reads the
/// subset it understands and ignores the rest.
#[derive(Clone, Debug)]
pub struct BuildOpts {
    /// Number of workers K (ignored by serial SDCA).
    pub k: usize,
    pub seed: u64,
    /// Local SDCA epochs per round (CoCoA variants) or total local
    /// epochs (one-shot, rounded to ≥ 1).
    pub epochs: f64,
    /// Subproblem parameter σ' override (CoCoA variants only).
    pub sigma_prime: Option<f64>,
    /// Pooled-thread vs sequential execution (CoCoA variants only).
    pub parallel: bool,
    /// Which runtime executes the local solves (CoCoA variants only);
    /// `Auto` honours `parallel`.
    pub executor: ExecutorChoice,
    /// Mini-batch size per worker per round (mb-sgd / mb-sdca).
    pub batch_per_worker: usize,
    /// Aggregation scaling β (mb-sdca).
    pub beta: f64,
    /// Augmented-Lagrangian penalty ρ (ADMM).
    pub rho: f64,
    /// Inexact local subgradient steps per round (ADMM).
    pub local_iters: usize,
    /// Flight recorder the built method traces into (CoCoA variants
    /// only); disabled by default.
    pub recorder: Recorder,
}

impl BuildOpts {
    pub fn new(k: usize) -> BuildOpts {
        BuildOpts {
            k,
            seed: 42,
            epochs: 1.0,
            sigma_prime: None,
            parallel: true,
            executor: ExecutorChoice::Auto,
            batch_per_worker: 16,
            beta: 1.0,
            rho: 1.0,
            local_iters: 50,
            recorder: Recorder::disabled(),
        }
    }
}

/// Build a boxed [`Method`] ready to hand to a
/// [`Driver`](crate::driver::Driver). Loss and λ come from `problem`;
/// stopping policy and certificate cadence belong to the Driver, not the
/// method, so the per-method configs' stopping fields are left at their
/// defaults.
pub fn build_method(
    name: MethodName,
    problem: Problem,
    partition: Partition,
    opts: &BuildOpts,
) -> Box<dyn Method> {
    match name {
        MethodName::CocoaPlus | MethodName::Cocoa => {
            let solver = SolverSpec::SdcaEpochs {
                epochs: opts.epochs,
            };
            let mut cfg = if name == MethodName::CocoaPlus {
                CocoaConfig::cocoa_plus(opts.k, problem.loss, problem.lambda, solver)
            } else {
                CocoaConfig::cocoa(opts.k, problem.loss, problem.lambda, solver)
            }
            .with_seed(opts.seed)
            .with_parallel(opts.parallel)
            .with_executor(opts.executor)
            .with_recorder(opts.recorder.clone());
            if let Some(sp) = opts.sigma_prime {
                cfg = cfg.with_sigma_prime(sp);
            }
            Box::new(Trainer::new(problem, partition, cfg))
        }
        MethodName::MbSgd => {
            let mut cfg = MiniBatchSgdConfig::new(opts.k);
            cfg.seed = opts.seed;
            cfg.batch_per_worker = opts.batch_per_worker;
            Box::new(MiniBatchSgd::new(problem, partition, cfg))
        }
        MethodName::MbSdca => {
            let mut cfg = MiniBatchSdcaConfig::new(opts.k);
            cfg.seed = opts.seed;
            cfg.batch_per_worker = opts.batch_per_worker;
            cfg.beta = opts.beta;
            Box::new(MiniBatchSdca::new(problem, partition, cfg))
        }
        MethodName::OneShot => {
            let mut cfg = OneShotConfig::new(opts.k);
            cfg.seed = opts.seed;
            cfg.local_epochs = opts.epochs.round().max(1.0) as usize;
            Box::new(OneShotAveraging::new(problem, partition, cfg))
        }
        MethodName::Admm => {
            let mut cfg = AdmmConfig::new(opts.k);
            cfg.seed = opts.seed;
            cfg.rho = opts.rho;
            cfg.local_iters = opts.local_iters;
            Box::new(Admm::new(problem, partition, cfg))
        }
        MethodName::SerialSdca => {
            let cfg = SerialSdcaConfig {
                seed: opts.seed,
                ..SerialSdcaConfig::default()
            };
            Box::new(SerialSdca::new(problem, cfg))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_canonical_spellings() {
        for name in MethodName::ALL {
            assert_eq!(MethodName::parse(name.as_str()), Some(name));
        }
        assert_eq!(MethodName::parse("plus"), Some(MethodName::CocoaPlus));
        assert_eq!(MethodName::parse("avg"), Some(MethodName::Cocoa));
        assert_eq!(MethodName::parse("frobnicate"), None);
    }

    #[test]
    fn usage_lists_all_methods() {
        let u = MethodName::usage();
        for name in MethodName::ALL {
            assert!(u.contains(name.as_str()), "usage missing {name:?}: {u}");
        }
    }
}
