//! Simple wall-clock timing helpers used by the coordinator and experiments.
//!
//! This module is the **only** place the round paths (`driver/`,
//! `solver/`, `coordinator/`) may read the wall clock: the `determinism`
//! rule of `cocoa-lint` forbids `Instant`/`SystemTime` there, so every
//! measurement or timeout funnels through [`Stopwatch`], [`timed`], or
//! [`Deadline`]. Timing is observational — it feeds `CommStats` and
//! failure reporting, never the optimization trajectory.

use std::time::{Duration, Instant};

/// A stopwatch that can be paused; the coordinator uses one per phase
/// (compute vs. reduce) so the communication model can be applied to the
/// right share of the round.
#[derive(Debug)]
pub struct Stopwatch {
    accumulated: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            accumulated: Duration::ZERO,
            started: None,
        }
    }

    /// A stopwatch that is already running — the common "time this scope"
    /// shape (`let clock = Stopwatch::started(); …; clock.elapsed_secs()`).
    pub fn started() -> Self {
        let mut sw = Self::new();
        sw.start();
        sw
    }

    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accumulated += t0.elapsed();
        }
    }

    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.accumulated + t0.elapsed(),
            None => self.accumulated,
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.accumulated = Duration::ZERO;
        self.started = None;
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// The process-wide trace epoch: fixed on first use so every
/// [`trace_now_us`] timestamp shares one origin across threads.
static TRACE_EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Microseconds since the first trace-clock read in this process — the
/// **only** wall-clock source the `telemetry` flight recorder may use.
/// Keeping the raw clock type confined to this module preserves the
/// `determinism` lint invariant (`telemetry/` is scanned like the round
/// paths), and a shared epoch keeps timestamps comparable across every
/// ring in the process. Monotone by construction.
pub fn trace_now_us() -> u64 {
    let epoch = TRACE_EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_micros().min(u128::from(u64::MAX)) as u64
}

/// A wall-clock cutoff: handshake windows, round-gather timeouts, child
/// reaping grace periods. Copyable so it can be captured once and checked
/// from several places in a polling loop.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// The point `d` from now.
    pub fn after(d: Duration) -> Deadline {
        Deadline {
            at: Instant::now() + d,
        }
    }

    /// True once the deadline has passed.
    pub fn expired(&self) -> bool {
        Instant::now() > self.at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let after_first = sw.elapsed();
        assert!(after_first >= Duration::from_millis(4));
        // paused: elapsed should not move
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(sw.elapsed(), after_first);
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > after_first);
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn started_stopwatch_is_running() {
        let sw = Stopwatch::started();
        std::thread::sleep(Duration::from_millis(3));
        assert!(sw.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn deadline_expires_and_not_before() {
        let d = Deadline::after(Duration::from_secs(3600));
        assert!(!d.expired());
        let past = Deadline::after(Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert!(past.expired());
    }

    #[test]
    fn double_start_is_idempotent() {
        let mut sw = Stopwatch::new();
        sw.start();
        sw.start();
        sw.stop();
        sw.stop();
        assert!(sw.elapsed() < Duration::from_secs(1));
    }
}
