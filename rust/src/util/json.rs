//! Minimal JSON support (writer + parser), built from scratch because the
//! offline environment has no `serde`.
//!
//! The writer covers everything the library emits (experiment reports,
//! histories). The parser covers everything it consumes (the AOT artifact
//! `manifest.json`, experiment configs). It is a strict recursive-descent
//! parser for the JSON grammar minus some exotica we never produce
//! (`\u` surrogate pairs are supported; numbers parse via Rust's f64 parser).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output ordering is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = fmt::Write::write_fmt(out, format_args!("{}", *x as i64));
                    } else {
                        let _ = fmt::Write::write_fmt(out, format_args!("{}", x));
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented behaviour).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error message with byte offset.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?} at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                            continue; // unicode_escape advanced pos itself
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| format!("invalid utf8: {e}"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, String> {
        // self.pos is at 'u'
        let hex4 = |p: &mut Self| -> Result<u32, String> {
            p.pos += 1; // consume 'u'
            if p.pos + 4 > p.bytes.len() {
                return Err("truncated \\u escape".into());
            }
            let s = std::str::from_utf8(&p.bytes[p.pos..p.pos + 4]).map_err(|e| e.to_string())?;
            let v = u32::from_str_radix(s, 16).map_err(|e| e.to_string())?;
            p.pos += 4;
            Ok(v)
        };
        let hi = hex4(self)?;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair: expect \uXXXX low surrogate
            if self.bytes.get(self.pos) == Some(&b'\\') && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 1; // consume '\'
                let lo = hex4(self)?;
                let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(cp).ok_or_else(|| "bad surrogate pair".into());
            }
            return Err("lone high surrogate".into());
        }
        char::from_u32(hi).ok_or_else(|| "bad codepoint".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']' found {:?}", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected ',' or '}}' found {:?}", other)),
            }
        }
    }
}

/// Convenience: turn a list of (key, value) into a Json object.
pub fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Convenience constructors.
pub fn jnum(x: f64) -> Json {
    Json::Num(x)
}
pub fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}
pub fn jarr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        // serialize and reparse
        let again = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(again, v);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ tab\t".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(jnum(3.0).to_string_compact(), "3");
        assert_eq!(jnum(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::obj().to_string_pretty(), "{}");
    }
}
