//! Deterministic pseudo-random number generation.
//!
//! The offline build environment ships no `rand` crate, so we implement the
//! generators we need from scratch: [`SplitMix64`] (seed expansion,
//! Steele et al. 2014) and [`Pcg32`] (O'Neill 2014, PCG-XSH-RR 64/32) as the
//! workhorse stream. Every stochastic component of the library — data
//! generation, partitioning, SDCA coordinate sampling, SGD batching — draws
//! from these so that whole experiments replay bit-identically from a seed.

/// SplitMix64: used to expand user seeds into well-mixed 64-bit state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid 32-bit generator.
///
/// `stream` selects an independent sequence; we give each worker its own
/// stream id so parallel runs are reproducible regardless of scheduling.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let init_state = sm.next_u64();
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire-style rejection).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u32;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar-free, two uniforms).
    pub fn gaussian(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// A vector of iid standard normals.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gaussian()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(7, 0);
        let mut b = Pcg32::new(7, 1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg32::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..1000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(4);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(5);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg32::seeded(6);
        let hits = (0..10_000).filter(|_| rng.bernoulli(0.3)).count();
        let rate = hits as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate {rate}");
    }
}
