//! Leveled stderr logger (no external logging backends offline).
//!
//! Verbosity is process-global and settable from the CLI (`--log debug`) or
//! the `COCOA_LOG` environment variable. The coordinator logs one line per
//! round at `Debug` and per-experiment summaries at `Info`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Initialize from `COCOA_LOG` if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("COCOA_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, msg: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn  { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn,  format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info  { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info,  format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn set_and_query() {
        let old = level();
        set_level(Level::Error);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(old);
    }
}
