//! A tiny command-line argument parser (no `clap` in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters parse on demand and report readable errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.flags
                        .insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else {
                    // `--key value` unless next token is another flag / absent.
                    let is_value_next = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_value_next {
                        let v = it.next().unwrap();
                        out.flags.insert(body.to_string(), v);
                    } else {
                        out.flags.insert(body.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} expects an integer, got {v:?}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        match self.flags.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} expects a float, got {v:?}: {e}")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a boolean, got {v:?}"),
        }
    }

    /// Parse a comma-separated list of floats, e.g. `--lambdas 1e-4,1e-5`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key}: bad float {t:?}: {e}"))
                })
                .collect(),
        }
    }

    /// Parse a comma-separated list of integers, e.g. `--ks 4,8,16`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key}: bad integer {t:?}: {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // binding is greedy: `--flag token` consumes the token as a value,
        // so boolean flags either go last or use `--flag=true`.
        let a = parse("train data.svm --k 8 --gamma=1.0 --verbose");
        assert_eq!(a.positional, vec!["train", "data.svm"]);
        assert_eq!(a.get_usize("k", 1), 8);
        assert_eq!(a.get_f64("gamma", 0.0), 1.0);
        assert!(a.get_bool("verbose", false));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("k", 4), 4);
        assert_eq!(a.get_str("loss", "hinge"), "hinge");
        assert!(!a.get_bool("quiet", false));
    }

    #[test]
    fn lists() {
        let a = parse("--lambdas 1e-4,1e-5 --ks 2,4,8");
        assert_eq!(a.get_f64_list("lambdas", &[]), vec![1e-4, 1e-5]);
        assert_eq!(a.get_usize_list("ks", &[]), vec![2, 4, 8]);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("--quiet --k 3");
        assert!(a.get_bool("quiet", false));
        assert_eq!(a.get_usize("k", 0), 3);
    }
}
