//! Micro-benchmark harness (the offline registry has no `criterion`).
//!
//! Usage from a `[[bench]] harness = false` target:
//! ```ignore
//! let mut b = Bench::new("sdca_epoch");
//! b.run("sparse_n10000", || solver.epoch(&mut state));
//! b.report();
//! ```
//! Each case is warmed up, then sampled `samples` times; we report mean,
//! p50, p95, and min. `black_box` prevents the optimizer from deleting the
//! measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl CaseResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }
    pub fn percentile(&self, p: f64) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
        s[idx]
    }
    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }
}

pub struct Bench {
    pub suite: String,
    pub warmup: usize,
    pub samples: usize,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Environment knobs so CI / quick runs can shrink the work.
        let warmup = std::env::var("BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2);
        let samples = std::env::var("BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(7);
        Self {
            suite: suite.to_string(),
            warmup,
            samples,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, samples: usize) -> Self {
        self.samples = samples;
        self
    }

    /// Time `f` (already including any per-iteration setup it owns).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &CaseResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        self.results.push(CaseResult {
            name: name.to_string(),
            samples,
        });
        self.results.last().unwrap()
    }

    pub fn report(&self) {
        println!("\n== bench suite: {} ==", self.suite);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            "case", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>12}",
                r.name,
                fmt_dur(r.mean()),
                fmt_dur(r.percentile(0.5)),
                fmt_dur(r.percentile(0.95)),
                fmt_dur(r.min()),
            );
        }
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new("test").with_samples(3);
        b.warmup = 1;
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.samples.len(), 3);
        assert!(r.min() > Duration::ZERO);
        assert!(r.mean() >= r.min());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn percentiles_ordered() {
        let r = CaseResult {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(1),
                Duration::from_millis(5),
                Duration::from_millis(3),
            ],
        };
        assert!(r.percentile(0.0) <= r.percentile(0.5));
        assert!(r.percentile(0.5) <= r.percentile(1.0));
        assert_eq!(r.min(), Duration::from_millis(1));
    }
}
