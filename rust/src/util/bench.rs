//! Micro-benchmark harness (the offline registry has no `criterion`).
//!
//! Usage from a `[[bench]] harness = false` target:
//! ```ignore
//! let mut b = Bench::new("sdca_epoch");
//! b.run("sparse_n10000", || solver.epoch(&mut state));
//! b.report();
//! ```
//! Each case is warmed up, then sampled `samples` times; we report mean,
//! p50, p95, and min. `black_box` prevents the optimizer from deleting the
//! measured work.

use crate::util::json::{jarr, jnum, jobj, jstr, Json};
use std::hint::black_box as std_black_box;
use std::path::Path;
use std::time::{Duration, Instant};

pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl CaseResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }
    pub fn percentile(&self, p: f64) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
        s[idx]
    }
    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }
}

pub struct Bench {
    pub suite: String,
    pub warmup: usize,
    pub samples: usize,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Environment knobs so CI / quick runs can shrink the work.
        let warmup = std::env::var("BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2);
        let samples = std::env::var("BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(7);
        Self {
            suite: suite.to_string(),
            warmup,
            samples,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, samples: usize) -> Self {
        self.samples = samples;
        self
    }

    /// Time `f` (already including any per-iteration setup it owns).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &CaseResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        self.results.push(CaseResult {
            name: name.to_string(),
            samples,
        });
        self.results.last().unwrap()
    }

    /// Machine-readable form of the suite: one object per case with the
    /// summary statistics in seconds, plus the sampling configuration so
    /// a CI artifact is self-describing.
    pub fn to_json(&self) -> Json {
        jobj(vec![
            ("suite", jstr(&self.suite)),
            ("warmup", jnum(self.warmup as f64)),
            ("samples", jnum(self.samples as f64)),
            (
                "cases",
                jarr(
                    self.results
                        .iter()
                        .map(|r| {
                            jobj(vec![
                                ("name", jstr(&r.name)),
                                ("mean_s", jnum(r.mean().as_secs_f64())),
                                ("p50_s", jnum(r.percentile(0.5).as_secs_f64())),
                                ("p95_s", jnum(r.percentile(0.95).as_secs_f64())),
                                ("min_s", jnum(r.min().as_secs_f64())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write [`Bench::to_json`] to `path` (creating parent directories).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())
    }

    /// Honour the `BENCH_JSON` env var: when set, write the JSON report
    /// there. CI points this at an artifact path; local runs that leave
    /// it unset pay nothing.
    pub fn maybe_write_json_env(&self) {
        if let Ok(path) = std::env::var("BENCH_JSON") {
            if path.is_empty() {
                return;
            }
            match self.write_json(Path::new(&path)) {
                Ok(()) => println!("bench json written to {path}"),
                Err(e) => eprintln!("warning: cannot write bench json to {path}: {e}"),
            }
        }
    }

    pub fn report(&self) {
        println!("\n== bench suite: {} ==", self.suite);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            "case", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>12}",
                r.name,
                fmt_dur(r.mean()),
                fmt_dur(r.percentile(0.5)),
                fmt_dur(r.percentile(0.95)),
                fmt_dur(r.min()),
            );
        }
    }
}

// ---------------------------------------------------------------------
// Snapshot comparison: committed BENCH_<pr>.json baselines vs a fresh run
// ---------------------------------------------------------------------

/// One case of a committed `BENCH_<pr>.json` snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineCase {
    pub name: String,
    pub mean_s: f64,
    pub min_s: f64,
}

/// A parsed bench snapshot (the schema [`Bench::to_json`] writes).
#[derive(Clone, Debug)]
pub struct Baseline {
    pub suite: String,
    pub cases: Vec<BaselineCase>,
}

/// Parse a snapshot from its JSON text. Tolerant of extra keys (p50/p95
/// are carried but not compared: `min` is the noise-robust statistic).
pub fn parse_baseline(text: &str) -> Result<Baseline, String> {
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let suite = doc
        .get("suite")
        .and_then(|v| v.as_str())
        .ok_or("missing \"suite\"")?
        .to_string();
    let cases_json = doc
        .get("cases")
        .and_then(|v| v.as_arr())
        .ok_or("missing \"cases\" array")?;
    let mut cases = Vec::with_capacity(cases_json.len());
    for (i, c) in cases_json.iter().enumerate() {
        let name = c
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("case {i}: missing \"name\""))?;
        let num = |key: &str| -> Result<f64, String> {
            let x = c
                .get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("case {name:?}: missing {key:?}"))?;
            if !(x.is_finite() && x >= 0.0) {
                return Err(format!(
                    "case {name:?}: {key:?} must be finite and non-negative, got {x}"
                ));
            }
            Ok(x)
        };
        cases.push(BaselineCase {
            name: name.to_string(),
            mean_s: num("mean_s")?,
            min_s: num("min_s")?,
        });
    }
    Ok(Baseline { suite, cases })
}

/// Read and parse a committed snapshot file.
pub fn load_baseline(path: &Path) -> Result<Baseline, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    parse_baseline(&text)
}

/// One case present in both snapshots.
#[derive(Clone, Debug)]
pub struct CaseDelta {
    pub name: String,
    pub base_min_s: f64,
    pub cur_min_s: f64,
}

impl CaseDelta {
    /// Baseline/current min-time ratio: > 1 is a speedup, < 1 a slowdown.
    pub fn speedup(&self) -> f64 {
        if self.cur_min_s > 0.0 {
            self.base_min_s / self.cur_min_s
        } else {
            f64::INFINITY
        }
    }

    /// A case regresses when it got slower by more than `threshold`×
    /// (1.5 tolerates 50% run-to-run noise before failing the gate).
    pub fn is_regression(&self, threshold: f64) -> bool {
        self.cur_min_s > self.base_min_s * threshold
    }
}

/// Per-case deltas plus the cases only one side has (renames/new work
/// are reported, never silently dropped).
#[derive(Clone, Debug, Default)]
pub struct Comparison {
    pub deltas: Vec<CaseDelta>,
    /// Cases only in the baseline (removed since the snapshot).
    pub only_base: Vec<String>,
    /// Cases only in the current run (new since the snapshot).
    pub only_cur: Vec<String>,
}

/// Match cases by name (current-run order) and compute the deltas.
pub fn compare(base: &Baseline, cur: &Baseline) -> Comparison {
    let mut cmp = Comparison::default();
    for c in &cur.cases {
        match base.cases.iter().find(|b| b.name == c.name) {
            Some(b) => cmp.deltas.push(CaseDelta {
                name: c.name.clone(),
                base_min_s: b.min_s,
                cur_min_s: c.min_s,
            }),
            None => cmp.only_cur.push(c.name.clone()),
        }
    }
    for b in &base.cases {
        if !cur.cases.iter().any(|c| c.name == b.name) {
            cmp.only_base.push(b.name.clone());
        }
    }
    cmp
}

impl Comparison {
    /// The deltas that fail the `threshold`× slowdown gate.
    pub fn regressions(&self, threshold: f64) -> Vec<&CaseDelta> {
        self.deltas
            .iter()
            .filter(|d| d.is_regression(threshold))
            .collect()
    }

    /// Aligned per-case delta table (what `bench_compare` prints).
    pub fn render(&self, threshold: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>9}\n",
            "case", "baseline", "current", "speedup"
        ));
        for d in &self.deltas {
            let flag = if d.is_regression(threshold) {
                "  REGRESSION"
            } else {
                ""
            };
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>8.2}x{flag}\n",
                d.name,
                fmt_dur(Duration::from_secs_f64(d.base_min_s)),
                fmt_dur(Duration::from_secs_f64(d.cur_min_s)),
                d.speedup(),
            ));
        }
        for name in &self.only_cur {
            out.push_str(&format!("{name:<44} (new: not in baseline)\n"));
        }
        for name in &self.only_base {
            out.push_str(&format!("{name:<44} (removed: baseline only)\n"));
        }
        out
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new("test").with_samples(3);
        b.warmup = 1;
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.samples.len(), 3);
        assert!(r.min() > Duration::ZERO);
        assert!(r.mean() >= r.min());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn json_report_carries_all_cases() {
        let mut b = Bench::new("json").with_samples(2);
        b.warmup = 0;
        b.run("a", || black_box(1 + 1));
        b.run("b", || black_box(2 + 2));
        let j = b.to_json();
        assert_eq!(j.get("suite").and_then(|v| v.as_str()), Some("json"));
        let cases = j.get("cases").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(cases.len(), 2);
        for (case, name) in cases.iter().zip(["a", "b"]) {
            assert_eq!(case.get("name").and_then(|v| v.as_str()), Some(name));
            for stat in ["mean_s", "p50_s", "p95_s", "min_s"] {
                let v = case.get(stat).and_then(|v| v.as_f64()).unwrap();
                assert!(v.is_finite() && v >= 0.0, "{name}.{stat} = {v}");
            }
        }
        // And the compact text parses back.
        let text = j.to_string_compact();
        assert!(Json::parse(&text).is_ok(), "unparseable: {text}");
    }

    #[test]
    fn write_json_creates_parents() {
        let dir = std::env::temp_dir().join("cocoa_bench_json_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("nested").join("bench.json");
        let mut b = Bench::new("disk").with_samples(1);
        b.warmup = 0;
        b.run("only", || black_box(0));
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("suite").and_then(|v| v.as_str()), Some("disk"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn baseline_round_trips_through_bench_json() {
        let mut b = Bench::new("rt").with_samples(2);
        b.warmup = 0;
        b.run("k1", || black_box(1));
        b.run("k2", || black_box(2));
        let base = parse_baseline(&b.to_json().to_string_compact()).unwrap();
        assert_eq!(base.suite, "rt");
        assert_eq!(base.cases.len(), 2);
        assert_eq!(base.cases[0].name, "k1");
        assert!(base.cases.iter().all(|c| c.min_s >= 0.0 && c.mean_s >= c.min_s));
    }

    #[test]
    fn parse_baseline_rejects_malformed() {
        assert!(parse_baseline("not json").is_err());
        assert!(parse_baseline("{\"suite\":\"s\"}").is_err());
        let bad_num = "{\"suite\":\"s\",\"cases\":[{\"name\":\"a\",\"mean_s\":-1,\"min_s\":0}]}";
        assert!(parse_baseline(bad_num).is_err());
    }

    fn snap(cases: &[(&str, f64)]) -> Baseline {
        Baseline {
            suite: "s".into(),
            cases: cases
                .iter()
                .map(|&(name, min_s)| BaselineCase {
                    name: name.into(),
                    mean_s: min_s,
                    min_s,
                })
                .collect(),
        }
    }

    #[test]
    fn compare_flags_regressions_and_set_differences() {
        let base = snap(&[("same", 1e-3), ("faster", 2e-3), ("slower", 1e-3), ("gone", 1e-3)]);
        let cur = snap(&[("same", 1e-3), ("faster", 1e-3), ("slower", 2e-3), ("new", 1e-3)]);
        let cmp = compare(&base, &cur);
        assert_eq!(cmp.deltas.len(), 3);
        assert_eq!(cmp.only_base, vec!["gone".to_string()]);
        assert_eq!(cmp.only_cur, vec!["new".to_string()]);
        let regs = cmp.regressions(1.5);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "slower");
        assert!((regs[0].speedup() - 0.5).abs() < 1e-12);
        // the 2× slowdown passes a laxer gate
        assert!(cmp.regressions(2.5).is_empty());
        let table = cmp.render(1.5);
        assert!(table.contains("REGRESSION"), "{table}");
        assert!(table.contains("(new: not in baseline)"), "{table}");
        assert!(table.contains("(removed: baseline only)"), "{table}");
    }

    #[test]
    fn percentiles_ordered() {
        let r = CaseResult {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(1),
                Duration::from_millis(5),
                Duration::from_millis(3),
            ],
        };
        assert!(r.percentile(0.0) <= r.percentile(0.5));
        assert!(r.percentile(0.5) <= r.percentile(1.0));
        assert_eq!(r.min(), Duration::from_millis(1));
    }
}
