//! Substrate utilities built from scratch for the offline environment:
//! RNG, JSON, CLI parsing, timing, logging, and a micro-bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod timer;
