//! Minimal HTTP/1.1 framing for `cocoa serve` — request parser and
//! response writer, dependency-free, built with the wire.rs hostile-input
//! discipline: hard size caps, typed errors, per-read socket timeouts
//! surfaced as [`HttpError::Timeout`], and a wall-clock parse budget so a
//! byte-dripping peer cannot hold a worker hostage. A malformed request
//! costs the client one 4xx response and its connection — never a hang,
//! never the server.
//!
//! Scope is deliberately one rung above the wire format and far below a
//! general web server: one request per connection (`Connection: close`),
//! declared `Content-Length` bodies only (chunked transfer encoding is
//! rejected), JSON payloads handled by `util::json` at the router layer.

use std::io::{ErrorKind, Read, Write};
use std::time::{Duration, Instant};

/// Cap on the request line + header block. 16 KiB fits any sane client;
/// anything larger is a header bomb and gets 431.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on a declared request body (4 MiB bounds predict batches).
pub const DEFAULT_MAX_BODY_BYTES: usize = 4 << 20;

/// Framing limits enforced while reading one request.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    pub max_head_bytes: usize,
    pub max_body_bytes: usize,
    /// Wall-clock budget for parsing one full request: catches peers that
    /// drip bytes just fast enough to defeat the per-read socket timeout.
    pub parse_budget: Duration,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_head_bytes: MAX_HEAD_BYTES,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            parse_budget: Duration::from_secs(10),
        }
    }
}

/// Typed request-framing failures, in the spirit of `wire::WireError`.
#[derive(Debug)]
pub enum HttpError {
    /// Clean EOF before the first request byte (client connected and left).
    Closed,
    /// Peer stopped mid-request.
    Truncated,
    /// Request line, headers, or body don't parse.
    Malformed(String),
    /// A size cap was exceeded; `what` names which ("head" or "body").
    TooLarge {
        what: &'static str,
        len: usize,
        limit: usize,
    },
    /// A read timed out (stalled or byte-dripping peer).
    Timeout,
    Io(std::io::Error),
}

impl HttpError {
    /// Status code for the error response; `None` means the peer is gone
    /// (or the transport failed) and no response should be attempted.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Malformed(_) | HttpError::Truncated => Some(400),
            HttpError::TooLarge { what: "head", .. } => Some(431),
            HttpError::TooLarge { .. } => Some(413),
            HttpError::Timeout => Some(408),
            HttpError::Closed | HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => write!(f, "connection closed before a request"),
            HttpError::Truncated => write!(f, "connection closed mid-request"),
            HttpError::Malformed(msg) => write!(f, "malformed request: {msg}"),
            HttpError::TooLarge { what, len, limit } => {
                write!(f, "request {what} too large: {len} bytes (limit {limit})")
            }
            HttpError::Timeout => write!(f, "timed out reading request"),
            HttpError::Io(e) => write!(f, "io error reading request: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// One parsed request. Header names are lowercased at parse time.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Target path with any `?query` suffix stripped.
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (pass the name in lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// The body as UTF-8 text, or a 400-worthy error.
    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::Malformed("body is not valid UTF-8".into()))
    }
}

fn read_byte<R: Read>(r: &mut R) -> Result<Option<u8>, HttpError> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            // A one-byte array always has a first element; `first` keeps
            // the no-panic surface free of direct indexing.
            Ok(_) => return Ok(b.first().copied()),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Err(HttpError::Timeout)
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

/// Read and parse exactly one request, enforcing every limit in `limits`.
/// The head is read byte-by-byte (wrap the stream in a `BufReader`), the
/// body in bulk after its declared length passes the cap — an oversized
/// declaration is rejected *before* any allocation.
pub fn read_request<R: Read>(r: &mut R, limits: &Limits) -> Result<Request, HttpError> {
    let t0 = Instant::now();
    let mut head: Vec<u8> = Vec::with_capacity(256);
    loop {
        if head.len() >= limits.max_head_bytes {
            return Err(HttpError::TooLarge {
                what: "head",
                len: head.len(),
                limit: limits.max_head_bytes,
            });
        }
        if t0.elapsed() > limits.parse_budget {
            return Err(HttpError::Timeout);
        }
        match read_byte(r)? {
            None if head.is_empty() => return Err(HttpError::Closed),
            None => return Err(HttpError::Truncated),
            Some(b) => head.push(b),
        }
        if head.ends_with(b"\r\n\r\n") {
            break;
        }
    }
    // The loop above only exits on a trailing CRLFCRLF, so the strip
    // cannot fail; the typed fallback replaces a `head[..len - 4]` slice
    // that would be a panic site on a hostile surface.
    let head_text = head
        .strip_suffix(b"\r\n\r\n")
        .ok_or_else(|| HttpError::Malformed("missing header terminator".into()))?;
    let text = std::str::from_utf8(head_text)
        .map_err(|_| HttpError::Malformed("header bytes are not UTF-8".into()))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(HttpError::Malformed(format!(
                "bad request line {request_line:?}"
            )))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!(
            "unsupported protocol {version:?}"
        )));
    }
    let path = target.split('?').next().unwrap_or(target).to_string();
    let mut headers = Vec::new();
    for line in lines {
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("bad header line {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let mut req = Request {
        method: method.to_string(),
        path,
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::Malformed(
            "chunked transfer encoding unsupported (send Content-Length)".into(),
        ));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::Malformed(format!("bad Content-Length {v:?}")))?,
    };
    if len > limits.max_body_bytes {
        return Err(HttpError::TooLarge {
            what: "body",
            len,
            limit: limits.max_body_bytes,
        });
    }
    if len > 0 {
        let mut body = vec![0u8; len];
        let mut filled = 0;
        while filled < len {
            if t0.elapsed() > limits.parse_budget {
                return Err(HttpError::Timeout);
            }
            // `filled < len == body.len()`, so the tail is never empty;
            // the empty-slice default keeps the bounds proof out of the
            // panic domain (reading into it would just yield Truncated).
            let tail = body.get_mut(filled..).unwrap_or_default();
            match r.read(tail) {
                Ok(0) => return Err(HttpError::Truncated),
                Ok(k) => filled += k,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    return Err(HttpError::Timeout)
                }
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
        req.body = body;
    }
    Ok(req)
}

/// The standard reason phrase for the statuses the router emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// One response, always a JSON body, always `Connection: close`.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub body: String,
}

impl Response {
    pub fn json(status: u16, body: crate::util::json::Json) -> Response {
        Response {
            status,
            body: body.to_string_compact(),
        }
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(
            status,
            crate::util::json::jobj(vec![("error", crate::util::json::jstr(msg))]),
        )
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            self.status,
            reason(self.status),
            self.body.len(),
            self.body
        )?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut Cursor::new(bytes.to_vec()), &Limits::default())
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body_and_query_strip() {
        let req = parse(
            b"POST /predict?debug=1 HTTP/1.1\r\nContent-Length: 7\r\nX-Thing: a b\r\n\r\n{\"x\":1}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.header("x-thing"), Some("a b"));
        assert_eq!(req.body_str().unwrap(), "{\"x\":1}");
    }

    #[test]
    fn garbage_request_line_is_malformed() {
        for bad in [
            &b"FROB\r\n\r\n"[..],
            b" / HTTP/1.1\r\n\r\n",
            b"GET /x SPDY/3\r\n\r\n",
            b"GET /x HTTP/1.1 extra\r\n\r\n",
        ] {
            match parse(bad) {
                Err(HttpError::Malformed(_)) => {}
                other => panic!("{bad:?} → {other:?}"),
            }
        }
    }

    #[test]
    fn bare_crlf_head_is_malformed_not_a_panic() {
        // Regression for the strip_suffix rewrite: a head that is *only*
        // the terminator leaves an empty request line → 400, never a
        // slice panic on `head[..len - 4]`.
        assert!(matches!(parse(b"\r\n\r\n"), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn header_without_colon_is_malformed() {
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nnocolonhere\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn empty_and_truncated_streams_are_typed() {
        assert!(matches!(parse(b""), Err(HttpError::Closed)));
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nHost:"),
            Err(HttpError::Truncated)
        ));
        // declared body longer than what arrives
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(HttpError::Truncated)
        ));
    }

    #[test]
    fn oversized_head_is_431_worthy() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(vec![b'a'; MAX_HEAD_BYTES + 10]);
        match parse(&raw) {
            Err(e @ HttpError::TooLarge { what: "head", .. }) => {
                assert_eq!(e.status(), Some(431))
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn oversized_declared_body_rejected_before_allocation() {
        let raw = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            usize::MAX / 2
        );
        match parse(raw.as_bytes()) {
            Err(e @ HttpError::TooLarge { what: "body", .. }) => {
                assert_eq!(e.status(), Some(413))
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bad_content_length_and_chunked_are_malformed() {
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: -3\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(HttpError::Malformed(_))
        ));
    }

    #[test]
    fn response_writer_emits_framed_json() {
        let mut out = Vec::new();
        Response::error(404, "no such endpoint")
            .write_to(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body, "{\"error\":\"no such endpoint\"}");
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
    }

    #[test]
    fn error_statuses_map_to_4xx_never_5xx() {
        let cases: Vec<HttpError> = vec![
            HttpError::Malformed("x".into()),
            HttpError::Truncated,
            HttpError::Timeout,
            HttpError::TooLarge {
                what: "body",
                len: 9,
                limit: 1,
            },
        ];
        for e in cases {
            let s = e.status().unwrap();
            assert!((400..500).contains(&s), "{e} → {s}");
        }
        assert_eq!(HttpError::Closed.status(), None);
    }
}
