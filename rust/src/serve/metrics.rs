//! Serving metrics for `cocoa serve`, built on the shared
//! [`crate::telemetry::metrics`] primitives (relaxed-atomic counters,
//! gauges, and the fixed log-spaced latency histogram) registered in a
//! [`Registry`] — the same implementation the training CLI summary
//! reads through. Recording costs the predict hot path one relaxed
//! atomic op; `GET /metrics` renders a consistent-enough JSON snapshot
//! in the exact shape this endpoint has always served, plus a `queue`
//! section exposing accept-queue depth/saturation.

pub use crate::telemetry::metrics::BUCKET_US;
use crate::telemetry::metrics::{Counter, Gauge, Histogram, Registry};
use crate::util::json::{jnum, jobj, Json};
use crate::util::timer::trace_now_us;
use std::sync::Arc;
use std::time::Duration;

/// The serve layer's metric handles. All counters live in a shared
/// [`Registry`] (name-indexed, inspectable via [`Metrics::registry`]);
/// the struct caches the `Arc` handles so the hot path never touches
/// the registry lock.
#[derive(Debug)]
pub struct Metrics {
    registry: Arc<Registry>,
    /// Trace-epoch microseconds at construction (the uptime origin).
    started_us: u64,
    in_flight: Arc<Gauge>,
    requests_total: Arc<Counter>,
    responses_2xx: Arc<Counter>,
    responses_4xx: Arc<Counter>,
    responses_5xx: Arc<Counter>,
    predictions_total: Arc<Counter>,
    reloads_total: Arc<Counter>,
    retrains_total: Arc<Counter>,
    latency: Arc<Histogram>,
    queue_depth: Arc<Gauge>,
    queue_capacity: Arc<Gauge>,
    queue_saturated_total: Arc<Counter>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        let registry = Arc::new(Registry::new());
        Metrics {
            started_us: trace_now_us(),
            in_flight: registry.gauge("http.in_flight"),
            requests_total: registry.counter("http.requests_total"),
            responses_2xx: registry.counter("http.responses_2xx"),
            responses_4xx: registry.counter("http.responses_4xx"),
            responses_5xx: registry.counter("http.responses_5xx"),
            predictions_total: registry.counter("predictions_total"),
            reloads_total: registry.counter("reloads_total"),
            retrains_total: registry.counter("retrains_total"),
            latency: registry.histogram("http.latency_us"),
            queue_depth: registry.gauge("queue.depth"),
            queue_capacity: registry.gauge("queue.capacity"),
            queue_saturated_total: registry.counter("queue.saturated_total"),
            registry,
        }
    }

    /// The backing registry (name-indexed view of every handle above,
    /// for summaries and embedders that add their own metrics).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Mark one request in flight; the returned guard decrements the
    /// gauge on drop, so an unwinding handler cannot leak an in-flight.
    pub fn begin(&self) -> InFlight<'_> {
        self.requests_total.inc();
        self.in_flight.inc();
        InFlight { metrics: self }
    }

    /// Record the response status class and end-to-end handler latency.
    pub fn record_response(&self, status: u16, elapsed: Duration) {
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.inc();
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        self.latency.observe_us(us);
    }

    pub fn record_predictions(&self, count: u64) {
        self.predictions_total.add(count);
    }

    pub fn record_reload(&self) {
        self.reloads_total.inc();
    }

    pub fn record_retrain(&self) {
        self.retrains_total.inc();
    }

    /// Record the accept queue's configured capacity (once, at startup).
    pub fn set_queue_capacity(&self, capacity: u64) {
        self.queue_capacity.set(capacity);
    }

    /// One connection entered the accept queue.
    pub fn queue_enqueued(&self) {
        self.queue_depth.inc();
    }

    /// One connection left the accept queue for a worker.
    pub fn queue_dequeued(&self) {
        self.queue_depth.dec();
    }

    /// The accept queue was full when a connection arrived (the accept
    /// thread is now applying backpressure).
    pub fn record_queue_saturated(&self) {
        self.queue_saturated_total.inc();
    }

    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.get()
    }

    pub fn queue_saturated_total(&self) -> u64 {
        self.queue_saturated_total.get()
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.get()
    }

    pub fn requests_total(&self) -> u64 {
        self.requests_total.get()
    }

    /// The `GET /metrics` snapshot. Counters are read relaxed and
    /// independently — momentarily inconsistent under load, monotone
    /// per-counter, which is all a scraper needs. The shape is the
    /// endpoint's long-standing contract; `queue` is the one addition.
    pub fn to_json(&self) -> Json {
        let uptime_us = trace_now_us().saturating_sub(self.started_us);
        jobj(vec![
            ("uptime_s", jnum(uptime_us as f64 / 1e6)),
            ("in_flight", jnum(self.in_flight.get() as f64)),
            ("requests_total", jnum(self.requests_total.get() as f64)),
            (
                "responses",
                jobj(vec![
                    ("2xx", jnum(self.responses_2xx.get() as f64)),
                    ("4xx", jnum(self.responses_4xx.get() as f64)),
                    ("5xx", jnum(self.responses_5xx.get() as f64)),
                ]),
            ),
            (
                "predictions_total",
                jnum(self.predictions_total.get() as f64),
            ),
            ("reloads_total", jnum(self.reloads_total.get() as f64)),
            ("retrains_total", jnum(self.retrains_total.get() as f64)),
            ("latency", self.latency.to_json()),
            (
                "queue",
                jobj(vec![
                    ("depth", jnum(self.queue_depth.get() as f64)),
                    ("capacity", jnum(self.queue_capacity.get() as f64)),
                    (
                        "saturated_total",
                        jnum(self.queue_saturated_total.get() as f64),
                    ),
                ]),
            ),
        ])
    }
}

/// RAII in-flight guard returned by [`Metrics::begin`].
pub struct InFlight<'a> {
    metrics: &'a Metrics,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.metrics.in_flight.dec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauge_track_requests() {
        let m = Metrics::new();
        {
            let _g = m.begin();
            let _g2 = m.begin();
            assert_eq!(m.in_flight(), 2);
        }
        assert_eq!(m.in_flight(), 0, "guards must decrement on drop");
        assert_eq!(m.requests_total(), 2);
        m.record_response(200, Duration::from_micros(80));
        m.record_response(404, Duration::from_micros(3));
        m.record_response(500, Duration::from_millis(20));
        let j = m.to_json();
        let resp = j.get("responses").unwrap();
        assert_eq!(resp.get("2xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("4xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("5xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("latency").unwrap().get("count").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn histogram_places_latencies_in_right_buckets() {
        let m = Metrics::new();
        // 80µs → bucket le=100; 3µs → le=50; exactly 50µs → le=50 (≤ is
        // inclusive); 2s → overflow bucket
        m.record_response(200, Duration::from_micros(80));
        m.record_response(200, Duration::from_micros(3));
        m.record_response(200, Duration::from_micros(50));
        m.record_response(200, Duration::from_secs(2));
        let j = m.to_json();
        let buckets = j
            .get("latency")
            .unwrap()
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.get("count").unwrap().as_f64().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(buckets.len(), BUCKET_US.len() + 1);
        assert_eq!(buckets[0], 2.0, "le=50µs bucket: {buckets:?}");
        assert_eq!(buckets[1], 1.0, "le=100µs bucket: {buckets:?}");
        assert_eq!(buckets[BUCKET_US.len()], 1.0, "+∞ bucket: {buckets:?}");
    }

    #[test]
    fn prediction_and_admin_counters_accumulate() {
        let m = Metrics::new();
        m.record_predictions(64);
        m.record_predictions(1);
        m.record_reload();
        m.record_retrain();
        let j = m.to_json();
        assert_eq!(j.get("predictions_total").unwrap().as_f64(), Some(65.0));
        assert_eq!(j.get("reloads_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("retrains_total").unwrap().as_f64(), Some(1.0));
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn queue_section_reports_depth_capacity_and_saturation() {
        let m = Metrics::new();
        m.set_queue_capacity(256);
        m.queue_enqueued();
        m.queue_enqueued();
        m.queue_dequeued();
        m.record_queue_saturated();
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.queue_saturated_total(), 1);
        let q = m.to_json();
        let q = q.get("queue").unwrap();
        assert_eq!(q.get("depth").unwrap().as_f64(), Some(1.0));
        assert_eq!(q.get("capacity").unwrap().as_f64(), Some(256.0));
        assert_eq!(q.get("saturated_total").unwrap().as_f64(), Some(1.0));
        // the same handles are visible through the shared registry
        let lines = m.registry().summary_lines();
        assert!(
            lines.iter().any(|l| l == "queue.depth=1"),
            "registry view missing queue.depth: {lines:?}"
        );
    }
}
