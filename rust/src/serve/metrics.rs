//! Lock-free serving metrics: request counters, status classes, an
//! in-flight gauge (RAII guard so a panicking handler still decrements),
//! and a fixed log-spaced latency histogram. Everything is relaxed
//! atomics — recording must cost the predict hot path nanoseconds — and
//! `GET /metrics` renders a consistent-enough JSON snapshot.

use crate::util::json::{jarr, jnum, jobj, jstr, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Histogram bucket upper bounds in microseconds (log-spaced); a final
/// implicit +∞ bucket catches the rest. Fixed buckets keep recording a
/// single atomic increment.
pub const BUCKET_US: [u64; 10] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 100_000, 1_000_000,
];

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    in_flight: AtomicU64,
    requests_total: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    predictions_total: AtomicU64,
    reloads_total: AtomicU64,
    retrains_total: AtomicU64,
    latency_buckets: [AtomicU64; BUCKET_US.len() + 1],
    latency_sum_us: AtomicU64,
    latency_count: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            in_flight: AtomicU64::new(0),
            requests_total: AtomicU64::new(0),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            predictions_total: AtomicU64::new(0),
            reloads_total: AtomicU64::new(0),
            retrains_total: AtomicU64::new(0),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_sum_us: AtomicU64::new(0),
            latency_count: AtomicU64::new(0),
        }
    }

    /// Mark one request in flight; the returned guard decrements the
    /// gauge on drop, so an unwinding handler cannot leak an in-flight.
    pub fn begin(&self) -> InFlight<'_> {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        InFlight { metrics: self }
    }

    /// Record the response status class and end-to-end handler latency.
    pub fn record_response(&self, status: u16, elapsed: Duration) {
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = BUCKET_US.partition_point(|&le| us > le);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        self.latency_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_predictions(&self, count: u64) {
        self.predictions_total.fetch_add(count, Ordering::Relaxed);
    }

    pub fn record_reload(&self) {
        self.reloads_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retrain(&self) {
        self.retrains_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn requests_total(&self) -> u64 {
        self.requests_total.load(Ordering::Relaxed)
    }

    /// The `GET /metrics` snapshot. Counters are read relaxed and
    /// independently — momentarily inconsistent under load, monotone
    /// per-counter, which is all a scraper needs.
    pub fn to_json(&self) -> Json {
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let buckets: Vec<Json> = self
            .latency_buckets
            .iter()
            .enumerate()
            .map(|(i, count)| {
                let le = if i < BUCKET_US.len() {
                    jnum(BUCKET_US[i] as f64)
                } else {
                    jstr("inf")
                };
                jobj(vec![("le_us", le), ("count", jnum(load(count)))])
            })
            .collect();
        jobj(vec![
            ("uptime_s", jnum(self.started.elapsed().as_secs_f64())),
            ("in_flight", jnum(load(&self.in_flight))),
            ("requests_total", jnum(load(&self.requests_total))),
            (
                "responses",
                jobj(vec![
                    ("2xx", jnum(load(&self.responses_2xx))),
                    ("4xx", jnum(load(&self.responses_4xx))),
                    ("5xx", jnum(load(&self.responses_5xx))),
                ]),
            ),
            ("predictions_total", jnum(load(&self.predictions_total))),
            ("reloads_total", jnum(load(&self.reloads_total))),
            ("retrains_total", jnum(load(&self.retrains_total))),
            (
                "latency",
                jobj(vec![
                    ("buckets", jarr(buckets)),
                    ("sum_us", jnum(load(&self.latency_sum_us))),
                    ("count", jnum(load(&self.latency_count))),
                ]),
            ),
        ])
    }
}

/// RAII in-flight guard returned by [`Metrics::begin`].
pub struct InFlight<'a> {
    metrics: &'a Metrics,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauge_track_requests() {
        let m = Metrics::new();
        {
            let _g = m.begin();
            let _g2 = m.begin();
            assert_eq!(m.in_flight(), 2);
        }
        assert_eq!(m.in_flight(), 0, "guards must decrement on drop");
        assert_eq!(m.requests_total(), 2);
        m.record_response(200, Duration::from_micros(80));
        m.record_response(404, Duration::from_micros(3));
        m.record_response(500, Duration::from_millis(20));
        let j = m.to_json();
        let resp = j.get("responses").unwrap();
        assert_eq!(resp.get("2xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("4xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(resp.get("5xx").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("latency").unwrap().get("count").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn histogram_places_latencies_in_right_buckets() {
        let m = Metrics::new();
        // 80µs → bucket le=100; 3µs → le=50; exactly 50µs → le=50 (≤ is
        // inclusive); 2s → overflow bucket
        m.record_response(200, Duration::from_micros(80));
        m.record_response(200, Duration::from_micros(3));
        m.record_response(200, Duration::from_micros(50));
        m.record_response(200, Duration::from_secs(2));
        let j = m.to_json();
        let buckets = j
            .get("latency")
            .unwrap()
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|b| b.get("count").unwrap().as_f64().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(buckets.len(), BUCKET_US.len() + 1);
        assert_eq!(buckets[0], 2.0, "le=50µs bucket: {buckets:?}");
        assert_eq!(buckets[1], 1.0, "le=100µs bucket: {buckets:?}");
        assert_eq!(buckets[BUCKET_US.len()], 1.0, "+∞ bucket: {buckets:?}");
    }

    #[test]
    fn prediction_and_admin_counters_accumulate() {
        let m = Metrics::new();
        m.record_predictions(64);
        m.record_predictions(1);
        m.record_reload();
        m.record_retrain();
        let j = m.to_json();
        assert_eq!(j.get("predictions_total").unwrap().as_f64(), Some(65.0));
        assert_eq!(j.get("reloads_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("retrains_total").unwrap().as_f64(), Some(1.0));
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }
}
