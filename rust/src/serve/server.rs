//! TCP front end for `cocoa serve`: a bounded accept loop feeding a
//! fixed worker pool, patterned on the PR 1 pooled executor (named
//! threads, bounded handoff queue, deterministic shutdown, never a
//! hang). Each connection is one request/response exchange
//! (`Connection: close`); workers apply the wire limits from
//! [`crate::serve::http`] so a hostile or stalled client costs at most
//! one worker for one read-timeout, never the server.
//!
//! Shutdown is cooperative: `POST /quit` (or [`ServerHandle::shutdown`])
//! sets the quit flag, the accept thread notices within one poll tick
//! and drops the queue sender, and the workers drain what was already
//! accepted and exit. Pure-std cannot install a SIGTERM handler, so
//! orchestration that wants a graceful stop POSTs `/quit`; SIGTERM still
//! kills the process, it just skips the drain.

use crate::serve::http::{
    read_request, HttpError, Limits, Response, DEFAULT_MAX_BODY_BYTES, MAX_HEAD_BYTES,
};
use crate::serve::predict::Model;
use crate::serve::router::{route, AppState};
use crate::telemetry::Recorder;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Poll interval of the non-blocking accept loop. Short enough that
/// `/quit` feels immediate, long enough to stay invisible in a profile.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 picks a free port).
    pub addr: String,
    /// Worker threads handling connections.
    pub threads: usize,
    /// Accepted-but-unhandled connections the queue will hold before the
    /// accept thread itself blocks (natural backpressure).
    pub queue_depth: usize,
    /// Per-socket read/write timeout; a stalled client is cut off here.
    pub read_timeout: Duration,
    /// Largest request body a client may declare.
    pub max_body_bytes: usize,
    /// Flight recorder for the serve path (`--trace-out`); disabled by
    /// default. Each worker records one "request" span per connection.
    pub trace: Recorder,
}

impl ServeConfig {
    pub fn new(addr: &str) -> ServeConfig {
        let threads = thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .clamp(2, 16);
        ServeConfig {
            addr: addr.to_string(),
            threads,
            queue_depth: 256,
            read_timeout: Duration::from_secs(5),
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            trace: Recorder::disabled(),
        }
    }

    /// Attach a flight recorder to the serve path.
    pub fn with_recorder(mut self, recorder: Recorder) -> ServeConfig {
        self.trace = recorder;
        self
    }
}

/// Bind, spawn the pool, and return immediately. The caller owns the
/// [`ServerHandle`]; dropping it shuts the server down.
pub fn serve(model: Model, cfg: ServeConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let state = Arc::new(AppState::new(model));
    state.metrics.set_queue_capacity(cfg.queue_depth as u64);
    let (tx, rx) = mpsc::sync_channel::<TcpStream>(cfg.queue_depth);
    let rx = Arc::new(Mutex::new(rx));

    let mut workers = Vec::with_capacity(cfg.threads);
    for id in 0..cfg.threads {
        let rx = Arc::clone(&rx);
        let state = Arc::clone(&state);
        let read_timeout = cfg.read_timeout;
        let max_body = cfg.max_body_bytes;
        // Serve workers use the same lane convention as the training
        // executors: tid 1+id, one ring per thread, flushed on exit.
        let mut ring = cfg.trace.ring(1 + id as u32);
        let handle = thread::Builder::new()
            .name(format!("serve-worker-{id}"))
            .spawn(move || loop {
                // Hold the receiver lock only for the dequeue, never
                // while handling: the scoped block drops the guard.
                let conn = { rx.lock().unwrap_or_else(|e| e.into_inner()).recv() };
                match conn {
                    Ok(stream) => {
                        state.metrics.queue_dequeued();
                        let t0 = ring.now();
                        handle_connection(stream, &state, read_timeout, max_body);
                        ring.complete("request", "serve", t0, None);
                    }
                    // sender gone: accept loop exited, we are draining out
                    Err(_) => break,
                }
            })
            .expect("spawn serve worker");
        workers.push(handle);
    }

    let accept_state = Arc::clone(&state);
    let accept = thread::Builder::new()
        .name("serve-accept".to_string())
        .spawn(move || {
            while !accept_state.quit_requested() {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // Try the queue first so saturation is visible in
                        // /metrics; a full queue falls back to the blocking
                        // send — exactly the backpressure we want. A
                        // disconnect means every worker is gone; nothing
                        // left to do.
                        match tx.try_send(stream) {
                            Ok(()) => accept_state.metrics.queue_enqueued(),
                            Err(mpsc::TrySendError::Full(stream)) => {
                                accept_state.metrics.record_queue_saturated();
                                if tx.send(stream).is_err() {
                                    break;
                                }
                                accept_state.metrics.queue_enqueued();
                            }
                            Err(mpsc::TrySendError::Disconnected(_)) => break,
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        // Transient accept failures (EMFILE under load)
                        // must not kill the loop; back off and retry.
                        eprintln!("serve: accept error: {e}");
                        thread::sleep(ACCEPT_POLL * 10);
                    }
                }
            }
            // tx drops here; workers drain the queue and exit.
        })
        .expect("spawn serve accept loop");

    Ok(ServerHandle {
        addr,
        state,
        accept: Some(accept),
        workers,
        trace: cfg.trace,
    })
}

/// One connection, one exchange: parse under the wire limits, route,
/// reply, close. Every early return leaves the connection dropped and
/// the in-flight gauge decremented (RAII guard).
fn handle_connection(
    stream: TcpStream,
    state: &Arc<AppState>,
    read_timeout: Duration,
    max_body: usize,
) {
    let _guard = state.metrics.begin();
    let t0 = Instant::now();
    if stream.set_nonblocking(false).is_err()
        || stream.set_read_timeout(Some(read_timeout)).is_err()
        || stream.set_write_timeout(Some(read_timeout)).is_err()
    {
        return;
    }
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    let limits = Limits {
        max_head_bytes: MAX_HEAD_BYTES,
        max_body_bytes: max_body,
        // the parse budget spans several socket reads; give it headroom
        parse_budget: read_timeout.saturating_mul(4),
    };
    let response = match read_request(&mut reader, &limits) {
        // A handler panic (it should never happen — route() validates
        // everything) costs one 500 response, not a worker thread.
        Ok(req) => match catch_unwind(AssertUnwindSafe(|| route(state, &req))) {
            Ok(resp) => resp,
            Err(_) => Response::error(500, "internal error"),
        },
        Err(HttpError::Closed) => return,
        Err(e) => match e.status() {
            Some(status) => Response::error(status, &e.to_string()),
            None => return,
        },
    };
    state.metrics.record_response(response.status, t0.elapsed());
    // Client may already be gone; that is its problem, not ours.
    let _ = response.write_to(&mut writer);
    let _ = writer.flush();
}

/// Owner of a running server: its bound address, shared state, and every
/// thread. Joining is idempotent and ordered — accept thread first (its
/// exit drops the queue sender), then the workers (they drain and see
/// the disconnect).
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<AppState>,
    accept: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    trace: Recorder,
}

impl ServerHandle {
    /// The actual bound address (resolves port 0 to the kernel's pick).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared state, for tests and embedders that want to inspect
    /// metrics or request shutdown without a socket round-trip.
    pub fn state(&self) -> &Arc<AppState> {
        &self.state
    }

    /// Block until the server stops on its own (`POST /quit`).
    pub fn wait(mut self) {
        self.join_all();
    }

    /// Request shutdown and block until every thread has exited.
    pub fn shutdown(mut self) {
        self.state.request_quit();
        self.join_all();
    }

    fn join_all(&mut self) {
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers flushed their rings on exit; seal the trace file.
        // Idempotent, so wait → drop (or embedders calling finish on
        // their own clone afterwards) stays safe.
        if let Err(e) = self.trace.finish() {
            crate::log_warn!("serve: closing trace file failed: {e}");
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.state.request_quit();
        self.join_all();
    }
}
