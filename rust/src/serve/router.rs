//! Request routing and endpoint handlers for `cocoa serve`.
//!
//! The shared [`AppState`] holds the servable [`Model`] behind an
//! `RwLock<Arc<Model>>`: the predict path clones the `Arc` (two atomic
//! ops) and never blocks on admin work, while `/reload` and `/retrain`
//! build a complete replacement model off to the side and swap it in
//! atomically — in-flight requests finish on the model they started
//! with. Admin endpoints serialize through a `try_lock` (a second
//! concurrent reload/retrain gets 409, not a queue), and `/retrain` runs
//! the full [`Driver`] warm-start loop inside the handling worker thread
//! while the other workers keep serving the old weights.

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::{CocoaConfig, SolverSpec, StopReason, Trainer};
use crate::data::partition::random_balanced;
use crate::driver::{Driver, StopPolicy};
use crate::objective::Problem;
use crate::serve::http::{Request, Response};
use crate::serve::metrics::Metrics;
use crate::serve::predict::{parse_features, Model};
use crate::util::json::{jarr, jnum, jobj, jstr, Json};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, TryLockError};

/// State shared by the accept loop and every worker thread.
pub struct AppState {
    model: RwLock<Arc<Model>>,
    pub metrics: Metrics,
    quit: AtomicBool,
    /// Serializes the model-replacing endpoints (/reload, /retrain).
    admin: Mutex<()>,
}

impl AppState {
    pub fn new(model: Model) -> AppState {
        AppState {
            model: RwLock::new(Arc::new(model)),
            metrics: Metrics::new(),
            quit: AtomicBool::new(false),
            admin: Mutex::new(()),
        }
    }

    /// The current model. Cheap (Arc clone under a read lock); the caller
    /// keeps serving this model even if an admin swap lands mid-request.
    pub fn model(&self) -> Arc<Model> {
        // A poisoned lock means some handler panicked *while swapping*;
        // the stored Arc is still a complete model, so serve it rather
        // than taking the whole server down.
        match self.model.read() {
            Ok(g) => Arc::clone(&g),
            Err(poisoned) => Arc::clone(&poisoned.into_inner()),
        }
    }

    fn swap_model(&self, m: Model) {
        let new = Arc::new(m);
        match self.model.write() {
            Ok(mut g) => *g = new,
            Err(poisoned) => *poisoned.into_inner() = new,
        }
    }

    pub fn request_quit(&self) {
        self.quit.store(true, Ordering::SeqCst);
    }

    pub fn quit_requested(&self) -> bool {
        self.quit.load(Ordering::SeqCst)
    }
}

/// Dispatch one parsed request. Pure: all I/O besides handler side
/// effects (checkpoint loads, retraining) happens in the server layer.
pub fn route(state: &AppState, req: &Request) -> Response {
    const ENDPOINTS: [&str; 6] = [
        "/healthz", "/metrics", "/predict", "/reload", "/retrain", "/quit",
    ];
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => Response::json(200, state.metrics.to_json()),
        ("POST", "/predict") => predict(state, req),
        ("POST", "/reload") => reload(state, req),
        ("POST", "/retrain") => retrain(state, req),
        ("POST", "/quit") => {
            state.request_quit();
            Response::json(200, jobj(vec![("status", jstr("shutting down"))]))
        }
        (_, path) if ENDPOINTS.contains(&path) => Response::error(405, "method not allowed"),
        _ => Response::error(404, "no such endpoint"),
    }
}

fn healthz(state: &AppState) -> Response {
    let model = state.model();
    Response::json(
        200,
        jobj(vec![
            ("status", jstr("ok")),
            ("loss", jstr(model.loss.name())),
            ("d", jnum(model.d() as f64)),
            ("n_train", jnum(model.n_train as f64)),
            ("lambda", jnum(model.lambda)),
            ("model", jstr(&model.source)),
        ]),
    )
}

fn parse_json_body(req: &Request) -> Result<Json, Response> {
    let text = req
        .body_str()
        .map_err(|e| Response::error(400, &e.to_string()))?;
    if text.trim().is_empty() {
        return Err(Response::error(400, "request body must be a JSON object"));
    }
    Json::parse(text).map_err(|e| Response::error(400, &format!("body is not valid JSON: {e}")))
}

fn predict(state: &AppState, req: &Request) -> Response {
    let body = match parse_json_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let model = state.model();
    if let Some(rows) = body.get("rows") {
        // batch shape: {"rows": [[[idx, val], ...], ...]}
        let rows = match rows.as_arr() {
            Some(r) => r,
            None => return Response::error(400, "rows must be an array of feature vectors"),
        };
        let mut parsed = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            match parse_features(row) {
                Ok(p) => parsed.push(p),
                Err(e) => return Response::error(400, &format!("row {i}: {e}")),
            }
        }
        // One CSR build + one blocked matvec for the whole batch;
        // `predict_batch` errors already carry the "row {r}: " prefix.
        match model.predict_batch(&parsed) {
            Ok(batch) => {
                let preds: Vec<Json> = batch.iter().map(|p| p.to_json()).collect();
                state.metrics.record_predictions(preds.len() as u64);
                Response::json(
                    200,
                    jobj(vec![("count", jnum(preds.len() as f64)), ("predictions", jarr(preds))]),
                )
            }
            Err(e) => Response::error(400, &e),
        }
    } else if let Some(features) = body.get("features") {
        // single shape: {"features": [[idx, val], ...]}
        match parse_features(features).and_then(|p| model.predict_pairs(&p)) {
            Ok(pred) => {
                state.metrics.record_predictions(1);
                Response::json(200, pred.to_json())
            }
            Err(e) => Response::error(400, &e),
        }
    } else {
        Response::error(400, "body needs \"features\" (single) or \"rows\" (batch)")
    }
}

/// Take the admin lock without blocking; a second in-flight admin
/// operation is a client-visible 409, never a queued surprise.
fn admin_guard(state: &AppState) -> Result<std::sync::MutexGuard<'_, ()>, Response> {
    match state.admin.try_lock() {
        Ok(g) => Ok(g),
        Err(TryLockError::WouldBlock) => Err(Response::error(
            409,
            "another reload/retrain is in progress",
        )),
        // a panicked admin handler left no partial state (swap is atomic)
        Err(TryLockError::Poisoned(p)) => Ok(p.into_inner()),
    }
}

fn reload(state: &AppState, req: &Request) -> Response {
    let body = match parse_json_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let path = match body.get("checkpoint").and_then(|v| v.as_str()) {
        Some(p) => p.to_string(),
        None => return Response::error(400, "body needs {\"checkpoint\": \"<path>\"}"),
    };
    let _admin = match admin_guard(state) {
        Ok(g) => g,
        Err(resp) => return resp,
    };
    let loaded = Checkpoint::load(Path::new(&path))
        .map_err(|e| e.to_string())
        .and_then(|ck| Model::from_checkpoint(ck, &path));
    match loaded {
        Ok(model) => {
            let (d, loss) = (model.d(), model.loss.name());
            state.swap_model(model);
            state.metrics.record_reload();
            Response::json(
                200,
                jobj(vec![
                    ("status", jstr("reloaded")),
                    ("model", jstr(&path)),
                    ("loss", jstr(loss)),
                    ("d", jnum(d as f64)),
                ]),
            )
        }
        Err(e) => Response::error(400, &format!("cannot load checkpoint {path}: {e}")),
    }
}

fn usize_field(body: &Json, name: &str, default: usize) -> Result<usize, String> {
    match body.get(name) {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| format!("{name} must be a number"))?;
            if !x.is_finite() || x < 0.0 || x.fract() != 0.0 || x > (1u64 << 53) as f64 {
                return Err(format!("{name} must be a non-negative integer, got {x}"));
            }
            Ok(x as usize)
        }
    }
}

fn f64_field(body: &Json, name: &str, default: f64) -> Result<f64, String> {
    match body.get(name) {
        None => Ok(default),
        Some(v) => {
            let x = v.as_f64().ok_or_else(|| format!("{name} must be a number"))?;
            if !x.is_finite() || x < 0.0 {
                return Err(format!("{name} must be finite and ≥ 0, got {x}"));
            }
            Ok(x)
        }
    }
}

/// Validate the /retrain knobs: (rounds, gap_tol, k, seed, epochs).
fn retrain_params(body: &Json, model: &Model) -> Result<(usize, f64, usize, u64, f64), String> {
    let rounds = usize_field(body, "rounds", 50)?;
    let gap_tol = f64_field(body, "gap_tol", 1e-4)?;
    let k = usize_field(body, "k", model.k.max(1))?;
    let seed = usize_field(body, "seed", 42)?;
    let epochs = f64_field(body, "epochs", 1.0)?;
    if rounds == 0 {
        return Err("rounds must be ≥ 1".to_string());
    }
    if k == 0 || k > model.n_train {
        return Err(format!("k must be in 1..={}, got {k}", model.n_train));
    }
    if epochs <= 0.0 {
        return Err("epochs must be > 0".to_string());
    }
    Ok((rounds, gap_tol, k, seed as u64, epochs))
}

/// Warm-start re-training on drift data: load the libsvm file, adopt the
/// served model's α as the starting dual iterate (recomputing w against
/// the *new* data), continue the [`Driver`], and swap the result in.
/// Serving never stops — every other worker keeps answering /predict
/// from the old `Arc` until the final swap. The initial α may be
/// dual-infeasible on drifted labels, so the stop policy allows an
/// infinite starting gap (the first local solves clamp α back into the
/// feasible box).
fn retrain(state: &AppState, req: &Request) -> Response {
    let body = match parse_json_body(req) {
        Ok(j) => j,
        Err(resp) => return resp,
    };
    let data_path = match body.get("data").and_then(|v| v.as_str()) {
        Some(p) => p.to_string(),
        None => {
            return Response::error(
                400,
                "body needs {\"data\": \"<path.svm>\"} (plus optional rounds/gap_tol/k/seed/epochs)",
            )
        }
    };
    let model = state.model();
    let (rounds, gap_tol, k, seed, epochs) = match retrain_params(&body, &model) {
        Ok(v) => v,
        Err(e) => return Response::error(400, &e),
    };
    let _admin = match admin_guard(state) {
        Ok(g) => g,
        Err(resp) => return resp,
    };
    let data = match crate::data::libsvm::load(Path::new(&data_path), Some(model.d())) {
        Ok(d) => d,
        Err(e) => return Response::error(400, &format!("cannot load {data_path}: {e}")),
    };
    if data.n() != model.n_train {
        return Response::error(
            400,
            &format!(
                "drift data has n = {}, model α has n = {} (warm start needs one α per row)",
                data.n(),
                model.n_train
            ),
        );
    }
    let n = data.n();
    let problem = Problem::new(data, model.loss, model.lambda);
    let partition = random_balanced(n, k, seed);
    let cfg = CocoaConfig::cocoa_plus(
        k,
        model.loss,
        model.lambda,
        SolverSpec::SdcaEpochs { epochs },
    )
    .with_rounds(rounds)
    .with_gap_tol(gap_tol)
    .with_seed(seed);
    let mut trainer = Trainer::new(problem, partition, cfg);
    if let Err(e) = trainer.warm_start_from_alpha(&model.alpha) {
        return Response::error(500, &format!("warm start failed: {e}"));
    }
    let stop = StopPolicy::new(rounds)
        .with_gap_tol(gap_tol)
        .with_divergence_gap(f64::INFINITY);
    let history = Driver::new(stop).run(&mut trainer);
    if history.stop == StopReason::Diverged {
        return Response::error(
            500,
            &format!("retraining diverged (gap {})", history.final_gap()),
        );
    }
    let train_error = trainer.problem.data.classification_error(&trainer.w);
    let retrained = Checkpoint::capture(&trainer);
    let source = format!("retrain:{data_path}");
    let new_model = match Model::from_checkpoint(retrained, &source) {
        Ok(m) => m,
        Err(e) => return Response::error(500, &format!("retrained model invalid: {e}")),
    };
    state.swap_model(new_model);
    state.metrics.record_retrain();
    Response::json(
        200,
        jobj(vec![
            ("status", jstr("retrained")),
            ("model", jstr(&source)),
            ("rounds_run", jnum(history.rounds_run() as f64)),
            ("stop", jstr(history.stop.as_str())),
            ("final_gap", jnum(history.final_gap())),
            ("train_error", jnum(train_error)),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Loss;

    fn state() -> AppState {
        AppState::new(Model {
            loss: Loss::Hinge,
            lambda: 1e-2,
            n_train: 4,
            k: 2,
            w: vec![1.0, -2.0, 0.5],
            alpha: vec![0.0; 4],
            source: "test-ck.json".into(),
        })
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            headers: vec![],
            body: body.as_bytes().to_vec(),
        }
    }

    fn body_json(resp: &Response) -> Json {
        Json::parse(&resp.body).unwrap()
    }

    #[test]
    fn routes_by_method_and_path() {
        let s = state();
        assert_eq!(route(&s, &req("GET", "/healthz", "")).status, 200);
        assert_eq!(route(&s, &req("GET", "/metrics", "")).status, 200);
        assert_eq!(route(&s, &req("GET", "/predict", "")).status, 405);
        assert_eq!(route(&s, &req("POST", "/healthz", "")).status, 405);
        assert_eq!(route(&s, &req("GET", "/nope", "")).status, 404);
        assert!(!s.quit_requested());
        assert_eq!(route(&s, &req("POST", "/quit", "")).status, 200);
        assert!(s.quit_requested());
    }

    #[test]
    fn healthz_reports_model_shape() {
        let s = state();
        let j = body_json(&route(&s, &req("GET", "/healthz", "")));
        assert_eq!(j.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(j.get("loss").unwrap().as_str(), Some("hinge"));
        assert_eq!(j.get("d").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("model").unwrap().as_str(), Some("test-ck.json"));
    }

    #[test]
    fn predict_single_and_batch() {
        let s = state();
        let resp = route(&s, &req("POST", "/predict", "{\"features\": [[0, 2.0], [2, 2.0]]}"));
        assert_eq!(resp.status, 200, "{}", resp.body);
        let j = body_json(&resp);
        assert_eq!(j.get("score").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("label").unwrap().as_f64(), Some(1.0));

        let resp = route(&s, &req("POST", "/predict", "{\"rows\": [[[0, 1.0]], [[1, 1.0]], []]}"));
        assert_eq!(resp.status, 200, "{}", resp.body);
        let j = body_json(&resp);
        assert_eq!(j.get("count").unwrap().as_f64(), Some(3.0));
        let preds = j.get("predictions").unwrap().as_arr().unwrap();
        assert_eq!(preds[0].get("label").unwrap().as_f64(), Some(1.0));
        assert_eq!(preds[1].get("label").unwrap().as_f64(), Some(-1.0));
        // the all-zeros row classifies negative under the shared tie rule
        assert_eq!(preds[2].get("label").unwrap().as_f64(), Some(-1.0));
        assert_eq!(s.metrics.to_json().get("predictions_total").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn predict_rejects_bad_bodies_with_400() {
        let s = state();
        for body in [
            "",
            "not json",
            "{\"wrong\": 1}",
            "{\"features\": 7}",
            "{\"features\": [[9, 1.0]]}", // out of range (d = 3)
            "{\"rows\": 5}",
            "{\"rows\": [[[0, 1]], [[99, 1]]]}",
        ] {
            let resp = route(&s, &req("POST", "/predict", body));
            assert_eq!(resp.status, 400, "body {body:?} → {}", resp.body);
        }
    }

    #[test]
    fn reload_missing_file_is_client_error() {
        let s = state();
        let resp = route(&s, &req("POST", "/reload", "{\"checkpoint\": \"/no/such\"}"));
        assert_eq!(resp.status, 400, "{}", resp.body);
        let resp = route(&s, &req("POST", "/reload", "{}"));
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn retrain_validates_request_before_training() {
        let s = state();
        let resp = route(&s, &req("POST", "/retrain", "{}"));
        assert_eq!(resp.status, 400);
        let resp = route(&s, &req("POST", "/retrain", "{\"data\": \"x.svm\", \"rounds\": 1.5}"));
        assert_eq!(resp.status, 400, "{}", resp.body);
        let resp = route(&s, &req("POST", "/retrain", "{\"data\": \"x.svm\", \"k\": 99}"));
        assert_eq!(resp.status, 400, "{}", resp.body);
        let resp = route(&s, &req("POST", "/retrain", "{\"data\": \"/no/such.svm\"}"));
        assert_eq!(resp.status, 400, "{}", resp.body);
    }
}
