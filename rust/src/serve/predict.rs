//! Checkpoint → servable model: validate a [`Checkpoint`] into a
//! [`Model`] and score sparse client feature vectors with *exactly* the
//! training-time computation — the same CSR construction
//! ([`CsrMatrix::row_from_pairs`]: sort, merge duplicates, drop zeros)
//! and the same SIMD-dispatched [`CsrMatrix::row_dot`] kernel (fixed
//! lane-reduction order, see [`crate::linalg::simd`]) — so a served
//! score is bit-identical to what the trainer's own evaluation would
//! produce for that row. Batches ride the blocked
//! [`CsrMatrix::rows_dot`] matvec, which is bit-identical per row to the
//! single-row path. The link on top is [`Loss::predict`]: hard ±1 for
//! the hinge family, σ(z) for logistic, identity for regression.

use crate::coordinator::checkpoint::Checkpoint;
use crate::linalg::CsrMatrix;
use crate::loss::{classify, Loss};
use crate::util::json::{jnum, jobj, Json};

/// An immutable, fully validated model. The server hands these out
/// behind an `Arc` swap, so /reload and /retrain replace the whole model
/// atomically while in-flight requests finish on the one they started
/// with. `alpha` rides along (in caller row order) because it is the
/// complete optimizer state — /retrain warm-starts the Driver from it.
#[derive(Debug)]
pub struct Model {
    pub loss: Loss,
    pub lambda: f64,
    /// Rows the checkpointed α was trained on (drift data must match).
    pub n_train: usize,
    /// Worker count the checkpoint was trained with (retrain default).
    pub k: usize,
    pub w: Vec<f64>,
    pub alpha: Vec<f64>,
    /// Where this model came from (checkpoint path or "retrain:<data>").
    pub source: String,
}

impl Model {
    /// Validate a checkpoint into a servable model. Everything a hostile
    /// or truncated checkpoint could get wrong is rejected here, once,
    /// so the predict hot path never re-checks.
    pub fn from_checkpoint(ck: Checkpoint, source: &str) -> Result<Model, String> {
        let loss = Loss::parse(&ck.loss)
            .ok_or_else(|| format!("checkpoint has unknown loss {:?}", ck.loss))?;
        if ck.w.len() != ck.d {
            return Err(format!(
                "checkpoint w has {} entries, header says d = {}",
                ck.w.len(),
                ck.d
            ));
        }
        if ck.alpha.len() != ck.n {
            return Err(format!(
                "checkpoint α has {} entries, header says n = {}",
                ck.alpha.len(),
                ck.n
            ));
        }
        if ck.d == 0 {
            return Err("checkpoint has d = 0 (nothing to score)".into());
        }
        if !ck.lambda.is_finite() || ck.lambda <= 0.0 {
            return Err(format!("checkpoint λ must be positive, got {}", ck.lambda));
        }
        if ck.alpha.iter().chain(ck.w.iter()).any(|v| !v.is_finite()) {
            return Err("checkpoint contains non-finite values".into());
        }
        Ok(Model {
            loss,
            lambda: ck.lambda,
            n_train: ck.n,
            k: ck.k,
            w: ck.w,
            alpha: ck.alpha,
            source: source.to_string(),
        })
    }

    /// Feature dimension d (the length a dense input would have).
    pub fn d(&self) -> usize {
        self.w.len()
    }

    /// Score one sparse feature vector given as *untrusted* (index,
    /// value) pairs — unsorted and duplicated columns are fine, an
    /// out-of-range index or non-finite value is a client error.
    pub fn predict_pairs(&self, pairs: &[(usize, f64)]) -> Result<Prediction, String> {
        let row = CsrMatrix::row_from_pairs(self.d(), pairs)?;
        Ok(self.prediction_from_score(row.row_dot(0, &self.w)))
    }

    /// Score a whole batch through one CSR build
    /// ([`CsrMatrix::rows_from_pairs`]) and one blocked matvec
    /// ([`CsrMatrix::rows_dot`]) instead of a per-row construct-and-dot
    /// loop. Scores are bit-identical to mapping
    /// [`Model::predict_pairs`] over the rows; errors name the offending
    /// row (`"row {r}: …"`) so the router can pass them straight to the
    /// client as a 4xx.
    pub fn predict_batch(&self, rows: &[Vec<(usize, f64)>]) -> Result<Vec<Prediction>, String> {
        let batch = CsrMatrix::rows_from_pairs(self.d(), rows)?;
        let mut scores = vec![0.0; batch.rows];
        batch.matvec(&self.w, &mut scores);
        Ok(scores
            .iter()
            .map(|&z| self.prediction_from_score(z))
            .collect())
    }

    /// The served quantities for a raw score z = wᵀx.
    pub fn prediction_from_score(&self, score: f64) -> Prediction {
        Prediction {
            score,
            value: self.loss.predict(score),
            label: if self.loss.is_classification() {
                Some(classify(score))
            } else {
                None
            },
        }
    }
}

/// One prediction: the raw score wᵀx, the loss's link output
/// ([`Loss::predict`]), and — for classification losses — the hard ±1
/// decision from the shared [`classify`] rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Prediction {
    pub score: f64,
    pub value: f64,
    pub label: Option<f64>,
}

impl Prediction {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("score", jnum(self.score)), ("prediction", jnum(self.value))];
        if let Some(label) = self.label {
            fields.push(("label", jnum(label)));
        }
        jobj(fields)
    }
}

/// Parse one feature vector from its JSON form: an array of
/// `[index, value]` pairs (the sparse libsvm-like shape). Indices get the
/// checkpoint-grade dimension discipline — finite, non-negative,
/// integral, ≤ 2⁵³ — before the cast; values are validated downstream by
/// `row_from_pairs`.
pub fn parse_features(j: &Json) -> Result<Vec<(usize, f64)>, String> {
    let arr = j
        .as_arr()
        .ok_or("features must be an array of [index, value] pairs")?;
    let mut pairs = Vec::with_capacity(arr.len());
    for (i, entry) in arr.iter().enumerate() {
        // The slice pattern both checks the pair shape and binds its
        // halves — no `pair[0]`/`pair[1]` indexing on client input.
        let (idx_j, val_j) = match entry.as_arr() {
            Some([idx_j, val_j]) => (idx_j, val_j),
            _ => return Err(format!("feature {i} is not an [index, value] pair")),
        };
        let idx = idx_j
            .as_f64()
            .ok_or_else(|| format!("feature {i} index is not a number"))?;
        if !idx.is_finite() || idx < 0.0 || idx.fract() != 0.0 || idx > (1u64 << 53) as f64 {
            return Err(format!("feature {i} index {idx} is not a valid column"));
        }
        let val = val_j
            .as_f64()
            .ok_or_else(|| format!("feature {i} value is not a number"))?;
        pairs.push((idx as usize, val));
    }
    Ok(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(loss: Loss) -> Model {
        Model {
            loss,
            lambda: 1e-2,
            n_train: 0,
            k: 1,
            w: vec![0.5, -1.0, 0.0, 2.0],
            alpha: vec![],
            source: "test".into(),
        }
    }

    fn ck(loss: &str) -> Checkpoint {
        Checkpoint {
            n: 2,
            d: 3,
            k: 1,
            lambda: 1e-2,
            loss: loss.into(),
            alpha: vec![0.1, -0.2],
            w: vec![1.0, 0.0, -1.0],
        }
    }

    #[test]
    fn from_checkpoint_validates_everything_once() {
        assert!(Model::from_checkpoint(ck("hinge"), "p").is_ok());
        let mut bad = ck("frobnicate");
        assert!(Model::from_checkpoint(bad, "p").is_err());
        bad = ck("hinge");
        bad.w.pop();
        assert!(Model::from_checkpoint(bad, "p").is_err());
        bad = ck("hinge");
        bad.alpha.push(0.0);
        assert!(Model::from_checkpoint(bad, "p").is_err());
        bad = ck("hinge");
        bad.lambda = -1.0;
        assert!(Model::from_checkpoint(bad, "p").is_err());
        bad = ck("hinge");
        bad.w[0] = f64::NAN;
        assert!(Model::from_checkpoint(bad, "p").is_err());
    }

    #[test]
    fn predict_pairs_scores_unsorted_input_like_training() {
        let m = model(Loss::Hinge);
        // unsorted + duplicate column: (3, 1.0+0.5), (0, 2.0) → z = 2·0.5 + 1.5·2.0 = 4.0
        let p = m.predict_pairs(&[(3, 1.0), (0, 2.0), (3, 0.5)]).unwrap();
        assert_eq!(p.score, 4.0);
        assert_eq!(p.value, 1.0);
        assert_eq!(p.label, Some(1.0));
        // out-of-range column is a client error, not a panic
        assert!(m.predict_pairs(&[(4, 1.0)]).is_err());
    }

    #[test]
    fn predict_batch_matches_single_predictions_bitwise() {
        let m = model(Loss::Logistic);
        let rows: Vec<Vec<(usize, f64)>> = vec![
            vec![(3, 1.0), (0, 2.0), (3, 0.5)], // unsorted + duplicate
            vec![],                             // all-zeros row
            vec![(1, -0.25), (2, 7.0)],
            vec![(0, 1e-310), (3, -0.0)], // subnormal + signed zero
        ];
        let batch = m.predict_batch(&rows).unwrap();
        assert_eq!(batch.len(), rows.len());
        for (r, row) in rows.iter().enumerate() {
            let single = m.predict_pairs(row).unwrap();
            assert_eq!(
                batch[r].score.to_bits(),
                single.score.to_bits(),
                "row {r}"
            );
            assert_eq!(batch[r].value, single.value);
            assert_eq!(batch[r].label, single.label);
        }
        // batch errors name the offending row
        let err = m.predict_batch(&[vec![], vec![(9, 1.0)]]).unwrap_err();
        assert!(err.contains("row 1"), "{err}");
    }

    #[test]
    fn links_follow_the_loss() {
        let z = -0.75;
        let hinge = model(Loss::Hinge).prediction_from_score(z);
        assert_eq!(hinge.value, -1.0);
        assert_eq!(hinge.label, Some(-1.0));
        let logistic = model(Loss::Logistic).prediction_from_score(z);
        assert_eq!(logistic.value, Loss::Logistic.predict(z));
        assert!(logistic.value < 0.5);
        assert_eq!(logistic.label, Some(-1.0));
        let squared = model(Loss::Squared).prediction_from_score(z);
        assert_eq!(squared.value, z);
        assert_eq!(squared.label, None, "regression serves no label");
    }

    #[test]
    fn prediction_json_shape() {
        let j = model(Loss::Logistic).prediction_from_score(0.0).to_json();
        assert_eq!(j.get("score").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("prediction").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("label").unwrap().as_f64(), Some(-1.0));
        let j = model(Loss::Absolute).prediction_from_score(1.5).to_json();
        assert!(j.get("label").is_none());
    }

    #[test]
    fn parse_features_rejects_hostile_shapes() {
        let ok = Json::parse("[[0, 1.5], [3, -2]]").unwrap();
        assert_eq!(parse_features(&ok).unwrap(), vec![(0, 1.5), (3, -2.0)]);
        for bad in [
            "{\"0\": 1}",          // not an array
            "[[0]]",               // not a pair
            "[[0, 1, 2]]",         // triple
            "[[\"a\", 1]]",        // index not a number
            "[[0.5, 1]]",          // fractional index
            "[[-1, 1]]",           // negative index
            "[[1e300, 1]]",        // absurd index
            "[[0, null]]",         // value not a number
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(parse_features(&j).is_err(), "accepted {bad}");
        }
        // empty feature list is a legal all-zeros row
        assert_eq!(
            parse_features(&Json::parse("[]").unwrap()).unwrap(),
            Vec::<(usize, f64)>::new()
        );
    }

    #[test]
    fn pair_shape_errors_name_the_offending_feature() {
        // Regression for the slice-pattern rewrite: a malformed pair in
        // the middle of a valid list is rejected by position, not by a
        // `pair[0]` panic.
        let j = Json::parse("[[0, 1], [2]]").unwrap();
        let err = parse_features(&j).unwrap_err();
        assert!(err.contains("feature 1"), "{err}");
    }
}
