//! `cocoa serve` — a dependency-free checkpoint-to-inference HTTP
//! subsystem.
//!
//! A [`Checkpoint`](crate::coordinator::checkpoint::Checkpoint) written
//! by `cocoa train --checkpoint-out` holds the full primal-dual state
//! (w, α); this module turns one into a live prediction service over
//! plain `std::net` — no HTTP crate, no async runtime, mirroring the
//! repo-wide zero-dependency rule. The served score is **bit-identical**
//! to training-time evaluation: client feature pairs go through the same
//! CSR construction and the same two-lane dot kernel the trainer uses.
//!
//! Endpoints (all bodies JSON, responses `Connection: close`):
//!
//! | method | path       | purpose                                        |
//! |--------|------------|------------------------------------------------|
//! | GET    | `/healthz` | liveness + model shape (loss, d, n, λ, source) |
//! | GET    | `/metrics` | counters, latency histogram, in-flight gauge   |
//! | POST   | `/predict` | score `{"features": [[i, v], ...]}` or batch `{"rows": [...]}` |
//! | POST   | `/reload`  | hot-swap to `{"checkpoint": "<path>"}`         |
//! | POST   | `/retrain` | warm-start the Driver on `{"data": "<path.svm>"}` drift data |
//! | POST   | `/quit`    | graceful shutdown (drain, join, exit)          |
//!
//! Wire discipline follows `worker/wire.rs`: hard size caps on head and
//! body (431/413), a wall-clock parse budget and socket read timeouts
//! (408), and typed 4xx for malformed requests — hostile input can cost
//! one response, never a worker thread and never a hang. `/reload` and
//! `/retrain` build the replacement model aside and swap an `Arc`, so
//! in-flight requests finish on the model they started with; `/retrain`
//! warm-starts from the served α
//! ([`Trainer::warm_start_from_alpha`](crate::coordinator::Trainer::warm_start_from_alpha))
//! while the other workers keep serving.
//!
//! Pure std cannot install signal handlers, so SIGTERM is the blunt
//! path; orchestration wanting a drained shutdown POSTs `/quit`.

pub mod http;
pub mod metrics;
pub mod predict;
pub mod router;
pub mod server;

pub use http::{HttpError, Request, Response};
pub use predict::{Model, Prediction};
pub use server::{serve, ServeConfig, ServerHandle};
