//! Linear-algebra substrate: dense vector kernels, CSR sparse matrices
//! with zero-copy row-range shard views, and power iteration for the
//! paper's partition constants σ_k.

pub mod dense;
pub mod power_iter;
pub mod simd;
pub mod sparse;

pub use power_iter::{sigma_k, spectral_norm_sq};
pub use sparse::{CsrMatrix, CsrShard};
