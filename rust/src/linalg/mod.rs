//! Linear-algebra substrate: dense vector kernels, CSR sparse matrices,
//! and power iteration for the paper's partition constants σ_k.

pub mod dense;
pub mod power_iter;
pub mod sparse;

pub use power_iter::{sigma_k, spectral_norm_sq};
pub use sparse::CsrMatrix;
