//! Explicit-SIMD f64 kernels with a **fixed lane-reduction order**.
//!
//! Every executor (sequential, pooled, socket) and the serving path score
//! through these four primitives, so they carry the repo's determinism
//! contract: for a given input, the returned bits are identical no matter
//! which implementation ran. That holds because the AVX2 paths and the
//! portable 4-lane-unrolled scalar fallback share one accumulator layout:
//!
//! * lane `j ∈ {0,1,2,3}` accumulates elements `i ≡ j (mod 4)` over the
//!   full 4-chunks, as `lane_j += a[i] * b[i]` (separate mul then add —
//!   **never** a fused multiply-add, which rounds differently);
//! * leftover elements accumulate left-to-right into a single `tail`;
//! * the reduction is always `((((s0 + s1) + s2) + s3) + tail)`.
//!
//! AVX2 maps lane `j` onto lane `j` of one `__m256d` accumulator and
//! reduces by extracting the four lanes in index order, so each partial
//! sum sees exactly the same sequence of f64 additions as the scalar
//! code. `axpy`/`scatter_axpy` touch every output element with a single
//! `y[i] + c·x[i]`, so their bit-identity needs no ordering argument at
//! all (again: no FMA).
//!
//! Dispatch is resolved once per process from runtime CPU detection;
//! setting the `COCOA_NO_SIMD` environment variable (any value) forces
//! the scalar fallback — the escape hatch for debugging a suspected
//! kernel issue. [`force_scalar`] is the in-process equivalent used by
//! the determinism suite to exercise both paths in one binary. Because
//! both paths are bit-identical, flipping the mode mid-run is benign.

use std::sync::atomic::{AtomicU8, Ordering};

const MODE_UNRESOLVED: u8 = 0;
const MODE_SCALAR: u8 = 1;
const MODE_AVX2: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNRESOLVED);

fn detect() -> u8 {
    if std::env::var_os("COCOA_NO_SIMD").is_some() {
        return MODE_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return MODE_AVX2;
        }
    }
    MODE_SCALAR
}

/// The resolved kernel mode (cached after the first call).
#[inline]
fn mode() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m != MODE_UNRESOLVED {
        return m;
    }
    let detected = detect();
    MODE.store(detected, Ordering::Relaxed);
    detected
}

/// Force the portable scalar path (`true`) or return to runtime
/// detection (`false`). Exists so the determinism and property suites
/// can drive both implementations from one process; safe to flip at any
/// time because the two paths are bit-identical by construction.
pub fn force_scalar(on: bool) {
    let m = if on { MODE_SCALAR } else { MODE_UNRESOLVED };
    MODE.store(m, Ordering::Relaxed);
}

/// True when the AVX2 paths are selected (detection already resolved).
pub fn avx2_active() -> bool {
    mode() == MODE_AVX2
}

// ---------------------------------------------------------------------
// Dense dot: aᵀb
// ---------------------------------------------------------------------

/// Portable reference: 4 independent scalar lanes + left-to-right tail,
/// reduced in the fixed order. This is both the non-x86 fallback and the
/// bit-for-bit oracle the AVX2 path is property-tested against.
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// AVX2 dense dot with the shared lane layout.
///
/// # Safety
/// Callers must ensure the CPU supports AVX2 (`is_x86_feature_detected!`)
/// and that `a.len() == b.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe fn` — the AVX2 intrinsics below require the caller to
// have verified CPU support; all pointer arithmetic stays within the
// equal-length input slices (loop bound `chunks * 4 <= n`).
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    // SAFETY: loads read a[i..i+4] and b[i..i+4] with i + 4 <= chunks*4
    // <= n; unaligned loads are explicitly allowed by _mm256_loadu_pd.
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let i = c * 4;
        let va = _mm256_loadu_pd(a.as_ptr().add(i));
        let vb = _mm256_loadu_pd(b.as_ptr().add(i));
        // mul then add (NOT fmadd): each lane j performs the same
        // `s_j += a[i+j] * b[i+j]` rounding steps as the scalar lanes.
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += a[i] * b[i];
    }
    ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3] + tail
}

/// Dense dot product, dispatching to AVX2 when available. Bit-identical
/// to [`dot_scalar`] on every input.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true.
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot_scalar(a, b)
}

// ---------------------------------------------------------------------
// Sparse gather dot: Σ vals[t] · v[idx[t]]
// ---------------------------------------------------------------------

/// Portable reference for the CSR row dot: same 4-lane layout as
/// [`dot_scalar`], with the gather `v[idx[t]]` unchecked.
///
/// # Safety
/// Every `idx[t]` must be `< v.len()` (the CSR constructors validate
/// columns against `cols`, and callers pass `v.len() == cols`).
#[inline]
// SAFETY: `unsafe fn` — the gathers below index `v` by caller-validated
// CSR column indices; see the Safety section above.
pub unsafe fn gather_dot_scalar(idx: &[u32], vals: &[f64], v: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        // SAFETY: i + 3 < chunks * 4 <= n bounds the CSR arrays, and all
        // indices are < v.len() per the function contract.
        unsafe {
            s0 += vals[i] * *v.get_unchecked(idx[i] as usize);
            s1 += vals[i + 1] * *v.get_unchecked(idx[i + 1] as usize);
            s2 += vals[i + 2] * *v.get_unchecked(idx[i + 2] as usize);
            s3 += vals[i + 3] * *v.get_unchecked(idx[i + 3] as usize);
        }
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        // SAFETY: i < n bounds the CSR arrays; idx[i] < v.len() per the
        // function contract.
        unsafe {
            tail += vals[i] * *v.get_unchecked(idx[i] as usize);
        }
    }
    s0 + s1 + s2 + s3 + tail
}

/// AVX2 gather dot with the shared lane layout, using `vgatherdpd` for
/// the indexed loads.
///
/// # Safety
/// CPU must support AVX2; every `idx[t]` must be `< v.len()`, and
/// `v.len()` must fit in `i32` (the gather interprets indices as signed
/// 32-bit — the dispatcher falls back to scalar above that).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe fn` — gathers read v[idx[t]] for caller-validated
// indices; lane layout mirrors gather_dot_scalar exactly.
unsafe fn gather_dot_avx2(idx: &[u32], vals: &[f64], v: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    // SAFETY: each iteration reads idx[i..i+4] / vals[i..i+4] in bounds,
    // and the gather dereferences v + idx[i+j] with idx[i+j] < v.len()
    // (caller contract) interpreted as a non-negative i32 (caller
    // guarantees v.len() <= i32::MAX).
    for c in 0..chunks {
        let i = c * 4;
        let vi = _mm_loadu_si128(idx.as_ptr().add(i) as *const __m128i);
        let gathered = _mm256_i32gather_pd::<8>(v.as_ptr(), vi);
        let vv = _mm256_loadu_pd(vals.as_ptr().add(i));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(vv, gathered));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let mut tail = 0.0;
    for i in chunks * 4..n {
        // SAFETY: i < n; idx[i] < v.len() per the caller contract.
        tail += vals[i] * *v.get_unchecked(idx[i] as usize);
    }
    ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3] + tail
}

/// Sparse gather dot `Σ vals[t]·v[idx[t]]`, dispatching to the AVX2
/// `vgatherdpd` path when available. Bit-identical to
/// [`gather_dot_scalar`] on every input.
///
/// # Safety
/// Every `idx[t]` must be `< v.len()`.
#[inline]
// SAFETY: `unsafe fn` — forwards the caller's index-validity contract to
// the selected implementation.
pub unsafe fn gather_dot(idx: &[u32], vals: &[f64], v: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        // The i32 gather sign-extends indices, so columns past i32::MAX
        // must take the scalar path (no real dataset gets there, but the
        // kernel must not be the thing that breaks first).
        if mode() == MODE_AVX2 && v.len() <= i32::MAX as usize {
            // SAFETY: AVX2 verified by detection; index bound and i32
            // range checked above; remaining contract forwarded.
            return unsafe { gather_dot_avx2(idx, vals, v) };
        }
    }
    // SAFETY: identical caller contract.
    unsafe { gather_dot_scalar(idx, vals, v) }
}

// ---------------------------------------------------------------------
// Dense axpy: y += c·x
// ---------------------------------------------------------------------

/// Portable `y[i] += c * x[i]`. Each output element is touched by exactly
/// one multiply-then-add, so ordering cannot affect bits.
#[inline]
pub fn axpy_scalar(c: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += c * *xi;
    }
}

/// AVX2 `y += c·x` (mul then add per element — no FMA).
///
/// # Safety
/// CPU must support AVX2; `x.len() == y.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `unsafe fn` — vector loads/stores stay within the equal-length
// slices; per-element arithmetic matches axpy_scalar.
unsafe fn axpy_avx2(c: f64, x: &[f64], y: &mut [f64]) {
    use std::arch::x86_64::*;
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let vc = _mm256_set1_pd(c);
    // SAFETY: loads/stores touch x[i..i+4] / y[i..i+4], i + 4 <= n.
    for ch in 0..chunks {
        let i = ch * 4;
        let vx = _mm256_loadu_pd(x.as_ptr().add(i));
        let vy = _mm256_loadu_pd(y.as_ptr().add(i));
        _mm256_storeu_pd(
            y.as_mut_ptr().add(i),
            _mm256_add_pd(vy, _mm256_mul_pd(vc, vx)),
        );
    }
    for i in chunks * 4..n {
        y[i] += c * x[i];
    }
}

/// `y += c·x`, dispatching to AVX2 when available. Bit-identical to
/// [`axpy_scalar`].
#[inline]
pub fn axpy(c: f64, x: &[f64], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 implies detection succeeded; lengths are
            // asserted inside.
            unsafe { axpy_avx2(c, x, y) };
            return;
        }
    }
    axpy_scalar(c, x, y)
}

// ---------------------------------------------------------------------
// Sparse scatter axpy: v[idx[t]] += c·vals[t]
// ---------------------------------------------------------------------

/// 4-way unrolled scatter `v[idx[t]] += c·vals[t]`. AVX2 has no scatter
/// store, so the unrolled scalar form (independent address chains for
/// the prefetcher) is the fast portable answer; CSR rows never repeat a
/// column, so each output element is touched once and bit-identity is
/// order-free.
///
/// # Safety
/// Every `idx[t]` must be `< v.len()`.
#[inline]
// SAFETY: `unsafe fn` — the scatter stores index `v` by caller-validated
// CSR column indices.
pub unsafe fn scatter_axpy(c: f64, idx: &[u32], vals: &[f64], v: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let chunks = n / 4;
    for ch in 0..chunks {
        let i = ch * 4;
        // SAFETY: i + 3 < n bounds the CSR arrays; all indices < v.len()
        // per the function contract. CSR rows hold strictly increasing
        // columns, so the four targets are distinct elements.
        unsafe {
            *v.get_unchecked_mut(idx[i] as usize) += c * vals[i];
            *v.get_unchecked_mut(idx[i + 1] as usize) += c * vals[i + 1];
            *v.get_unchecked_mut(idx[i + 2] as usize) += c * vals[i + 2];
            *v.get_unchecked_mut(idx[i + 3] as usize) += c * vals[i + 3];
        }
    }
    for i in chunks * 4..n {
        // SAFETY: i < n; idx[i] < v.len() per the function contract.
        unsafe {
            *v.get_unchecked_mut(idx[i] as usize) += c * vals[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adversarial dense vector patterns: empty, single element, exact
    /// multiples of the lane width, lane width ± 1, signed zeros,
    /// subnormals, and magnitude spreads that make reassociation visible.
    fn dense_cases() -> Vec<(Vec<f64>, Vec<f64>)> {
        let mut cases: Vec<(Vec<f64>, Vec<f64>)> = vec![
            (vec![], vec![]),
            (vec![2.5], vec![-0.5]),
            (vec![-0.0, 0.0, -0.0], vec![1.0, -1.0, 0.0]),
        ];
        for n in [3usize, 4, 5, 7, 8, 15, 16, 17, 64, 257] {
            let a: Vec<f64> = (0..n)
                .map(|i| {
                    let base = ((i * 37 + 11) % 101) as f64 - 50.0;
                    // mix in subnormals, signed zeros, and huge spreads
                    match i % 7 {
                        0 => base * 1e-310,            // subnormal territory
                        1 => -0.0,
                        2 => base * 1e12,
                        _ => base * 0.25,
                    }
                })
                .collect();
            let b: Vec<f64> = (0..n)
                .map(|i| (((i * 53 + 5) % 97) as f64 - 48.0) * 0.5)
                .collect();
            cases.push((a, b));
        }
        cases
    }

    #[test]
    fn dot_dispatch_matches_scalar_bitwise() {
        for (a, b) in dense_cases() {
            let want = dot_scalar(&a, &b).to_bits();
            assert_eq!(dot(&a, &b).to_bits(), want, "n = {}", a.len());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn dot_avx2_matches_scalar_bitwise() {
        if !is_x86_feature_detected!("avx2") {
            return; // nothing to compare on this host (e.g. under Miri)
        }
        for (a, b) in dense_cases() {
            let want = dot_scalar(&a, &b).to_bits();
            // SAFETY: AVX2 support checked above; equal lengths by
            // construction of the cases.
            let got = unsafe { dot_avx2(&a, &b) }.to_bits();
            assert_eq!(got, want, "n = {}", a.len());
        }
    }

    /// Adversarial sparse patterns over a d-length target: empty row,
    /// single nnz, fully dense row, strided gathers, repeated magnitude
    /// extremes.
    fn sparse_cases(d: usize) -> Vec<(Vec<u32>, Vec<f64>)> {
        let dense: Vec<u32> = (0..d as u32).collect();
        let dense_vals: Vec<f64> = (0..d).map(|i| ((i % 13) as f64 - 6.0) * 0.5).collect();
        let mut cases = vec![
            (vec![], vec![]),
            (vec![(d - 1) as u32], vec![1e-308]),
            (vec![0, 1, 2], vec![-0.0, 0.0, 5.0]),
            (dense, dense_vals),
        ];
        for nnz in [4usize, 5, 9, 31, 32, 33] {
            let idx: Vec<u32> = (0..nnz).map(|i| ((i * 17 + 3) % d) as u32).collect();
            let mut idx = idx;
            idx.sort_unstable();
            idx.dedup();
            let vals: Vec<f64> = idx
                .iter()
                .map(|&c| match c % 5 {
                    0 => 1e-312,
                    1 => -3.75e10,
                    _ => (c as f64 - 8.0) * 0.125,
                })
                .collect();
            cases.push((idx, vals));
        }
        cases
    }

    #[test]
    fn gather_dot_dispatch_matches_scalar_bitwise() {
        let d = 64;
        let v: Vec<f64> = (0..d).map(|i| ((i * 29 + 7) % 31) as f64 - 15.0).collect();
        for (idx, vals) in sparse_cases(d) {
            // SAFETY: all test indices are built < d = v.len().
            let (got, want) = unsafe {
                (
                    gather_dot(&idx, &vals, &v).to_bits(),
                    gather_dot_scalar(&idx, &vals, &v).to_bits(),
                )
            };
            assert_eq!(got, want, "nnz = {}", idx.len());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn gather_dot_avx2_matches_scalar_bitwise() {
        if !is_x86_feature_detected!("avx2") {
            return;
        }
        let d = 96;
        let v: Vec<f64> = (0..d).map(|i| ((i * 41 + 13) % 37) as f64 * 0.25).collect();
        for (idx, vals) in sparse_cases(d) {
            // SAFETY: AVX2 checked above; indices < d = v.len(); d fits
            // in i32 trivially.
            let (got, want) = unsafe {
                (
                    gather_dot_avx2(&idx, &vals, &v).to_bits(),
                    gather_dot_scalar(&idx, &vals, &v).to_bits(),
                )
            };
            assert_eq!(got, want, "nnz = {}", idx.len());
        }
    }

    #[test]
    fn axpy_dispatch_matches_scalar_bitwise() {
        for (x, _) in dense_cases() {
            let y0: Vec<f64> = (0..x.len()).map(|i| (i as f64 - 2.0) * 0.3).collect();
            let mut y_scalar = y0.clone();
            let mut y_dispatch = y0;
            axpy_scalar(-1.75, &x, &mut y_scalar);
            axpy(-1.75, &x, &mut y_dispatch);
            let a: Vec<u64> = y_scalar.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = y_dispatch.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "n = {}", x.len());
        }
    }

    #[test]
    fn scatter_axpy_applies_each_target_once() {
        let d = 16;
        let idx: Vec<u32> = vec![0, 3, 4, 7, 8, 11, 15];
        let vals: Vec<f64> = idx.iter().map(|&c| c as f64 + 0.5).collect();
        let mut v = vec![1.0; d];
        // SAFETY: indices above are all < d = v.len().
        unsafe { scatter_axpy(2.0, &idx, &vals, &mut v) };
        for (t, &c) in idx.iter().enumerate() {
            assert_eq!(v[c as usize], 1.0 + 2.0 * vals[t]);
        }
        assert_eq!(v[1], 1.0);
        assert_eq!(v[14], 1.0);
    }

    #[test]
    fn force_scalar_switches_and_restores() {
        force_scalar(true);
        assert!(!avx2_active());
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let b = vec![0.5; 5];
        let scalar_bits = dot(&a, &b).to_bits();
        force_scalar(false);
        // whatever mode detection lands on, the bits must not move
        assert_eq!(dot(&a, &b).to_bits(), scalar_bits);
    }
}
