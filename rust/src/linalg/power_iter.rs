//! Power iteration for the partition difficulty constants of the paper.
//!
//! `σ_k := max_α ‖A α_[k]‖² / ‖α_[k]‖²` (Eq. 19) is the largest eigenvalue
//! of `A_k A_kᵀ` (equivalently of the Gram matrix `A_kᵀ A_k`), where `A_k`
//! holds worker k's datapoints as columns — i.e. the squared spectral norm
//! of the local data block. Table 1 reports `(n²/K)/σ` with
//! `σ = Σ_k σ_k n_k` (Eq. 18); we regenerate it with this module.
//!
//! We iterate `v ← normalize(Aᵀ(A v))` on the *feature-space* operator
//! `A_k A_kᵀ ∈ R^{d×d}` applied implicitly through the CSR rows, so cost per
//! sweep is O(nnz) and no d×d matrix is ever formed.

use crate::linalg::{dense, sparse::CsrMatrix};
use crate::util::rng::Pcg32;

/// Result of a spectral norm estimate.
#[derive(Clone, Copy, Debug)]
pub struct SpectralEstimate {
    /// λ_max(AᵀA) = ‖A‖₂² (the paper's σ_k for a partition block).
    pub sigma: f64,
    /// Iterations actually used.
    pub iters: usize,
    /// Relative change of the eigenvalue estimate at the last step.
    pub rel_residual: f64,
}

/// Estimate `‖X‖₂²` for a CSR block `X` (rows = datapoints) by power
/// iteration on `XᵀX` (d×d, applied implicitly).
pub fn spectral_norm_sq(x: &CsrMatrix, max_iters: usize, tol: f64, seed: u64) -> SpectralEstimate {
    if x.rows == 0 || x.nnz() == 0 {
        return SpectralEstimate {
            sigma: 0.0,
            iters: 0,
            rel_residual: 0.0,
        };
    }
    let d = x.cols;
    let mut rng = Pcg32::seeded(seed);
    let mut v: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
    let nrm = dense::norm(&v);
    dense::scale(1.0 / nrm, &mut v);

    let mut xv = vec![0.0; x.rows];
    let mut xtxv = vec![0.0; d];
    let mut lambda_prev = 0.0f64;
    let mut rel = f64::INFINITY;
    let mut used = 0;
    for it in 0..max_iters {
        used = it + 1;
        x.matvec(&v, &mut xv);
        x.matvec_t(&xv, &mut xtxv);
        // Rayleigh quotient with unit v: λ = vᵀ XᵀX v = ‖Xv‖².
        let lambda = dense::norm_sq(&xv);
        let nrm = dense::norm(&xtxv);
        if nrm == 0.0 {
            // v in the null space — restart from a fresh random vector.
            v = (0..d).map(|_| rng.gaussian()).collect();
            let n2 = dense::norm(&v);
            dense::scale(1.0 / n2, &mut v);
            continue;
        }
        for i in 0..d {
            v[i] = xtxv[i] / nrm;
        }
        rel = if lambda > 0.0 {
            ((lambda - lambda_prev) / lambda).abs()
        } else {
            0.0
        };
        lambda_prev = lambda;
        if rel < tol && it > 2 {
            break;
        }
    }
    SpectralEstimate {
        sigma: lambda_prev,
        iters: used,
        rel_residual: rel,
    }
}

/// Convenience wrapper with library defaults.
pub fn sigma_k(block: &CsrMatrix, seed: u64) -> f64 {
    spectral_norm_sq(block, 300, 1e-9, seed).sigma
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_2x2() {
        // X = [[3, 0], [0, 1]] → XᵀX has eigenvalues 9 and 1.
        let x = CsrMatrix::from_dense(2, 2, &[3.0, 0.0, 0.0, 1.0]);
        let est = spectral_norm_sq(&x, 200, 1e-12, 1);
        assert!((est.sigma - 9.0).abs() < 1e-6, "{}", est.sigma);
    }

    #[test]
    fn rank_one_matrix() {
        // X = u vᵀ with u=[1,2], v=[1,1,1]: ‖X‖₂² = ‖u‖²‖v‖² = 5*3 = 15.
        let x = CsrMatrix::from_dense(2, 3, &[1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        let est = spectral_norm_sq(&x, 200, 1e-12, 2);
        assert!((est.sigma - 15.0).abs() < 1e-6, "{}", est.sigma);
    }

    #[test]
    fn sigma_bounded_by_frobenius_and_row_norm() {
        // For any X: max_i ‖x_i‖² ≤ ‖X‖₂² ≤ ‖X‖_F².
        let mut rng = Pcg32::seeded(3);
        let data: Vec<f64> = (0..20 * 6).map(|_| rng.gaussian()).collect();
        let x = CsrMatrix::from_dense(20, 6, &data);
        let sig = sigma_k(&x, 4);
        let fro: f64 = x.values.iter().map(|v| v * v).sum();
        let max_row = x.row_norms_sq().into_iter().fold(0.0f64, f64::max);
        assert!(sig <= fro + 1e-9, "sigma {sig} > fro {fro}");
        assert!(sig >= max_row - 1e-9, "sigma {sig} < max row {max_row}");
    }

    #[test]
    fn empty_block() {
        let x = CsrMatrix::from_rows(4, &[]);
        assert_eq!(sigma_k(&x, 0), 0.0);
    }

    #[test]
    fn normalized_rows_sigma_le_rows() {
        // Remark 7: if ‖x_i‖ ≤ 1 then σ_k ≤ n_k.
        let mut rng = Pcg32::seeded(5);
        let data: Vec<f64> = (0..30 * 8).map(|_| rng.gaussian()).collect();
        let mut x = CsrMatrix::from_dense(30, 8, &data);
        x.normalize_rows();
        let sig = sigma_k(&x, 6);
        assert!(sig <= 30.0 + 1e-9, "{sig}");
        assert!(sig >= 1.0 - 1e-6); // at least one unit row
    }
}
