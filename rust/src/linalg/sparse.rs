//! Compressed sparse row (CSR) matrix, the storage for the data matrix
//! `A = [x_1 … x_n]` (rows are datapoints, `n × d`).
//!
//! The SDCA hot loop needs exactly two sparse primitives per coordinate
//! step — `row_dot` (x_iᵀv) and `row_axpy` (v += c·x_i) — plus precomputed
//! row norms `‖x_i‖²`. Everything else (matvec, transpose-matvec, slicing a
//! partition into its own local matrix) supports the coordinator and the
//! spectral σ_k computations.
//!
//! [`CsrShard`] is the zero-copy counterpart of `select_rows`: a borrowed
//! (indptr-offset, row-range) view over a `CsrMatrix` exposing the same
//! hot-path kernels. A worker's data shard is such a view into the one
//! shared dataset instead of a cloned sub-matrix — the storage layer of
//! the shared data plane (see [`crate::subproblem::LocalBlock`]).

use crate::linalg::dense;

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// Number of rows (datapoints).
    pub rows: usize,
    /// Number of columns (features).
    pub cols: usize,
    /// Row offsets, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-row (column, value) lists. Columns within a row may be
    /// unsorted; duplicates are summed.
    pub fn from_rows(cols: usize, rows: &[Vec<(usize, f64)>]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        // One scratch buffer reused across all rows: sort, then merge runs
        // of equal columns directly into the CSR arrays — no per-row clone
        // and no per-row merge allocation.
        let mut scratch: Vec<(usize, f64)> = Vec::new();
        for row in rows {
            scratch.clear();
            scratch.extend_from_slice(row);
            scratch.sort_by_key(|&(c, _)| c);
            let mut j = 0;
            while j < scratch.len() {
                let (c, mut v) = scratch[j];
                assert!(c < cols, "column {c} out of bounds ({cols})");
                j += 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: rows.len(),
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Build a single-row matrix from one *untrusted* (column, value)
    /// list — the serving-side constructor for client feature vectors.
    /// Same semantics as [`CsrMatrix::from_rows`] (columns may be
    /// unsorted, duplicates are summed left-to-right after a stable sort,
    /// exact zeros are dropped), but hostile input surfaces as `Err`
    /// instead of a panic: an out-of-range column or non-finite value
    /// must cost the client a 4xx, never the server its life. Because the
    /// merge order matches `from_rows` bit for bit, a served row scores
    /// identically to the same row ingested at training time.
    pub fn row_from_pairs(cols: usize, pairs: &[(usize, f64)]) -> Result<CsrMatrix, String> {
        let mut scratch: Vec<(usize, f64)> = Vec::with_capacity(pairs.len());
        for &(c, v) in pairs {
            if c >= cols {
                return Err(format!("feature index {c} out of range (d = {cols})"));
            }
            if !v.is_finite() {
                return Err(format!("feature {c} has non-finite value {v}"));
            }
            scratch.push((c, v));
        }
        scratch.sort_by_key(|&(c, _)| c);
        let mut indices = Vec::with_capacity(scratch.len());
        let mut values = Vec::with_capacity(scratch.len());
        let mut j = 0;
        while j < scratch.len() {
            let (c, mut v) = scratch[j];
            j += 1;
            while j < scratch.len() && scratch[j].0 == c {
                v += scratch[j].1;
                j += 1;
            }
            if v != 0.0 {
                indices.push(c as u32);
                values.push(v);
            }
        }
        Ok(CsrMatrix {
            rows: 1,
            cols,
            indptr: vec![0, indices.len()],
            indices,
            values,
        })
    }

    /// Build from a dense row-major matrix (used in tests and the XLA path).
    pub fn from_dense(rows: usize, cols: usize, data: &[f64]) -> CsrMatrix {
        assert_eq!(data.len(), rows * cols);
        let row_lists: Vec<Vec<(usize, f64)>> = (0..rows)
            .map(|r| {
                (0..cols)
                    .filter_map(|c| {
                        let v = data[r * cols + c];
                        (v != 0.0).then_some((c, v))
                    })
                    .collect()
            })
            .collect();
        CsrMatrix::from_rows(cols, &row_lists)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// (indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Number of nonzeros in row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// x_iᵀ v for dense v.
    ///
    /// Hot path of every SDCA step. The `zip` removes the bounds checks on
    /// the CSR arrays; the gather `v[c]` is checked once against `v.len()`
    /// via the debug assert + unsafe read (columns are validated against
    /// `cols` at construction, so `c < cols == v.len()`).
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.cols);
        let (idx, vals) = self.row(i);
        // Fully dense row ⇒ indices are exactly 0..cols (sorted, deduped
        // at construction): use the contiguous SIMD-friendly dot.
        if idx.len() == self.cols {
            return dense::dot(vals, v);
        }
        let (mut s0, mut s1) = (0.0, 0.0);
        let mut it = idx.chunks_exact(2).zip(vals.chunks_exact(2));
        for (c2, v2) in &mut it {
            // SAFETY: all indices < self.cols = v.len() (checked on build).
            unsafe {
                s0 += v2[0] * *v.get_unchecked(c2[0] as usize);
                s1 += v2[1] * *v.get_unchecked(c2[1] as usize);
            }
        }
        if idx.len() % 2 == 1 {
            let j = idx.len() - 1;
            // SAFETY: j = idx.len() - 1 is in bounds for both CSR arrays
            // (idx and vals share one length by construction), and
            // idx[j] < self.cols = v.len() — columns are validated against
            // `cols` when the matrix is built.
            unsafe {
                s0 += vals[j] * *v.get_unchecked(idx[j] as usize);
            }
        }
        s0 + s1
    }

    /// v += c * x_i for dense v (same safety argument as `row_dot`).
    #[inline]
    pub fn row_axpy(&self, i: usize, c: f64, v: &mut [f64]) {
        debug_assert_eq!(v.len(), self.cols);
        let (idx, vals) = self.row(i);
        if idx.len() == self.cols {
            return dense::axpy(c, vals, v);
        }
        for (&col, &val) in idx.iter().zip(vals.iter()) {
            // SAFETY: all indices < self.cols = v.len() (checked on build).
            unsafe {
                *v.get_unchecked_mut(col as usize) += c * val;
            }
        }
    }

    /// ‖x_i‖² for every row.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                let (_, vals) = self.row(i);
                dense::norm_sq(vals)
            })
            .collect()
    }

    /// out = A v  (matvec over rows; out length = rows).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self.row_dot(i, v);
        }
    }

    /// out = Aᵀ u  (transpose matvec; out length = cols).
    pub fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        dense::zero(out);
        for i in 0..self.rows {
            self.row_axpy(i, u[i], out);
        }
    }

    /// Extract the sub-matrix of the given rows (a worker's partition),
    /// keeping the full column space.
    pub fn select_rows(&self, row_ids: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(row_ids.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &r in row_ids {
            let (idx, vals) = self.row(r);
            indices.extend_from_slice(idx);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: row_ids.len(),
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }

    /// Dense row-major copy (tests, XLA literal packing).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (j, &c) in idx.iter().enumerate() {
                out[i * self.cols + c as usize] = vals[j];
            }
        }
        out
    }

    /// Borrow rows `[start, start + len)` as a zero-copy [`CsrShard`] view.
    pub fn shard(&self, start: usize, len: usize) -> CsrShard<'_> {
        CsrShard::new(self, start, len)
    }

    /// The whole matrix as a single shard (the central-evaluation case of
    /// the shard-partial certificate protocol).
    pub fn as_shard(&self) -> CsrShard<'_> {
        CsrShard::new(self, 0, self.rows)
    }

    /// Scale each row to unit L2 norm (paper assumption ‖x_i‖ ≤ 1).
    /// Zero rows are left untouched. Returns the original norms.
    pub fn normalize_rows(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.rows);
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let nrm = dense::norm(&self.values[lo..hi]);
            norms.push(nrm);
            if nrm > 0.0 {
                for v in &mut self.values[lo..hi] {
                    *v /= nrm;
                }
            }
        }
        norms
    }
}

/// A borrowed, zero-copy row-range view over a [`CsrMatrix`]: an
/// (indptr-offset, row-range) pair instead of a cloned sub-matrix.
///
/// Shard row `i` is matrix row `start + i`; all kernels delegate to the
/// matrix's own `row_dot`/`row_axpy`/`row` hot paths, so a view pays one
/// index add per call and nothing else. This is what makes a worker's
/// data shard free: K shards of one shared matrix occupy the memory of
/// the matrix, not 2× of it.
#[derive(Clone, Copy, Debug)]
pub struct CsrShard<'a> {
    mat: &'a CsrMatrix,
    start: usize,
    len: usize,
}

impl<'a> CsrShard<'a> {
    pub fn new(mat: &'a CsrMatrix, start: usize, len: usize) -> CsrShard<'a> {
        assert!(
            start + len <= mat.rows,
            "shard [{start}, {}) out of bounds for {} rows",
            start + len,
            mat.rows
        );
        CsrShard { mat, start, len }
    }

    /// Number of rows in the view.
    #[inline]
    pub fn rows(&self) -> usize {
        self.len
    }

    /// Full column space of the underlying matrix.
    #[inline]
    pub fn cols(&self) -> usize {
        self.mat.cols
    }

    /// First underlying row (the indptr offset of the view).
    #[inline]
    pub fn start(&self) -> usize {
        self.start
    }

    /// Nonzeros inside the row range — one indptr subtraction, no scan.
    pub fn nnz(&self) -> usize {
        self.mat.indptr[self.start + self.len] - self.mat.indptr[self.start]
    }

    /// (indices, values) of shard row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f64]) {
        debug_assert!(i < self.len);
        self.mat.row(self.start + i)
    }

    /// Number of nonzeros in shard row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        self.mat.row_nnz(self.start + i)
    }

    /// x_iᵀ v — the same kernel as [`CsrMatrix::row_dot`].
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        debug_assert!(i < self.len);
        self.mat.row_dot(self.start + i, v)
    }

    /// v += c·x_i — the same kernel as [`CsrMatrix::row_axpy`].
    #[inline]
    pub fn row_axpy(&self, i: usize, c: f64, v: &mut [f64]) {
        debug_assert!(i < self.len);
        self.mat.row_axpy(self.start + i, c, v)
    }

    /// ‖x_i‖² for every shard row. Prefer the dataset's cached
    /// `row_norms_sq` slice when one exists (e.g.
    /// [`crate::subproblem::LocalBlock::norms_sq`]) — this recomputes.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.len)
            .map(|i| dense::norm_sq(self.row(i).1))
            .collect()
    }

    /// out = A_shardᵀ u (u length = shard rows, out length = cols).
    pub fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), self.len);
        assert_eq!(out.len(), self.cols());
        dense::zero(out);
        for (i, &ui) in u.iter().enumerate() {
            self.row_axpy(i, ui, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 5, 6]]
        CsrMatrix::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (1, 5.0), (2, 6.0)],
            ],
        )
    }

    #[test]
    fn structure() {
        let m = sample();
        assert_eq!(m.rows, 3);
        assert_eq!(m.cols, 3);
        assert_eq!(m.nnz(), 6);
        assert!((m.density() - 6.0 / 9.0).abs() < 1e-12);
        assert_eq!(m.row_nnz(1), 1);
    }

    #[test]
    fn row_from_pairs_matches_from_rows_bitwise() {
        // Unsorted with a duplicate column and an exact zero — the messy
        // input a serving client is allowed to send.
        let pairs = vec![(4usize, 0.5), (1, -2.0), (4, 0.25), (0, 1.5), (3, 0.0)];
        let single = CsrMatrix::row_from_pairs(6, &pairs).unwrap();
        let reference = CsrMatrix::from_rows(6, &[pairs]);
        assert_eq!(single, reference);
        let v = vec![0.5, 1.0, -1.0, 2.0, 4.0, 0.25];
        assert_eq!(
            single.row_dot(0, &v).to_bits(),
            reference.row_dot(0, &v).to_bits()
        );
    }

    #[test]
    fn row_from_pairs_rejects_hostile_input_without_panicking() {
        let err = CsrMatrix::row_from_pairs(3, &[(3, 1.0)]).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let err = CsrMatrix::row_from_pairs(3, &[(1, f64::NAN)]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        let err = CsrMatrix::row_from_pairs(3, &[(0, 1.0), (2, f64::INFINITY)]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        // empty features are a valid (all-zero) row, not an error
        let empty = CsrMatrix::row_from_pairs(3, &[]).unwrap();
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.row_dot(0, &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn row_ops() {
        let m = sample();
        let v = vec![1.0, 2.0, 3.0];
        assert!((m.row_dot(0, &v) - 7.0).abs() < 1e-12);
        assert!((m.row_dot(2, &v) - 32.0).abs() < 1e-12);
        let mut acc = vec![0.0; 3];
        m.row_axpy(2, 2.0, &mut acc);
        assert_eq!(acc, vec![8.0, 10.0, 12.0]);
    }

    #[test]
    fn matvec_roundtrip_vs_dense() {
        let m = sample();
        let d = m.to_dense();
        let v = vec![0.5, -1.0, 2.0];
        let mut out = vec![0.0; 3];
        m.matvec(&v, &mut out);
        for i in 0..3 {
            let expect: f64 = (0..3).map(|c| d[i * 3 + c] * v[c]).sum();
            assert!((out[i] - expect).abs() < 1e-12);
        }
        let u = vec![1.0, 2.0, 3.0];
        let mut out_t = vec![0.0; 3];
        m.matvec_t(&u, &mut out_t);
        for c in 0..3 {
            let expect: f64 = (0..3).map(|r| d[r * 3 + c] * u[r]).sum();
            assert!((out_t[c] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn duplicate_columns_are_summed() {
        let m = CsrMatrix::from_rows(2, &[vec![(0, 1.0), (0, 2.0)], vec![]]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0).1, &[3.0]);
        assert_eq!(m.row_nnz(1), 0);
    }

    #[test]
    fn explicit_zeros_dropped() {
        let m = CsrMatrix::from_rows(2, &[vec![(1, 0.0)], vec![(0, 5.0)]]);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn select_rows_is_partition_view() {
        let m = sample();
        let sub = m.select_rows(&[2, 0]);
        assert_eq!(sub.rows, 2);
        assert_eq!(sub.row(0).1, m.row(2).1);
        assert_eq!(sub.row(1).1, m.row(0).1);
    }

    #[test]
    fn row_norms_and_normalization() {
        let mut m = sample();
        let norms = m.row_norms_sq();
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert!((norms[2] - 77.0).abs() < 1e-12);
        let orig = m.normalize_rows();
        assert!((orig[0] - 5.0f64.sqrt()).abs() < 1e-12);
        for n in m.row_norms_sq() {
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn from_dense_roundtrip() {
        let data = vec![0.0, 1.0, 2.0, 0.0, 0.0, 3.0];
        let m = CsrMatrix::from_dense(2, 3, &data);
        assert_eq!(m.to_dense(), data);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_column_panics() {
        CsrMatrix::from_rows(2, &[vec![(5, 1.0)]]);
    }

    #[test]
    fn shard_views_rows_without_copying() {
        let m = sample();
        let s = m.shard(1, 2); // rows 1..3
        assert_eq!(s.rows(), 2);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.nnz(), 4); // 1 (row 1) + 3 (row 2)
        assert_eq!(s.row(0), m.row(1));
        assert_eq!(s.row(1), m.row(2));
        assert_eq!(s.row_nnz(1), 3);
        let v = vec![1.0, 2.0, 3.0];
        assert_eq!(s.row_dot(1, &v), m.row_dot(2, &v));
        let mut acc_s = vec![0.0; 3];
        let mut acc_m = vec![0.0; 3];
        s.row_axpy(1, 2.0, &mut acc_s);
        m.row_axpy(2, 2.0, &mut acc_m);
        assert_eq!(acc_s, acc_m);
    }

    #[test]
    fn shard_kernels_match_full_matrix() {
        let m = sample();
        let s = m.shard(0, 3);
        assert_eq!(s.row_norms_sq(), m.row_norms_sq());
        let u = vec![1.0, 2.0, 3.0];
        let (mut t_s, mut t_m) = (vec![0.0; 3], vec![0.0; 3]);
        s.matvec_t(&u, &mut t_s);
        m.matvec_t(&u, &mut t_m);
        assert_eq!(t_s, t_m);
        // a strict sub-range transposes only its own rows
        let sub = m.shard(1, 2);
        let u2 = vec![2.0, 3.0];
        let mut t_sub = vec![0.0; 3];
        sub.matvec_t(&u2, &mut t_sub);
        let mut expect = vec![0.0; 3];
        m.row_axpy(1, 2.0, &mut expect);
        m.row_axpy(2, 3.0, &mut expect);
        assert_eq!(t_sub, expect);
    }

    #[test]
    fn as_shard_covers_everything() {
        let m = sample();
        let s = m.as_shard();
        assert_eq!(s.rows(), m.rows);
        assert_eq!(s.nnz(), m.nnz());
        assert_eq!(s.start(), 0);
    }

    #[test]
    #[should_panic]
    fn shard_out_of_range_panics() {
        sample().shard(2, 2);
    }
}
