//! Compressed sparse row (CSR) matrix, the storage for the data matrix
//! `A = [x_1 … x_n]` (rows are datapoints, `n × d`).
//!
//! The SDCA hot loop needs exactly two sparse primitives per coordinate
//! step — `row_dot` (x_iᵀv) and `row_axpy` (v += c·x_i) — plus precomputed
//! row norms `‖x_i‖²`. Everything else (matvec, transpose-matvec, slicing a
//! partition into its own local matrix) supports the coordinator and the
//! spectral σ_k computations.
//!
//! [`CsrShard`] is the zero-copy counterpart of `select_rows`: a borrowed
//! (indptr-offset, row-range) view over a `CsrMatrix` exposing the same
//! hot-path kernels. A worker's data shard is such a view into the one
//! shared dataset instead of a cloned sub-matrix — the storage layer of
//! the shared data plane (see [`crate::subproblem::LocalBlock`]).

use crate::linalg::{dense, simd};

#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    /// Number of rows (datapoints).
    pub rows: usize,
    /// Number of columns (features).
    pub cols: usize,
    /// Row offsets, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-row (column, value) lists. Columns within a row may be
    /// unsorted; duplicates are summed.
    pub fn from_rows(cols: usize, rows: &[Vec<(usize, f64)>]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        // One scratch buffer reused across all rows: sort, then merge runs
        // of equal columns directly into the CSR arrays — no per-row clone
        // and no per-row merge allocation.
        let mut scratch: Vec<(usize, f64)> = Vec::new();
        for row in rows {
            scratch.clear();
            scratch.extend_from_slice(row);
            scratch.sort_by_key(|&(c, _)| c);
            let mut j = 0;
            while j < scratch.len() {
                let (c, mut v) = scratch[j];
                assert!(c < cols, "column {c} out of bounds ({cols})");
                j += 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: rows.len(),
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Build a single-row matrix from one *untrusted* (column, value)
    /// list — the serving-side constructor for client feature vectors.
    /// Same semantics as [`CsrMatrix::from_rows`] (columns may be
    /// unsorted, duplicates are summed left-to-right after a stable sort,
    /// exact zeros are dropped), but hostile input surfaces as `Err`
    /// instead of a panic: an out-of-range column or non-finite value
    /// must cost the client a 4xx, never the server its life. Because the
    /// merge order matches `from_rows` bit for bit, a served row scores
    /// identically to the same row ingested at training time.
    pub fn row_from_pairs(cols: usize, pairs: &[(usize, f64)]) -> Result<CsrMatrix, String> {
        let mut scratch: Vec<(usize, f64)> = Vec::with_capacity(pairs.len());
        for &(c, v) in pairs {
            if c >= cols {
                return Err(format!("feature index {c} out of range (d = {cols})"));
            }
            if !v.is_finite() {
                return Err(format!("feature {c} has non-finite value {v}"));
            }
            scratch.push((c, v));
        }
        scratch.sort_by_key(|&(c, _)| c);
        let mut indices = Vec::with_capacity(scratch.len());
        let mut values = Vec::with_capacity(scratch.len());
        let mut j = 0;
        while j < scratch.len() {
            let (c, mut v) = scratch[j];
            j += 1;
            while j < scratch.len() && scratch[j].0 == c {
                v += scratch[j].1;
                j += 1;
            }
            if v != 0.0 {
                indices.push(c as u32);
                values.push(v);
            }
        }
        Ok(CsrMatrix {
            rows: 1,
            cols,
            indptr: vec![0, indices.len()],
            indices,
            values,
        })
    }

    /// Build a multi-row matrix from *untrusted* per-row (column, value)
    /// lists — the batch counterpart of [`CsrMatrix::row_from_pairs`],
    /// sharing its exact merge semantics (stable sort, left-to-right
    /// duplicate summing, exact zeros dropped). Because each row merges
    /// bit-identically to `row_from_pairs`, a batched prediction scores
    /// exactly like the same rows predicted one at a time. Hostile input
    /// surfaces as `Err` naming the offending row.
    pub fn rows_from_pairs(cols: usize, rows: &[Vec<(usize, f64)>]) -> Result<CsrMatrix, String> {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        let mut scratch: Vec<(usize, f64)> = Vec::new();
        for (r, row) in rows.iter().enumerate() {
            scratch.clear();
            for &(c, v) in row {
                if c >= cols {
                    return Err(format!(
                        "row {r}: feature index {c} out of range (d = {cols})"
                    ));
                }
                if !v.is_finite() {
                    return Err(format!("row {r}: feature {c} has non-finite value {v}"));
                }
                scratch.push((c, v));
            }
            scratch.sort_by_key(|&(c, _)| c);
            let mut j = 0;
            while j < scratch.len() {
                let (c, mut v) = scratch[j];
                j += 1;
                while j < scratch.len() && scratch[j].0 == c {
                    v += scratch[j].1;
                    j += 1;
                }
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Ok(CsrMatrix {
            rows: rows.len(),
            cols,
            indptr,
            indices,
            values,
        })
    }

    /// Build from a dense row-major matrix (used in tests and the XLA path).
    pub fn from_dense(rows: usize, cols: usize, data: &[f64]) -> CsrMatrix {
        assert_eq!(data.len(), rows * cols);
        let row_lists: Vec<Vec<(usize, f64)>> = (0..rows)
            .map(|r| {
                (0..cols)
                    .filter_map(|c| {
                        let v = data[r * cols + c];
                        (v != 0.0).then_some((c, v))
                    })
                    .collect()
            })
            .collect();
        CsrMatrix::from_rows(cols, &row_lists)
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of nonzero entries.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// (indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Number of nonzeros in row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// x_iᵀ v for dense v.
    ///
    /// Hot path of every SDCA step. Fully dense rows (indices are exactly
    /// `0..cols` — sorted, deduped at construction) take the contiguous
    /// dense kernel; everything else takes the gather kernel. Both
    /// dispatch to AVX2 with a portable scalar fallback in
    /// [`crate::linalg::simd`], and both have a fixed lane-reduction
    /// order, so the returned bits do not depend on which path ran.
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.cols);
        let (idx, vals) = self.row(i);
        if idx.len() == self.cols {
            return dense::dot(vals, v);
        }
        // SAFETY: all indices < self.cols = v.len() (checked on build).
        unsafe { simd::gather_dot(idx, vals, v) }
    }

    /// v += c * x_i for dense v (same safety argument as `row_dot`):
    /// dense rows use the vectorized axpy, sparse rows the unrolled
    /// scatter kernel.
    #[inline]
    pub fn row_axpy(&self, i: usize, c: f64, v: &mut [f64]) {
        debug_assert_eq!(v.len(), self.cols);
        let (idx, vals) = self.row(i);
        if idx.len() == self.cols {
            return dense::axpy(c, vals, v);
        }
        // SAFETY: all indices < self.cols = v.len() (checked on build).
        unsafe { simd::scatter_axpy(c, idx, vals, v) }
    }

    /// `out[b] = x_{start+b}ᵀ v` for every `b < out.len()` — the blocked
    /// multi-row form of [`CsrMatrix::row_dot`] behind `matvec`, serve
    /// batch prediction, and certificate margins.
    ///
    /// Rows are walked in fixed 64-row blocks: a block's indices/values
    /// are contiguous in the CSR arrays, so each block streams through
    /// the low cache levels while `v` stays resident across the whole
    /// call. Every output element is bit-identical to the corresponding
    /// single-row `row_dot`.
    pub fn rows_dot(&self, start: usize, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.cols);
        assert!(
            start + out.len() <= self.rows,
            "rows_dot range [{start}, {}) out of bounds for {} rows",
            start + out.len(),
            self.rows
        );
        const BLOCK: usize = 64;
        let mut base = 0;
        while base < out.len() {
            let hi = (base + BLOCK).min(out.len());
            for (b, slot) in out[base..hi].iter_mut().enumerate() {
                let i = start + base + b;
                let lo = self.indptr[i];
                let up = self.indptr[i + 1];
                let idx = &self.indices[lo..up];
                let vals = &self.values[lo..up];
                *slot = if idx.len() == self.cols {
                    dense::dot(vals, v)
                } else {
                    // SAFETY: all indices < self.cols = v.len() (checked
                    // on build).
                    unsafe { simd::gather_dot(idx, vals, v) }
                };
            }
            base = hi;
        }
    }

    /// ‖x_i‖² for every row.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                let (_, vals) = self.row(i);
                dense::norm_sq(vals)
            })
            .collect()
    }

    /// out = A v  (matvec over rows; out length = rows). Rides the
    /// blocked [`CsrMatrix::rows_dot`] kernel.
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        self.rows_dot(0, v, out);
    }

    /// out = Aᵀ u  (transpose matvec; out length = cols).
    pub fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        dense::zero(out);
        for i in 0..self.rows {
            self.row_axpy(i, u[i], out);
        }
    }

    /// Extract the sub-matrix of the given rows (a worker's partition),
    /// keeping the full column space.
    pub fn select_rows(&self, row_ids: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(row_ids.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &r in row_ids {
            let (idx, vals) = self.row(r);
            indices.extend_from_slice(idx);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: row_ids.len(),
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }

    /// Consuming variant of [`CsrMatrix::select_rows`] for full row
    /// permutations: new row `p` holds old row `new_to_old[p]`,
    /// bit-identical to `select_rows(new_to_old)`, but the old storage is
    /// replaced one array at a time — the old index array is dropped
    /// before the new value array is built, so peak memory is one matrix
    /// plus one nnz-sized array instead of two matrices.
    pub fn permute_rows(self, new_to_old: &[usize]) -> CsrMatrix {
        assert_eq!(new_to_old.len(), self.rows, "permutation must cover all rows");
        let CsrMatrix {
            rows,
            cols,
            indptr: old_ip,
            indices: old_ix,
            values: old_v,
        } = self;
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let mut nnz = 0usize;
        for &r in new_to_old {
            nnz += old_ip[r + 1] - old_ip[r];
            indptr.push(nnz);
        }
        let mut indices = Vec::with_capacity(nnz);
        for &r in new_to_old {
            indices.extend_from_slice(&old_ix[old_ip[r]..old_ip[r + 1]]);
        }
        drop(old_ix);
        let mut values = Vec::with_capacity(nnz);
        for &r in new_to_old {
            values.extend_from_slice(&old_v[old_ip[r]..old_ip[r + 1]]);
        }
        CsrMatrix {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    /// Dense row-major copy (tests, XLA literal packing).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.rows * self.cols];
        for i in 0..self.rows {
            let (idx, vals) = self.row(i);
            for (j, &c) in idx.iter().enumerate() {
                out[i * self.cols + c as usize] = vals[j];
            }
        }
        out
    }

    /// Borrow rows `[start, start + len)` as a zero-copy [`CsrShard`] view.
    pub fn shard(&self, start: usize, len: usize) -> CsrShard<'_> {
        CsrShard::new(self, start, len)
    }

    /// The whole matrix as a single shard (the central-evaluation case of
    /// the shard-partial certificate protocol).
    pub fn as_shard(&self) -> CsrShard<'_> {
        CsrShard::new(self, 0, self.rows)
    }

    /// Scale each row to unit L2 norm (paper assumption ‖x_i‖ ≤ 1).
    /// Zero rows are left untouched. Returns the original norms.
    pub fn normalize_rows(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.rows);
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let nrm = dense::norm(&self.values[lo..hi]);
            norms.push(nrm);
            if nrm > 0.0 {
                for v in &mut self.values[lo..hi] {
                    *v /= nrm;
                }
            }
        }
        norms
    }
}

/// A borrowed, zero-copy row-range view over a [`CsrMatrix`]: an
/// (indptr-offset, row-range) pair instead of a cloned sub-matrix.
///
/// Shard row `i` is matrix row `start + i`; all kernels delegate to the
/// matrix's own `row_dot`/`row_axpy`/`row` hot paths, so a view pays one
/// index add per call and nothing else. This is what makes a worker's
/// data shard free: K shards of one shared matrix occupy the memory of
/// the matrix, not 2× of it.
#[derive(Clone, Copy, Debug)]
pub struct CsrShard<'a> {
    mat: &'a CsrMatrix,
    start: usize,
    len: usize,
}

impl<'a> CsrShard<'a> {
    pub fn new(mat: &'a CsrMatrix, start: usize, len: usize) -> CsrShard<'a> {
        assert!(
            start + len <= mat.rows,
            "shard [{start}, {}) out of bounds for {} rows",
            start + len,
            mat.rows
        );
        CsrShard { mat, start, len }
    }

    /// Number of rows in the view.
    #[inline]
    pub fn rows(&self) -> usize {
        self.len
    }

    /// Full column space of the underlying matrix.
    #[inline]
    pub fn cols(&self) -> usize {
        self.mat.cols
    }

    /// First underlying row (the indptr offset of the view).
    #[inline]
    pub fn start(&self) -> usize {
        self.start
    }

    /// Nonzeros inside the row range — one indptr subtraction, no scan.
    pub fn nnz(&self) -> usize {
        self.mat.indptr[self.start + self.len] - self.mat.indptr[self.start]
    }

    /// (indices, values) of shard row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f64]) {
        debug_assert!(i < self.len);
        self.mat.row(self.start + i)
    }

    /// Number of nonzeros in shard row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        debug_assert!(i < self.len);
        self.mat.row_nnz(self.start + i)
    }

    /// x_iᵀ v — the same kernel as [`CsrMatrix::row_dot`].
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        debug_assert!(i < self.len);
        self.mat.row_dot(self.start + i, v)
    }

    /// v += c·x_i — the same kernel as [`CsrMatrix::row_axpy`].
    #[inline]
    pub fn row_axpy(&self, i: usize, c: f64, v: &mut [f64]) {
        debug_assert!(i < self.len);
        self.mat.row_axpy(self.start + i, c, v)
    }

    /// `out[b] = x_{start+b}ᵀ v` over shard rows — the same blocked
    /// kernel as [`CsrMatrix::rows_dot`], offset into the view.
    pub fn rows_dot(&self, start: usize, v: &[f64], out: &mut [f64]) {
        assert!(
            start + out.len() <= self.len,
            "rows_dot range [{start}, {}) out of bounds for shard of {} rows",
            start + out.len(),
            self.len
        );
        self.mat.rows_dot(self.start + start, v, out)
    }

    /// ‖x_i‖² for every shard row. Prefer the dataset's cached
    /// `row_norms_sq` slice when one exists (e.g.
    /// [`crate::subproblem::LocalBlock::norms_sq`]) — this recomputes.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.len)
            .map(|i| dense::norm_sq(self.row(i).1))
            .collect()
    }

    /// out = A_shardᵀ u (u length = shard rows, out length = cols).
    pub fn matvec_t(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), self.len);
        assert_eq!(out.len(), self.cols());
        dense::zero(out);
        for (i, &ui) in u.iter().enumerate() {
            self.row_axpy(i, ui, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 5, 6]]
        CsrMatrix::from_rows(
            3,
            &[
                vec![(0, 1.0), (2, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 4.0), (1, 5.0), (2, 6.0)],
            ],
        )
    }

    #[test]
    fn structure() {
        let m = sample();
        assert_eq!(m.rows, 3);
        assert_eq!(m.cols, 3);
        assert_eq!(m.nnz(), 6);
        assert!((m.density() - 6.0 / 9.0).abs() < 1e-12);
        assert_eq!(m.row_nnz(1), 1);
    }

    #[test]
    fn row_from_pairs_matches_from_rows_bitwise() {
        // Unsorted with a duplicate column and an exact zero — the messy
        // input a serving client is allowed to send.
        let pairs = vec![(4usize, 0.5), (1, -2.0), (4, 0.25), (0, 1.5), (3, 0.0)];
        let single = CsrMatrix::row_from_pairs(6, &pairs).unwrap();
        let reference = CsrMatrix::from_rows(6, &[pairs]);
        assert_eq!(single, reference);
        let v = vec![0.5, 1.0, -1.0, 2.0, 4.0, 0.25];
        assert_eq!(
            single.row_dot(0, &v).to_bits(),
            reference.row_dot(0, &v).to_bits()
        );
    }

    #[test]
    fn row_from_pairs_rejects_hostile_input_without_panicking() {
        let err = CsrMatrix::row_from_pairs(3, &[(3, 1.0)]).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let err = CsrMatrix::row_from_pairs(3, &[(1, f64::NAN)]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        let err = CsrMatrix::row_from_pairs(3, &[(0, 1.0), (2, f64::INFINITY)]).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        // empty features are a valid (all-zero) row, not an error
        let empty = CsrMatrix::row_from_pairs(3, &[]).unwrap();
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.row_dot(0, &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn rows_from_pairs_matches_row_from_pairs_bitwise() {
        let rows = vec![
            vec![(4usize, 0.5), (1, -2.0), (4, 0.25), (0, 1.5), (3, 0.0)],
            vec![],
            vec![(5, -0.0), (2, 1e-310), (2, 3.0)],
        ];
        let batch = CsrMatrix::rows_from_pairs(6, &rows).unwrap();
        assert_eq!(batch.rows, 3);
        let v = vec![0.5, 1.0, -1.0, 2.0, 4.0, 0.25];
        for (r, row) in rows.iter().enumerate() {
            let single = CsrMatrix::row_from_pairs(6, row).unwrap();
            assert_eq!(batch.row(r), single.row(0), "row {r}");
            assert_eq!(
                batch.row_dot(r, &v).to_bits(),
                single.row_dot(0, &v).to_bits(),
                "row {r}"
            );
        }
    }

    #[test]
    fn rows_from_pairs_errors_name_the_row() {
        let err = CsrMatrix::rows_from_pairs(3, &[vec![(0, 1.0)], vec![(7, 1.0)]]).unwrap_err();
        assert!(err.contains("row 1"), "{err}");
        assert!(err.contains("out of range"), "{err}");
        let err =
            CsrMatrix::rows_from_pairs(3, &[vec![], vec![], vec![(1, f64::NAN)]]).unwrap_err();
        assert!(err.contains("row 2"), "{err}");
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn rows_dot_matches_row_dot_bitwise() {
        // > 64 rows so the blocked walk crosses a block boundary, with
        // empty, single-nnz, and fully dense rows mixed in.
        let d = 24;
        let rows: Vec<Vec<(usize, f64)>> = (0..150)
            .map(|r| match r % 4 {
                0 => vec![],
                1 => vec![(r % d, (r as f64 - 40.0) * 0.125)],
                2 => (0..d).map(|c| (c, ((r + c) % 9) as f64 - 4.0)).collect(),
                _ => (0..d)
                    .filter(|c| (r + c) % 3 == 0)
                    .map(|c| (c, (c as f64 - 7.0) * 0.5))
                    .collect(),
            })
            .collect();
        let m = CsrMatrix::from_rows(d, &rows);
        let v: Vec<f64> = (0..d).map(|c| ((c * 31 + 7) % 17) as f64 - 8.0).collect();
        let mut out = vec![0.0; m.rows];
        m.rows_dot(0, &v, &mut out);
        for i in 0..m.rows {
            assert_eq!(out[i].to_bits(), m.row_dot(i, &v).to_bits(), "row {i}");
        }
        // offset sub-range through a shard view
        let s = m.shard(5, 80);
        let mut sub = vec![0.0; 70];
        s.rows_dot(3, &v, &mut sub);
        for (b, got) in sub.iter().enumerate() {
            assert_eq!(got.to_bits(), m.row_dot(5 + 3 + b, &v).to_bits());
        }
    }

    #[test]
    fn row_ops() {
        let m = sample();
        let v = vec![1.0, 2.0, 3.0];
        assert!((m.row_dot(0, &v) - 7.0).abs() < 1e-12);
        assert!((m.row_dot(2, &v) - 32.0).abs() < 1e-12);
        let mut acc = vec![0.0; 3];
        m.row_axpy(2, 2.0, &mut acc);
        assert_eq!(acc, vec![8.0, 10.0, 12.0]);
    }

    #[test]
    fn matvec_roundtrip_vs_dense() {
        let m = sample();
        let d = m.to_dense();
        let v = vec![0.5, -1.0, 2.0];
        let mut out = vec![0.0; 3];
        m.matvec(&v, &mut out);
        for i in 0..3 {
            let expect: f64 = (0..3).map(|c| d[i * 3 + c] * v[c]).sum();
            assert!((out[i] - expect).abs() < 1e-12);
        }
        let u = vec![1.0, 2.0, 3.0];
        let mut out_t = vec![0.0; 3];
        m.matvec_t(&u, &mut out_t);
        for c in 0..3 {
            let expect: f64 = (0..3).map(|r| d[r * 3 + c] * u[r]).sum();
            assert!((out_t[c] - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn duplicate_columns_are_summed() {
        let m = CsrMatrix::from_rows(2, &[vec![(0, 1.0), (0, 2.0)], vec![]]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0).1, &[3.0]);
        assert_eq!(m.row_nnz(1), 0);
    }

    #[test]
    fn explicit_zeros_dropped() {
        let m = CsrMatrix::from_rows(2, &[vec![(1, 0.0)], vec![(0, 5.0)]]);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn select_rows_is_partition_view() {
        let m = sample();
        let sub = m.select_rows(&[2, 0]);
        assert_eq!(sub.rows, 2);
        assert_eq!(sub.row(0).1, m.row(2).1);
        assert_eq!(sub.row(1).1, m.row(0).1);
    }

    #[test]
    fn permute_rows_matches_select_rows_bitwise() {
        let m = sample();
        let perm: Vec<usize> = (0..m.rows).rev().collect();
        let selected = m.select_rows(&perm);
        let permuted = m.clone().permute_rows(&perm);
        assert_eq!(permuted.rows, selected.rows);
        assert_eq!(permuted.cols, selected.cols);
        assert_eq!(permuted.indptr, selected.indptr);
        assert_eq!(permuted.indices, selected.indices);
        assert_eq!(
            permuted.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            selected.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn row_norms_and_normalization() {
        let mut m = sample();
        let norms = m.row_norms_sq();
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert!((norms[2] - 77.0).abs() < 1e-12);
        let orig = m.normalize_rows();
        assert!((orig[0] - 5.0f64.sqrt()).abs() < 1e-12);
        for n in m.row_norms_sq() {
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn from_dense_roundtrip() {
        let data = vec![0.0, 1.0, 2.0, 0.0, 0.0, 3.0];
        let m = CsrMatrix::from_dense(2, 3, &data);
        assert_eq!(m.to_dense(), data);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_column_panics() {
        CsrMatrix::from_rows(2, &[vec![(5, 1.0)]]);
    }

    #[test]
    fn shard_views_rows_without_copying() {
        let m = sample();
        let s = m.shard(1, 2); // rows 1..3
        assert_eq!(s.rows(), 2);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.nnz(), 4); // 1 (row 1) + 3 (row 2)
        assert_eq!(s.row(0), m.row(1));
        assert_eq!(s.row(1), m.row(2));
        assert_eq!(s.row_nnz(1), 3);
        let v = vec![1.0, 2.0, 3.0];
        assert_eq!(s.row_dot(1, &v), m.row_dot(2, &v));
        let mut acc_s = vec![0.0; 3];
        let mut acc_m = vec![0.0; 3];
        s.row_axpy(1, 2.0, &mut acc_s);
        m.row_axpy(2, 2.0, &mut acc_m);
        assert_eq!(acc_s, acc_m);
    }

    #[test]
    fn shard_kernels_match_full_matrix() {
        let m = sample();
        let s = m.shard(0, 3);
        assert_eq!(s.row_norms_sq(), m.row_norms_sq());
        let u = vec![1.0, 2.0, 3.0];
        let (mut t_s, mut t_m) = (vec![0.0; 3], vec![0.0; 3]);
        s.matvec_t(&u, &mut t_s);
        m.matvec_t(&u, &mut t_m);
        assert_eq!(t_s, t_m);
        // a strict sub-range transposes only its own rows
        let sub = m.shard(1, 2);
        let u2 = vec![2.0, 3.0];
        let mut t_sub = vec![0.0; 3];
        sub.matvec_t(&u2, &mut t_sub);
        let mut expect = vec![0.0; 3];
        m.row_axpy(1, 2.0, &mut expect);
        m.row_axpy(2, 3.0, &mut expect);
        assert_eq!(t_sub, expect);
    }

    #[test]
    fn as_shard_covers_everything() {
        let m = sample();
        let s = m.as_shard();
        assert_eq!(s.rows(), m.rows);
        assert_eq!(s.nnz(), m.nnz());
        assert_eq!(s.start(), 0);
    }

    #[test]
    #[should_panic]
    fn shard_out_of_range_panics() {
        sample().shard(2, 2);
    }
}
