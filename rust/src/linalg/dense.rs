//! Dense vector operations on `&[f64]` / `Vec<f64>`.
//!
//! These are the primitives on the coordinator's hot path: the shared
//! primal vector `w ∈ R^d` and the per-worker updates `Δw_k` are dense even
//! when the data matrix is sparse. Everything is written allocation-free
//! over slices so callers control buffer reuse.

use crate::linalg::simd;

/// Dot product. Dispatches to the explicit-SIMD kernel
/// ([`crate::linalg::simd::dot`]); the 4-lane accumulator layout and the
/// final `s0 + s1 + s2 + s3 + tail` reduction are fixed there, so the
/// returned bits are identical whether AVX2 or the portable scalar
/// fallback ran.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// `y += alpha * x` (SIMD-dispatched; per-element mul-then-add, so bits
/// never depend on the selected kernel).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy(alpha, x, y)
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn norm(x: &[f64]) -> f64 {
    norm_sq(x).sqrt()
}

/// Elementwise `out = a + b`.
pub fn add(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// Elementwise `out = a - b`.
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// Set all entries to zero (buffer reuse helper).
#[inline]
pub fn zero(x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi = 0.0;
    }
}

/// Max |x_i|.
pub fn max_abs(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// L2 distance between two vectors.
pub fn distance(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (13 - i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_scale_norm() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![1.5, 2.5, 3.5]);
        assert!((norm_sq(&x) - 14.0).abs() < 1e-12);
        assert!((norm(&x) - 14.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn add_sub_distance() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 5.0];
        let mut out = vec![0.0; 2];
        add(&a, &b, &mut out);
        assert_eq!(out, vec![4.0, 7.0]);
        sub(&b, &a, &mut out);
        assert_eq!(out, vec![2.0, 3.0]);
        assert!((distance(&a, &b) - 13.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(norm(&[]), 0.0);
        assert_eq!(max_abs(&[]), 0.0);
    }

    #[test]
    fn max_abs_finds_negative_peak() {
        assert_eq!(max_abs(&[1.0, -5.0, 3.0]), 5.0);
    }
}
