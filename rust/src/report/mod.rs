//! Experiment reporting: CSV/JSON writers and terminal ASCII plots.

pub mod ascii_plot;
pub mod csv;

use std::path::{Path, PathBuf};

/// Where experiment outputs land (CSV series + JSON summaries).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("COCOA_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    PathBuf::from(dir)
}

/// Write text to `results_dir()/name`, creating directories as needed.
pub fn write_result(name: &str, contents: &str) -> std::io::Result<PathBuf> {
    let path = results_dir().join(name);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, contents)?;
    Ok(path)
}

/// Write to an explicit path, creating parents.
pub fn write_to(path: &Path, contents: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, contents)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_result_creates_dirs() {
        std::env::set_var("COCOA_RESULTS_DIR", "/tmp/cocoa_report_test");
        let p = write_result("sub/dir/file.csv", "a,b\n1,2\n").unwrap();
        assert!(p.exists());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b\n1,2\n");
        std::fs::remove_dir_all("/tmp/cocoa_report_test").ok();
        std::env::remove_var("COCOA_RESULTS_DIR");
    }
}
