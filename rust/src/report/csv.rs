//! Minimal CSV writer/reader for experiment series.

/// Build a CSV string from a header and rows of f64 cells.
pub fn to_csv(header: &[&str], rows: &[Vec<f64>]) -> String {
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format_cell(*v)).collect();
        out.push_str(&cells.join(","));
        out.push('\n');
    }
    out
}

fn format_cell(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.10e}")
    }
}

/// Parse a CSV of f64 cells back (header returned separately). Tolerates
/// blank lines; fails on ragged or non-numeric rows.
pub fn parse_csv(text: &str) -> Result<(Vec<String>, Vec<Vec<f64>>), String> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header: Vec<String> = lines
        .next()
        .ok_or("empty csv")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        let cells: Result<Vec<f64>, _> = line.split(',').map(|c| c.trim().parse::<f64>()).collect();
        let cells = cells.map_err(|e| format!("row {}: {e}", i + 2))?;
        if cells.len() != header.len() {
            return Err(format!(
                "row {}: {} cells, expected {}",
                i + 2,
                cells.len(),
                header.len()
            ));
        }
        rows.push(cells);
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let csv = to_csv(&["a", "b"], &[vec![1.0, 2.5], vec![3.0, 4.0]]);
        let (h, rows) = parse_csv(&csv).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows.len(), 2);
        assert!((rows[0][1] - 2.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_ragged() {
        assert!(parse_csv("a,b\n1\n").is_err());
        assert!(parse_csv("a\nxyz\n").is_err());
        assert!(parse_csv("").is_err());
    }
}
