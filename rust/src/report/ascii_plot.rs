//! Terminal ASCII plots for experiment output — log-log line charts like
//! the paper's Figures 1–3, rendered into the experiment logs so results
//! are inspectable without any plotting stack.

/// One plotted series.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub marker: char,
}

impl Series {
    pub fn new(label: &str, xs: Vec<f64>, ys: Vec<f64>, marker: char) -> Series {
        assert_eq!(xs.len(), ys.len());
        Series {
            label: label.to_string(),
            xs,
            ys,
            marker,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct PlotCfg {
    pub width: usize,
    pub height: usize,
    pub log_x: bool,
    pub log_y: bool,
}

impl Default for PlotCfg {
    fn default() -> Self {
        PlotCfg {
            width: 72,
            height: 20,
            log_x: true,
            log_y: true,
        }
    }
}

fn tx(v: f64, log: bool) -> Option<f64> {
    if !v.is_finite() {
        return None;
    }
    if log {
        if v <= 0.0 {
            None
        } else {
            Some(v.log10())
        }
    } else {
        Some(v)
    }
}

/// Render a multi-series chart to a string.
pub fn render(title: &str, series: &[Series], cfg: &PlotCfg) -> String {
    // Collect transformed points.
    let mut pts: Vec<(usize, f64, f64)> = Vec::new();
    for (si, s) in series.iter().enumerate() {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if let (Some(px), Some(py)) = (tx(x, cfg.log_x), tx(y, cfg.log_y)) {
                pts.push((si, px, py));
            }
        }
    }
    if pts.is_empty() {
        return format!("{title}\n(no plottable points)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }

    let mut grid = vec![vec![' '; cfg.width]; cfg.height];
    for &(si, x, y) in &pts {
        let cx = ((x - x0) / (x1 - x0) * (cfg.width - 1) as f64).round() as usize;
        let cy = ((y - y0) / (y1 - y0) * (cfg.height - 1) as f64).round() as usize;
        let row = cfg.height - 1 - cy;
        grid[row][cx] = series[si].marker;
    }

    let fmt_tick = |v: f64, log: bool| -> String {
        if log {
            format!("1e{v:.1}")
        } else {
            format!("{v:.3}")
        }
    };

    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    for (li, row) in grid.iter().enumerate() {
        let ylab = if li == 0 {
            fmt_tick(y1, cfg.log_y)
        } else if li == cfg.height - 1 {
            fmt_tick(y0, cfg.log_y)
        } else {
            String::new()
        };
        out.push_str(&format!("{ylab:>9} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(cfg.width)));
    out.push_str(&format!(
        "{:>10} {:<w$}{}\n",
        "",
        fmt_tick(x0, cfg.log_x),
        fmt_tick(x1, cfg.log_x),
        w = cfg.width.saturating_sub(6)
    ));
    for s in series {
        out.push_str(&format!("    {} {}\n", s.marker, s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series() {
        let s1 = Series::new(
            "cocoa+",
            vec![1.0, 10.0, 100.0],
            vec![1.0, 0.1, 0.001],
            '+',
        );
        let s2 = Series::new("cocoa", vec![1.0, 10.0, 100.0], vec![1.0, 0.5, 0.1], 'o');
        let chart = render("gap vs rounds", &[s1, s2], &PlotCfg::default());
        assert!(chart.contains('+'));
        assert!(chart.contains('o'));
        assert!(chart.contains("cocoa+"));
        assert!(chart.lines().count() > 20);
    }

    #[test]
    fn skips_nonpositive_on_log_axes() {
        let s = Series::new("s", vec![0.0, 1.0], vec![-1.0, 1.0], '*');
        let chart = render("t", &[s], &PlotCfg::default());
        // only the (1,1) point is plottable; must not panic
        assert!(chart.contains('*'));
    }

    #[test]
    fn empty_series_ok() {
        let s = Series::new("s", vec![], vec![], '*');
        let chart = render("t", &[s], &PlotCfg::default());
        assert!(chart.contains("no plottable points"));
    }

    #[test]
    fn linear_axes() {
        let cfg = PlotCfg {
            log_x: false,
            log_y: false,
            ..Default::default()
        };
        let s = Series::new("s", vec![0.0, 1.0, 2.0], vec![0.0, 1.0, 4.0], 'x');
        let chart = render("t", &[s], &cfg);
        assert!(chart.contains('x'));
    }
}
