//! The data-local subproblem `G_k^{σ'}` of CoCoA+ (Eq. 8–9) — the paper's
//! central object. Each worker k holds a [`LocalBlock`] (its partition of
//! the data) and maximizes
//!
//!   G_k^{σ'}(Δα_[k]; w, α_[k]) = −(1/n) Σ_{i∈P_k} ℓ*_i(−α_i − Δα_i)
//!       − (λ/2K)‖w‖² − (1/n) wᵀ A Δα_[k] − (λσ'/2) ‖A Δα_[k]/(λn)‖²
//!
//! approximately (Assumption 1, Θ-quality). The quadratic term scaled by σ'
//! is what makes additive aggregation (γ=1) safe: Lemma 3 shows that for
//! σ' ≥ γ·max ‖AΔ‖²/Σ‖AΔ_[k]‖², the sum of local gains lower-bounds the
//! global dual improvement.
//!
//! Since the zero-copy refactor a [`LocalBlock`] owns no matrix: it is a
//! contiguous row-range **view** into the shared `Arc<Dataset>` (see
//! [`LocalBlock::split`] and the permuted-contiguous layout in
//! [`crate::data::partition`]), exposing the same kernels through
//! [`LocalBlock::x`]/[`LocalBlock::y`]/[`LocalBlock::norms_sq`] so the
//! local solvers' inner loops are unchanged.

pub mod sigma;

use crate::data::{Dataset, Partition, ShardLayout};
use crate::linalg::{dense, CsrShard};
use crate::loss::Loss;
use std::sync::Arc;

/// Worker k's resident slice of the problem — a **view**, not a copy.
///
/// A block is an `Arc` to the shared dataset plus a contiguous row range
/// in it; the matrix shard ([`LocalBlock::x`]), labels ([`LocalBlock::y`])
/// and cached norms ([`LocalBlock::norms_sq`]) are all borrowed slices of
/// the shared storage. K blocks of one dataset therefore occupy the
/// memory of the dataset — the old per-worker `CsrMatrix` clones are
/// gone. Blocks over an arbitrary (non-contiguous) partition are produced
/// by permuting the dataset once into a
/// [`ShardLayout`](crate::data::ShardLayout); a block is then fully
/// addressed by its `(start, len)` range — local row `i` IS layout row
/// `start + i`, so the per-block O(n_k) index vectors of the old design
/// carry no information and are gone. Callers that scatter Δα back to a
/// *pre-layout* row order keep their own `Partition.parts[k]` list for
/// that (the layout preserves within-part order).
#[derive(Clone, Debug)]
pub struct LocalBlock {
    /// Shared (possibly permuted) dataset all sibling blocks view into.
    data: Arc<Dataset>,
    /// First shared-dataset row of this block.
    start: usize,
    /// Number of local rows n_k.
    len: usize,
}

impl LocalBlock {
    /// A view over rows `[start, start + len)` of a shared dataset.
    pub fn view(data: Arc<Dataset>, start: usize, len: usize) -> LocalBlock {
        assert!(start + len <= data.n(), "block rows out of range");
        LocalBlock { data, start, len }
    }

    /// Gather arbitrary rows into a standalone single-block dataset (used
    /// for one-off blocks in tests, benchmarks, and the Θ estimator; the
    /// K-way path is [`LocalBlock::split`], which shares storage).
    pub fn from_partition(data: &Dataset, part_rows: &[usize]) -> LocalBlock {
        let gathered = Arc::new(data.gather_rows(part_rows));
        LocalBlock::view(gathered, 0, part_rows.len())
    }

    /// Build all K blocks of a partition as views over shared storage.
    ///
    /// A contiguous partition yields views directly into `data` — zero
    /// copies. Any other partition is realized through
    /// [`Partition::apply_permutation`]: the dataset is reordered **once**
    /// and all K blocks view the single permuted copy. Block k's local
    /// row `i` holds the caller's row `partition.parts[k][i]` — keep that
    /// list around when Δα must scatter back to the caller's row order.
    pub fn split(data: &Arc<Dataset>, partition: &Partition) -> Vec<LocalBlock> {
        let layout = partition.apply_permutation(Arc::clone(data));
        LocalBlock::from_layout(&layout)
    }

    /// The K view-blocks of an already-realized [`ShardLayout`]: block k
    /// is the `(start, len)` range `layout.shards[k]` of `layout.data`.
    /// This is the trainer's path — its global α lives in layout order,
    /// so `start + i` addresses it directly.
    pub fn from_layout(layout: &ShardLayout) -> Vec<LocalBlock> {
        layout
            .shards
            .iter()
            .map(|&(start, len)| LocalBlock::view(Arc::clone(&layout.data), start, len))
            .collect()
    }

    /// The matrix shard: same `row_dot`/`row_axpy` kernels, zero copy.
    #[inline]
    pub fn x(&self) -> CsrShard<'_> {
        self.data.x.shard(self.start, self.len)
    }

    /// Local labels.
    #[inline]
    pub fn y(&self) -> &[f64] {
        &self.data.y[self.start..self.start + self.len]
    }

    /// Precomputed ‖x_i‖² for the local rows.
    #[inline]
    pub fn norms_sq(&self) -> &[f64] {
        &self.data.row_norms_sq[self.start..self.start + self.len]
    }

    /// The shared dataset this block views (sibling blocks of a `split`
    /// return the same `Arc`).
    pub fn shared_data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// First shared-dataset row of this block.
    pub fn start(&self) -> usize {
        self.start
    }

    pub fn n_local(&self) -> usize {
        self.len
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }
}

/// Hyperparameters of the local subproblem, fixed per run.
#[derive(Clone, Copy, Debug)]
pub struct SubproblemSpec {
    pub loss: Loss,
    pub lambda: f64,
    /// Global number of datapoints n (the subproblem scales by 1/n, not 1/n_k).
    pub n_global: usize,
    /// σ' — the subproblem difficulty parameter (Eq. 11; safe choice γK).
    pub sigma_prime: f64,
    /// K — number of workers (only enters through the constant ‖w‖² term).
    pub k: usize,
}

impl SubproblemSpec {
    /// Per-coordinate quadratic coefficient σ'‖x_i‖²/(λn): the curvature of
    /// the 1-D problem solved by each SDCA step.
    #[inline]
    pub fn coef(&self, norm_sq: f64) -> f64 {
        self.sigma_prime * norm_sq / (self.lambda * self.n_global as f64)
    }

    /// Step scale for maintaining the local primal image
    /// v = w + (σ'/(λn))·A Δα: each δ on row i adds `v_scale·δ·x_i`.
    #[inline]
    pub fn v_scale(&self) -> f64 {
        self.sigma_prime / (self.lambda * self.n_global as f64)
    }
}

/// Evaluate G_k^{σ'}(Δα; w, α) exactly (Eq. 9). Used by tests, by the
/// Θ-quality estimator, and by monotonicity checks — not on the hot path.
pub fn subproblem_value(
    block: &LocalBlock,
    spec: &SubproblemSpec,
    w: &[f64],
    alpha_local: &[f64],
    delta_local: &[f64],
) -> f64 {
    let n = spec.n_global as f64;
    let nk = block.n_local();
    assert_eq!(alpha_local.len(), nk);
    assert_eq!(delta_local.len(), nk);
    let y = block.y();

    // −(1/n) Σ ℓ*(−(α+Δ))
    let mut conj = 0.0;
    for i in 0..nk {
        let c = spec
            .loss
            .conjugate_neg(alpha_local[i] + delta_local[i], y[i]);
        if c.is_infinite() {
            return f64::NEG_INFINITY;
        }
        conj += c;
    }

    // A Δα (in feature space)
    let mut a_delta = vec![0.0; block.d()];
    block.x().matvec_t(delta_local, &mut a_delta);

    let term_conj = -conj / n;
    let term_reg = -(0.5 * spec.lambda / spec.k as f64) * dense::norm_sq(w);
    let term_lin = -dense::dot(w, &a_delta) / n;
    let term_quad = -0.5 * spec.lambda * spec.sigma_prime
        * dense::norm_sq(&a_delta)
        / (spec.lambda * n).powi(2);
    term_conj + term_reg + term_lin + term_quad
}

/// Lemma 3 right-hand side: (1−γ)·D(α) + γ·Σ_k G_k^{σ'}(Δα_[k]) — used by
/// the property tests to verify the paper's key inequality on instances.
pub fn lemma3_rhs(d_alpha: f64, gamma: f64, local_gains: &[f64]) -> f64 {
    (1.0 - gamma) * d_alpha + gamma * local_gains.iter().sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::objective::Problem;
    use crate::util::rng::Pcg32;

    fn setup(k: usize) -> (Problem, Vec<LocalBlock>, Partition) {
        let data = generate(&SynthConfig::new("t", 60, 8).seed(3));
        let part = random_balanced(60, k, 7);
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let blocks = LocalBlock::split(&p.data, &part);
        (p, blocks, part)
    }

    #[test]
    fn blocks_cover_dataset() {
        let (p, blocks, part) = setup(4);
        assert!(part.is_exact_cover());
        let total: usize = blocks.iter().map(|b| b.n_local()).sum();
        assert_eq!(total, p.n());
        // local row li of block k holds the caller's row part.parts[k][li]
        for (k, b) in blocks.iter().enumerate() {
            for (li, &gi) in part.parts[k].iter().enumerate() {
                assert_eq!(b.y()[li], p.data.y[gi]);
                assert_eq!(b.x().row(li).1, p.data.x.row(gi).1);
                assert_eq!(b.norms_sq()[li], p.data.row_norms_sq[gi]);
            }
        }
    }

    #[test]
    fn split_shares_one_dataset_copy() {
        // Non-contiguous partition: all K blocks must view the SAME
        // (permuted) dataset — one Arc, no per-worker matrix clones.
        let (_p, blocks, _part) = setup(4);
        for b in &blocks[1..] {
            assert!(
                Arc::ptr_eq(b.shared_data(), blocks[0].shared_data()),
                "sibling blocks must share storage"
            );
        }
        let total_rows: usize = blocks.iter().map(|b| b.n_local()).sum();
        assert_eq!(blocks[0].shared_data().n(), total_rows);
    }

    #[test]
    fn contiguous_split_is_zero_copy() {
        use crate::data::partition::contiguous;
        let data = generate(&SynthConfig::new("t", 40, 6).seed(5));
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let part = contiguous(40, 4);
        let blocks = LocalBlock::split(&p.data, &part);
        for (k, b) in blocks.iter().enumerate() {
            assert!(
                Arc::ptr_eq(b.shared_data(), &p.data),
                "contiguous split must view the caller's dataset directly"
            );
            assert_eq!(b.start(), k * 10);
            assert_eq!(b.n_local(), 10);
            let range: Vec<usize> = (b.start()..b.start() + b.n_local()).collect();
            assert_eq!(range, part.parts[k]);
        }
    }

    #[test]
    fn zero_delta_value_matches_dual_decomposition() {
        // Σ_k G_k^{σ'}(0; w, α) should equal D(α) when σ' arbitrary (the Δ
        // terms vanish and the ‖w‖² term splits as K·(1/K)).
        let (p, blocks, part) = setup(3);
        let n = p.n();
        let mut rng = Pcg32::seeded(9);
        let alpha: Vec<f64> = (0..n).map(|i| p.data.y[i] * rng.next_f64()).collect();
        let mut w = vec![0.0; p.d()];
        p.primal_from_dual(&alpha, &mut w);
        let d_val = p.dual_value(&alpha, &w);

        let spec = SubproblemSpec {
            loss: p.loss,
            lambda: p.lambda,
            n_global: n,
            sigma_prime: 2.0,
            k: part.k(),
        };
        let mut total = 0.0;
        for (k, b) in blocks.iter().enumerate() {
            let alpha_local: Vec<f64> =
                part.parts[k].iter().map(|&gi| alpha[gi]).collect();
            let zeros = vec![0.0; b.n_local()];
            total += subproblem_value(b, &spec, &w, &alpha_local, &zeros);
        }
        assert!((total - d_val).abs() < 1e-9, "{total} vs {d_val}");
    }

    #[test]
    fn lemma3_inequality_holds_for_safe_sigma() {
        // D(α + γ ΣΔ_[k]) ≥ (1−γ)D(α) + γ Σ G_k(Δ_[k]) when σ' = γK.
        let (p, blocks, part) = setup(4);
        let n = p.n();
        let gamma = 1.0;
        let spec = SubproblemSpec {
            loss: p.loss,
            lambda: p.lambda,
            n_global: n,
            sigma_prime: gamma * part.k() as f64,
            k: part.k(),
        };
        let mut rng = Pcg32::seeded(21);
        // start from a feasible dual point
        let alpha: Vec<f64> = (0..n).map(|i| p.data.y[i] * 0.3 * rng.next_f64()).collect();
        let mut w = vec![0.0; p.d()];
        p.primal_from_dual(&alpha, &mut w);
        let d_before = p.dual_value(&alpha, &w);

        // random feasible local deltas
        let mut new_alpha = alpha.clone();
        let mut gains = Vec::new();
        for (k, b) in blocks.iter().enumerate() {
            let alpha_local: Vec<f64> =
                part.parts[k].iter().map(|&gi| alpha[gi]).collect();
            let delta: Vec<f64> = (0..b.n_local())
                .map(|i| {
                    let target = b.y()[i] * rng.next_f64();
                    target - alpha_local[i]
                })
                .collect();
            gains.push(subproblem_value(b, &spec, &w, &alpha_local, &delta));
            for (li, &gi) in part.parts[k].iter().enumerate() {
                new_alpha[gi] += gamma * delta[li];
            }
        }
        let mut w_new = vec![0.0; p.d()];
        p.primal_from_dual(&new_alpha, &mut w_new);
        let d_after = p.dual_value(&new_alpha, &w_new);
        let rhs = lemma3_rhs(d_before, gamma, &gains);
        assert!(
            d_after + 1e-9 >= rhs,
            "Lemma 3 violated: D_after={d_after} rhs={rhs}"
        );
    }

    #[test]
    fn coef_and_vscale_consistent() {
        let spec = SubproblemSpec {
            loss: Loss::Hinge,
            lambda: 0.1,
            n_global: 100,
            sigma_prime: 4.0,
            k: 4,
        };
        // coef(q) = v_scale * q
        assert!((spec.coef(2.5) - spec.v_scale() * 2.5).abs() < 1e-15);
    }

    #[test]
    fn infeasible_delta_is_neg_inf() {
        let (p, blocks, part) = setup(2);
        let spec = SubproblemSpec {
            loss: p.loss,
            lambda: p.lambda,
            n_global: p.n(),
            sigma_prime: 2.0,
            k: part.k(),
        };
        let b = &blocks[0];
        let w = vec![0.0; p.d()];
        let alpha_local = vec![0.0; b.n_local()];
        let mut delta = vec![0.0; b.n_local()];
        delta[0] = -10.0 * b.y()[0]; // pushes yα far below 0
        let v = subproblem_value(b, &spec, &w, &alpha_local, &delta);
        assert_eq!(v, f64::NEG_INFINITY);
    }
}
