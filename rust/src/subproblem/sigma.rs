//! The partition-difficulty constants of §4:
//!
//! * σ_k = ‖A_k‖₂² (Eq. 19) — squared spectral norm of worker k's block,
//! * σ   = Σ_k σ_k·n_k (Eq. 18) — the aggregate entering Theorem 8,
//! * the safe subproblem parameter σ' = γK (Lemma 4), and
//! * Table 1's ratio (n²/K)/σ measuring how pessimistic the worst-case
//!   bound σ ≤ n²/K (Remark 7) is on real partitioned data.

use crate::data::{Dataset, Partition};
use crate::linalg::power_iter::spectral_norm_sq;

/// Per-partition spectral constants.
#[derive(Clone, Debug)]
pub struct PartitionSigma {
    /// σ_k for each worker.
    pub sigma_k: Vec<f64>,
    /// Part sizes n_k.
    pub sizes: Vec<usize>,
    /// σ = Σ_k σ_k n_k.
    pub sigma_sum: f64,
}

impl PartitionSigma {
    /// Largest σ_k (enters Theorem 10).
    pub fn sigma_max(&self) -> f64 {
        self.sigma_k.iter().fold(0.0f64, |m, &v| m.max(v))
    }

    /// Table 1's ratio: (n²/K) / σ. Large values mean the worst-case bound
    /// is very pessimistic and the practical rate much better.
    pub fn table1_ratio(&self, n: usize) -> f64 {
        let k = self.sigma_k.len() as f64;
        (n as f64 * n as f64 / k) / self.sigma_sum
    }
}

/// Compute σ_k for every part of a partition (power iteration per block;
/// cost O(iters·nnz_k) each).
pub fn partition_sigma(data: &Dataset, partition: &Partition, seed: u64) -> PartitionSigma {
    let mut sigma_k = Vec::with_capacity(partition.k());
    let mut sizes = Vec::with_capacity(partition.k());
    for (k, rows) in partition.parts.iter().enumerate() {
        // Power iteration wants an owned matrix; this is off the hot path
        // and the sub-matrix is dropped right after the estimate.
        let block_x = data.x.select_rows(rows);
        let est = spectral_norm_sq(&block_x, 300, 1e-9, seed.wrapping_add(k as u64));
        sigma_k.push(est.sigma);
        sizes.push(rows.len());
    }
    let sigma_sum = sigma_k
        .iter()
        .zip(&sizes)
        .map(|(&s, &nk)| s * nk as f64)
        .sum();
    PartitionSigma {
        sigma_k,
        sizes,
        sigma_sum,
    }
}

/// The safe σ' of Lemma 4: σ' := γK always satisfies Eq. (11).
#[inline]
pub fn safe_sigma_prime(gamma: f64, k: usize) -> f64 {
    gamma * k as f64
}

/// Empirical lower estimate of σ'_min (Eq. 11):
///
///   σ'_min = γ · max_α ‖Aα‖² / Σ_k ‖Aα_[k]‖²
///
/// maximized by random + power-iteration-refined probes. The true maximum
/// is a hard problem; this provides the *data-adaptive* σ' the paper's
/// Appendix C discussion points to ("using additional knowledge from the
/// input data, better bounds and therefore better step-sizes can be
/// achieved"). The returned value is a valid lower bound on σ'_min, so
/// using `max(estimate, 1)·safety` as σ' is aggressive-but-informed;
/// γK remains the only provably safe choice.
pub fn estimate_sigma_prime_min(
    data: &Dataset,
    partition: &Partition,
    gamma: f64,
    probes: usize,
    seed: u64,
) -> f64 {
    use crate::linalg::dense;
    use crate::util::rng::Pcg32;
    let n = data.n();
    let d = data.d();
    let owner = partition.owner_of();
    let k = partition.k();
    let mut rng = Pcg32::new(seed, 31);
    let mut best = 0.0f64;
    let mut alpha = vec![0.0; n];
    for p in 0..probes.max(1) {
        // Probe: random Gaussian α, then a few power-like refinements via
        // αᵀ(AᵀA) to push mass toward the top singular directions.
        for a in alpha.iter_mut() {
            *a = rng.gaussian();
        }
        let refine = p % 2; // alternate raw and refined probes
        let mut full = vec![0.0; d];
        for _ in 0..refine {
            data.x.matvec_t(&alpha, &mut full);
            data.x.matvec(&full, &mut alpha);
            let nrm = dense::norm(&alpha);
            if nrm > 0.0 {
                dense::scale(1.0 / nrm, &mut alpha);
            }
        }
        data.x.matvec_t(&alpha, &mut full);
        let num = dense::norm_sq(&full);
        // Σ_k ‖Aα_[k]‖²
        let mut per_k = vec![vec![0.0; d]; k];
        for i in 0..n {
            data.x.row_axpy(i, alpha[i], &mut per_k[owner[i]]);
        }
        let den: f64 = per_k.iter().map(|v| dense::norm_sq(v)).sum();
        if den > 0.0 {
            best = best.max(num / den);
        }
    }
    gamma * best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn remark7_bounds_hold() {
        // With normalized rows: σ_k ≤ n_k, hence σ ≤ Σ n_k² = n²/K for
        // a balanced partition, so the Table 1 ratio is ≥ 1.
        let data = generate(&SynthConfig::new("t", 120, 10).seed(5));
        let part = random_balanced(120, 4, 3);
        let ps = partition_sigma(&data, &part, 1);
        for (k, (&s, &nk)) in ps.sigma_k.iter().zip(&ps.sizes).enumerate() {
            assert!(s <= nk as f64 + 1e-6, "σ_{k} = {s} > n_k = {nk}");
            assert!(s >= 1.0 - 1e-6, "σ_{k} = {s} below unit-row floor");
        }
        assert!(ps.table1_ratio(120) >= 1.0 - 1e-9);
    }

    #[test]
    fn ratio_decreases_with_k_on_random_data() {
        // Table 1's qualitative trend: the upper bound gets tighter (ratio
        // shrinks) as K grows, because blocks get closer to single rows
        // where σ_k = n_k exactly.
        let data = generate(&SynthConfig::new("t", 256, 32).density(0.3).seed(9));
        let r4 = partition_sigma(&data, &random_balanced(256, 4, 1), 2).table1_ratio(256);
        let r64 = partition_sigma(&data, &random_balanced(256, 64, 1), 2).table1_ratio(256);
        assert!(
            r64 <= r4 + 0.25,
            "ratio should not grow materially with K: K=4 → {r4}, K=64 → {r64}"
        );
    }

    #[test]
    fn safe_sigma_prime_values() {
        assert_eq!(safe_sigma_prime(1.0, 8), 8.0);
        assert_eq!(safe_sigma_prime(1.0 / 8.0, 8), 1.0);
    }

    #[test]
    fn estimated_sigma_prime_min_below_safe_bound() {
        // Lemma 4: σ'_min ≤ γK, so any lower estimate must be too.
        let data = generate(&SynthConfig::new("t", 160, 12).density(0.5).seed(7));
        for k in [2usize, 4, 8] {
            let part = random_balanced(160, k, 3);
            for gamma in [1.0, 1.0 / k as f64] {
                let est = estimate_sigma_prime_min(&data, &part, gamma, 20, 9);
                let safe = safe_sigma_prime(gamma, k);
                assert!(
                    est <= safe + 1e-9,
                    "estimate {est} exceeds safe bound {safe} (K={k}, γ={gamma})"
                );
                assert!(est > 0.0, "estimate must be positive");
            }
        }
    }

    #[test]
    fn estimated_sigma_prime_min_at_least_gamma() {
        // ‖Aα‖² = ‖ΣAα_[k]‖² equals Σ‖Aα_[k]‖² for α supported on one
        // part, so the ratio is ≥ 1 and σ'_min ≥ γ. The estimator should
        // find at least that much.
        let data = generate(&SynthConfig::new("t", 120, 10).seed(5));
        let part = random_balanced(120, 4, 1);
        let est = estimate_sigma_prime_min(&data, &part, 1.0, 30, 2);
        assert!(est >= 0.9, "estimate {est} below the trivial γ floor");
    }

    #[test]
    fn sigma_max_is_max() {
        let data = generate(&SynthConfig::new("t", 60, 8).seed(2));
        let part = random_balanced(60, 3, 4);
        let ps = partition_sigma(&data, &part, 0);
        let m = ps.sigma_max();
        assert!(ps.sigma_k.iter().all(|&s| s <= m));
    }
}
