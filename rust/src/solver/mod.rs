//! Local solvers for the CoCoA+ subproblem (§5, Assumption 1).
//!
//! The framework is solver-agnostic: anything that improves `G_k^{σ'}` by a
//! Θ-fraction of the optimal improvement (Eq. 12) gives the paper's rates.
//! We ship three, behind one trait:
//!
//! * [`sdca::SdcaSolver`] — LOCALSDCA (Algorithm 2): uniformly random
//!   single-coordinate exact maximization, the paper's experimental choice;
//! * [`cyclic_cd::CyclicCdSolver`] — deterministic sweep variant;
//! * [`jacobi::JacobiSolver`] — damped synchronous (batch) coordinate
//!   updates, demonstrating the "arbitrary local solver" claim with a
//!   qualitatively different (mini-batch-CD-like) method.
//!
//! `theta.rs` empirically estimates a solver's Θ on a given block.

pub mod cyclic_cd;
pub mod jacobi;
pub mod sdca;
pub mod theta;

use crate::subproblem::{LocalBlock, SubproblemSpec};

/// Everything a local solver may read for one outer round.
pub struct LocalSolveCtx<'a> {
    pub block: &'a LocalBlock,
    pub spec: &'a SubproblemSpec,
    /// Shared primal vector w = w(α) at the start of the round.
    pub w: &'a [f64],
    /// Current local dual variables α_[k] (local indexing).
    pub alpha_local: &'a [f64],
}

/// The update a local solver returns. In the persistent-pool runtime this
/// struct doubles as a reusable scratch buffer: the coordinator allocates
/// it once per worker at startup and solvers overwrite it in place every
/// round via [`LocalSolver::solve_into`].
#[derive(Clone, Debug, Default)]
pub struct LocalUpdate {
    /// Δα_[k] in local indexing (length n_k).
    pub delta_alpha: Vec<f64>,
    /// Δw_k = A Δα_[k]/(λn) (length d) — what gets communicated.
    pub delta_w: Vec<f64>,
    /// Number of coordinate updates (or equivalent work units) performed.
    pub steps: usize,
}

impl LocalUpdate {
    /// A zeroed update sized for an (n_k, d) block.
    pub fn with_dims(n_local: usize, d: usize) -> LocalUpdate {
        LocalUpdate {
            delta_alpha: vec![0.0; n_local],
            delta_w: vec![0.0; d],
            steps: 0,
        }
    }

    /// Zero the buffers and (re)size them for an (n_k, d) block. After the
    /// first round this never reallocates — the basis of the pool's
    /// allocation-free steady state.
    pub fn reset(&mut self, n_local: usize, d: usize) {
        self.delta_alpha.clear();
        self.delta_alpha.resize(n_local, 0.0);
        self.delta_w.clear();
        self.delta_w.resize(d, 0.0);
        self.steps = 0;
    }
}

/// A Θ-approximate local solver (Assumption 1).
pub trait LocalSolver: Send {
    fn name(&self) -> String;

    /// Produce an approximate maximizer of G_k^{σ'}(·; w, α_[k]), writing
    /// Δα and Δw into `out` (implementations call [`LocalUpdate::reset`]
    /// first, so `out` may hold a previous round's values). Steady-state
    /// implementations must not allocate: the worker-pool runtime hands
    /// the same `out` back every round.
    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate);

    /// Allocating convenience wrapper around [`LocalSolver::solve_into`].
    fn solve(&mut self, ctx: &LocalSolveCtx) -> LocalUpdate {
        let mut out = LocalUpdate::with_dims(ctx.block.n_local(), ctx.block.d());
        self.solve_into(ctx, &mut out);
        out
    }

    /// Re-seed the solver's RNG stream (for reproducible multi-round runs
    /// the coordinator calls this with (round, worker) derived seeds).
    fn reseed(&mut self, seed: u64) {
        let _ = seed;
    }
}

/// Shared helper: maintain the local primal image
/// `v = w + (σ'/(λn))·A Δα` and derive `Δw = (v − w)/σ'` at the end.
/// All three solvers use this identity instead of accumulating Δw
/// separately — one O(d) pass at the end instead of O(nnz) per step.
/// Writes into the caller's reusable buffer.
pub(crate) fn delta_w_from_v_into(w: &[f64], v: &[f64], sigma_prime: f64, out: &mut Vec<f64>) {
    debug_assert!(sigma_prime > 0.0);
    out.clear();
    out.extend(
        w.iter()
            .zip(v.iter())
            .map(|(&wi, &vi)| (vi - wi) / sigma_prime),
    );
}

/// Allocating form of [`delta_w_from_v_into`] (tests and one-shot callers).
#[cfg(test)]
pub(crate) fn delta_w_from_v(w: &[f64], v: &[f64], sigma_prime: f64) -> Vec<f64> {
    let mut out = Vec::new();
    delta_w_from_v_into(w, v, sigma_prime, &mut out);
    out
}

#[cfg(test)]
pub(crate) mod test_fixtures {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::{Dataset, Partition};
    use crate::loss::Loss;
    use std::sync::Arc;

    pub fn fixture(
        n: usize,
        d: usize,
        k: usize,
        loss: Loss,
        lambda: f64,
    ) -> (Arc<Dataset>, Partition, Vec<LocalBlock>, SubproblemSpec) {
        let data = Arc::new(generate(&SynthConfig::new("fix", n, d).seed(13)));
        let part = random_balanced(n, k, 29);
        let blocks = LocalBlock::split(&data, &part);
        let spec = SubproblemSpec {
            loss,
            lambda,
            n_global: n,
            sigma_prime: k as f64,
            k,
        };
        (data, part, blocks, spec)
    }

    /// Assert the solver (a) returns consistent Δw, (b) improves G_k, and
    /// (c) stays dual-feasible.
    pub fn check_solver_contract(solver: &mut dyn LocalSolver, loss: Loss) {
        use crate::subproblem::subproblem_value;
        let (_data, _part, blocks, spec) = fixture(48, 6, 3, loss, 0.05);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha_local = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha_local,
        };
        let out = solver.solve(&ctx);
        assert_eq!(out.delta_alpha.len(), block.n_local());
        assert_eq!(out.delta_w.len(), block.d());

        // (a) Δw = A Δα/(λn)
        let mut a_delta = vec![0.0; block.d()];
        block.x().matvec_t(&out.delta_alpha, &mut a_delta);
        for j in 0..block.d() {
            let expect = a_delta[j] / (spec.lambda * spec.n_global as f64);
            assert!(
                (out.delta_w[j] - expect).abs() < 1e-9,
                "Δw mismatch at {j}: {} vs {}",
                out.delta_w[j],
                expect
            );
        }

        // (b) G_k(Δ) ≥ G_k(0)
        let g0 = subproblem_value(block, &spec, &w, &alpha_local, &vec![0.0; block.n_local()]);
        let g = subproblem_value(block, &spec, &w, &alpha_local, &out.delta_alpha);
        assert!(
            g >= g0 - 1e-9,
            "{}: solver decreased subproblem: {g} < {g0}",
            solver.name()
        );

        // (c) feasibility
        let y = block.y();
        for (i, &d) in out.delta_alpha.iter().enumerate() {
            assert!(
                loss.conjugate_neg(alpha_local[i] + d, y[i]).is_finite(),
                "infeasible coordinate {i}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_w_identity() {
        let w = vec![1.0, 2.0];
        let v = vec![1.5, 3.0];
        let dw = delta_w_from_v(&w, &v, 2.0);
        assert_eq!(dw, vec![0.25, 0.5]);
    }

    #[test]
    fn reset_zeroes_and_resizes_without_growth() {
        let mut u = LocalUpdate::with_dims(4, 2);
        u.delta_alpha[1] = 3.0;
        u.delta_w[0] = -1.0;
        u.steps = 9;
        let cap_a = u.delta_alpha.capacity();
        u.reset(4, 2);
        assert_eq!(u.delta_alpha, vec![0.0; 4]);
        assert_eq!(u.delta_w, vec![0.0; 2]);
        assert_eq!(u.steps, 0);
        assert_eq!(u.delta_alpha.capacity(), cap_a, "reset must not reallocate");
    }
}
