//! LOCALSDCA (Algorithm 2 of the paper): randomized dual coordinate ascent
//! on the local subproblem G_k^{σ'}.
//!
//! Per inner step h: draw i ∈ P_k uniformly, solve the 1-D problem
//!   δ* = argmax_δ G_k^{σ'}(Δα + δ e_i)
//! in closed form (loss-specific, see `loss::*::coordinate_delta`), and
//! update the local primal image v ← v + (σ'/(λn)) δ x_i. Theorems 13/14
//! bound the number of inner steps H needed for a target Θ.
//!
//! The hot loop is two sparse kernels per step (`row_dot`, `row_axpy`) and
//! is completely allocation-free after setup.

use crate::solver::{delta_w_from_v_into, LocalSolveCtx, LocalSolver, LocalUpdate};
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct SdcaSolver {
    /// Number of inner coordinate steps per outer round. The paper sweeps
    /// H ∈ {1e4, 1e5, 1e6}; a common default is a multiple of n_k.
    pub h: usize,
    rng: Pcg32,
    /// Scratch: local primal image v (reused across rounds).
    v: Vec<f64>,
    /// Scratch: per-round index sequence (reused across rounds).
    indices: Vec<usize>,
}

impl SdcaSolver {
    pub fn new(h: usize, seed: u64) -> SdcaSolver {
        SdcaSolver {
            h,
            rng: Pcg32::new(seed, 101),
            v: Vec::new(),
            indices: Vec::new(),
        }
    }

    /// H as a multiple of the local datapoint count ("epochs").
    pub fn with_epochs(epochs: f64, n_local: usize, seed: u64) -> SdcaSolver {
        let h = ((n_local as f64 * epochs).round() as usize).max(1);
        SdcaSolver::new(h, seed)
    }

    /// Run the inner loop with an externally supplied coordinate sequence
    /// (used by the XLA-equivalence tests: the Rust and AOT solvers consume
    /// the same index stream and must produce identical trajectories).
    pub fn solve_with_indices(&mut self, ctx: &LocalSolveCtx, indices: &[usize]) -> LocalUpdate {
        let mut out = LocalUpdate::with_dims(ctx.block.n_local(), ctx.block.d());
        self.solve_with_indices_into(ctx, indices, &mut out);
        out
    }

    /// Scratch-reusing core of the solver: write Δα/Δw for the given index
    /// stream into `out` without allocating (after the first round).
    pub fn solve_with_indices_into(
        &mut self,
        ctx: &LocalSolveCtx,
        indices: &[usize],
        out: &mut LocalUpdate,
    ) {
        let block = ctx.block;
        let spec = ctx.spec;
        let nk = block.n_local();
        assert!(nk > 0, "empty local block");
        out.reset(nk, block.d());
        let x = block.x();
        let y = block.y();
        let norms = block.norms_sq();

        // v = w (then updated in place); delta starts at 0.
        self.v.clear();
        self.v.extend_from_slice(ctx.w);
        let v = &mut self.v;
        let delta = &mut out.delta_alpha;
        let v_scale = spec.v_scale();

        for &i in indices {
            let q = norms[i];
            if q == 0.0 {
                continue; // empty row cannot move the objective
            }
            let xv = x.row_dot(i, v);
            let coef = spec.coef(q);
            let d = spec
                .loss
                .coordinate_delta(ctx.alpha_local[i] + delta[i], y[i], xv, coef);
            if d != 0.0 {
                delta[i] += d;
                x.row_axpy(i, v_scale * d, v);
            }
        }

        delta_w_from_v_into(ctx.w, v, spec.sigma_prime, &mut out.delta_w);
        out.steps = indices.len();
    }
}

impl LocalSolver for SdcaSolver {
    fn name(&self) -> String {
        format!("sdca(H={})", self.h)
    }

    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        let nk = ctx.block.n_local();
        // Draw the index sequence first (borrow discipline: rng vs &mut
        // self), into the reused scratch buffer.
        let mut indices = std::mem::take(&mut self.indices);
        indices.clear();
        indices.reserve(self.h);
        for _ in 0..self.h {
            indices.push(self.rng.gen_range(nk));
        }
        self.solve_with_indices_into(ctx, &indices, out);
        self.indices = indices; // return scratch for the next round
    }

    fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 101);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Loss;
    use crate::solver::test_fixtures::{check_solver_contract, fixture};
    use crate::subproblem::subproblem_value;

    #[test]
    fn contract_all_losses() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
        ] {
            let mut s = SdcaSolver::new(200, 5);
            check_solver_contract(&mut s, loss);
        }
    }

    #[test]
    fn more_inner_steps_more_gain() {
        let (_d, _p, blocks, spec) = fixture(60, 8, 2, Loss::Hinge, 0.02);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let gain = |h: usize| {
            let mut s = SdcaSolver::new(h, 7);
            let out = s.solve(&ctx);
            subproblem_value(block, &spec, &w, &alpha, &out.delta_alpha)
        };
        let g_small = gain(10);
        let g_big = gain(2000);
        assert!(
            g_big >= g_small - 1e-12,
            "H=2000 ({g_big}) should beat H=10 ({g_small})"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (_d, _p, blocks, spec) = fixture(40, 6, 2, Loss::Hinge, 0.05);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let mut s1 = SdcaSolver::new(100, 9);
        let mut s2 = SdcaSolver::new(100, 9);
        assert_eq!(s1.solve(&ctx).delta_alpha, s2.solve(&ctx).delta_alpha);
        let mut s3 = SdcaSolver::new(100, 10);
        assert_ne!(s1.reseed_then_solve(&ctx, 9), s3.solve(&ctx).delta_alpha);
    }

    impl SdcaSolver {
        fn reseed_then_solve(&mut self, ctx: &LocalSolveCtx, seed: u64) -> Vec<f64> {
            self.reseed(seed);
            self.solve(ctx).delta_alpha
        }
    }

    #[test]
    fn epochs_constructor() {
        let s = SdcaSolver::with_epochs(2.5, 40, 0);
        assert_eq!(s.h, 100);
        let s1 = SdcaSolver::with_epochs(0.0001, 40, 0);
        assert_eq!(s1.h, 1);
    }

    #[test]
    fn index_injection_reproduces_solve() {
        let (_d, _p, blocks, spec) = fixture(30, 5, 2, Loss::Hinge, 0.05);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        // Manually draw the same indices the solver would draw.
        let mut rng = Pcg32::new(3, 101);
        let idx: Vec<usize> = (0..50).map(|_| rng.gen_range(block.n_local())).collect();
        let mut s_auto = SdcaSolver::new(50, 3);
        let auto = s_auto.solve(&ctx);
        let mut s_inj = SdcaSolver::new(50, 999);
        let inj = s_inj.solve_with_indices(&ctx, &idx);
        assert_eq!(auto.delta_alpha, inj.delta_alpha);
    }
}
