//! Damped Jacobi (synchronous batch) coordinate solver.
//!
//! Computes *all* 1-D coordinate maximizers from the same frozen local
//! primal image, then applies them scaled by a damping factor β ∈ (0, 1].
//! With β = 1/n_k this is exactly the conservative mini-batch-CD update the
//! paper contrasts against; with β closer to 1 it is an aggressive but
//! possibly non-monotone solver. It exists to demonstrate the framework's
//! "arbitrary local solver" claim with a method that is structurally
//! different from sequential SDCA (and parallelizes trivially).
//!
//! To keep Assumption 1 satisfied for any β, the update is safeguarded: if
//! a candidate step does not improve G_k^{σ'}, β is halved (up to a few
//! times) before giving up and returning the best found.

use crate::solver::{delta_w_from_v_into, LocalSolveCtx, LocalSolver, LocalUpdate};
use crate::subproblem::subproblem_value;

#[derive(Clone, Debug)]
pub struct JacobiSolver {
    /// Number of synchronous sweeps.
    pub sweeps: usize,
    /// Initial damping β.
    pub beta: f64,
    /// Scratch (reused across rounds): local primal image, candidate
    /// coordinate moves, and the damped trial point.
    v: Vec<f64>,
    cand: Vec<f64>,
    trial: Vec<f64>,
}

impl JacobiSolver {
    pub fn new(sweeps: usize, beta: f64) -> JacobiSolver {
        assert!(beta > 0.0 && beta <= 1.0, "β must be in (0,1]");
        JacobiSolver {
            sweeps: sweeps.max(1),
            beta,
            v: Vec::new(),
            cand: Vec::new(),
            trial: Vec::new(),
        }
    }
}

impl LocalSolver for JacobiSolver {
    fn name(&self) -> String {
        format!("jacobi(sweeps={},beta={})", self.sweeps, self.beta)
    }

    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        let block = ctx.block;
        let spec = ctx.spec;
        let nk = block.n_local();
        assert!(nk > 0, "empty local block");
        out.reset(nk, block.d());
        let x = block.x();
        let y = block.y();
        let norms = block.norms_sq();
        let v_scale = spec.v_scale();

        let delta = &mut out.delta_alpha;
        self.v.clear();
        self.v.extend_from_slice(ctx.w);
        self.cand.clear();
        self.cand.resize(nk, 0.0);
        self.trial.clear();
        self.trial.resize(nk, 0.0);
        let mut g_cur = subproblem_value(block, spec, ctx.w, ctx.alpha_local, delta);
        let mut steps = 0usize;

        for _ in 0..self.sweeps {
            // Candidate coordinate moves from the frozen image v.
            for i in 0..nk {
                let q = norms[i];
                self.cand[i] = if q == 0.0 {
                    0.0
                } else {
                    let xv = x.row_dot(i, &self.v);
                    spec.loss.coordinate_delta(
                        ctx.alpha_local[i] + delta[i],
                        y[i],
                        xv,
                        spec.coef(q),
                    )
                };
                steps += 1;
            }
            // Damped apply with backtracking safeguard.
            let mut beta = self.beta;
            let mut applied = false;
            for _try in 0..6 {
                for i in 0..nk {
                    self.trial[i] = delta[i] + beta * self.cand[i];
                }
                let g_trial = subproblem_value(block, spec, ctx.w, ctx.alpha_local, &self.trial);
                if g_trial >= g_cur {
                    // Rebuild v for the accepted point.
                    for i in 0..nk {
                        let step = self.trial[i] - delta[i];
                        if step != 0.0 {
                            x.row_axpy(i, v_scale * step, &mut self.v);
                        }
                    }
                    delta.copy_from_slice(&self.trial);
                    g_cur = g_trial;
                    applied = true;
                    break;
                }
                beta *= 0.5;
            }
            if !applied {
                break; // converged (no damping level improves)
            }
        }

        delta_w_from_v_into(ctx.w, &self.v, spec.sigma_prime, &mut out.delta_w);
        out.steps = steps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Loss;
    use crate::solver::test_fixtures::{check_solver_contract, fixture};

    #[test]
    fn contract_all_losses() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
        ] {
            let mut s = JacobiSolver::new(4, 0.5);
            check_solver_contract(&mut s, loss);
        }
    }

    #[test]
    fn aggressive_beta_is_safeguarded() {
        // β=1 synchronous steps can overshoot; the safeguard must keep the
        // subproblem value monotone.
        let mut s = JacobiSolver::new(8, 1.0);
        check_solver_contract(&mut s, Loss::Hinge);
    }

    #[test]
    fn more_sweeps_not_worse() {
        use crate::solver::LocalSolveCtx;
        use crate::subproblem::subproblem_value;
        let (_d, _p, blocks, spec) = fixture(50, 7, 2, Loss::SmoothedHinge { mu: 0.5 }, 0.05);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let g = |sweeps| {
            let out = JacobiSolver::new(sweeps, 0.5).solve(&ctx);
            subproblem_value(block, &spec, &w, &alpha, &out.delta_alpha)
        };
        assert!(g(10) >= g(1) - 1e-12);
    }

    #[test]
    #[should_panic]
    fn invalid_beta_panics() {
        JacobiSolver::new(1, 0.0);
    }
}
