//! Cyclic coordinate descent local solver: like LOCALSDCA but sweeps the
//! local coordinates in a (reshuffled-per-epoch) fixed order instead of
//! sampling with replacement. A second "arbitrary local solver" satisfying
//! Assumption 1 — often slightly faster per epoch in practice.

use crate::solver::{delta_w_from_v_into, LocalSolveCtx, LocalSolver, LocalUpdate};
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct CyclicCdSolver {
    /// Number of full sweeps over the local data per round.
    pub epochs: usize,
    /// Reshuffle the visit order before each sweep.
    pub shuffle: bool,
    rng: Pcg32,
    v: Vec<f64>,
    order: Vec<usize>,
}

impl CyclicCdSolver {
    pub fn new(epochs: usize, shuffle: bool, seed: u64) -> CyclicCdSolver {
        CyclicCdSolver {
            epochs: epochs.max(1),
            shuffle,
            rng: Pcg32::new(seed, 211),
            v: Vec::new(),
            order: Vec::new(),
        }
    }
}

impl LocalSolver for CyclicCdSolver {
    fn name(&self) -> String {
        format!(
            "cyclic_cd(epochs={}{})",
            self.epochs,
            if self.shuffle { ",shuffled" } else { "" }
        )
    }

    fn solve_into(&mut self, ctx: &LocalSolveCtx, out: &mut LocalUpdate) {
        let block = ctx.block;
        let spec = ctx.spec;
        let nk = block.n_local();
        assert!(nk > 0, "empty local block");
        out.reset(nk, block.d());
        let x = block.x();
        let y = block.y();
        let norms = block.norms_sq();

        self.v.clear();
        self.v.extend_from_slice(ctx.w);
        if self.order.len() != nk {
            self.order = (0..nk).collect();
        }
        let delta = &mut out.delta_alpha;
        let v_scale = spec.v_scale();
        let mut steps = 0usize;

        for _ in 0..self.epochs {
            if self.shuffle {
                self.rng.shuffle(&mut self.order);
            }
            for &i in &self.order {
                let q = norms[i];
                if q == 0.0 {
                    continue;
                }
                let xv = x.row_dot(i, &self.v);
                let coef = spec.coef(q);
                let d = spec.loss.coordinate_delta(
                    ctx.alpha_local[i] + delta[i],
                    y[i],
                    xv,
                    coef,
                );
                if d != 0.0 {
                    delta[i] += d;
                    x.row_axpy(i, v_scale * d, &mut self.v);
                }
                steps += 1;
            }
        }

        delta_w_from_v_into(ctx.w, &self.v, spec.sigma_prime, &mut out.delta_w);
        out.steps = steps;
    }

    fn reseed(&mut self, seed: u64) {
        self.rng = Pcg32::new(seed, 211);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Loss;
    use crate::solver::test_fixtures::check_solver_contract;

    #[test]
    fn contract_all_losses() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
        ] {
            let mut s = CyclicCdSolver::new(3, true, 5);
            check_solver_contract(&mut s, loss);
        }
    }

    #[test]
    fn unshuffled_is_deterministic_across_instances() {
        use crate::solver::test_fixtures::fixture;
        let (_d, _p, blocks, spec) = fixture(40, 6, 2, Loss::Hinge, 0.05);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let a = CyclicCdSolver::new(2, false, 1).solve(&ctx).delta_alpha;
        let b = CyclicCdSolver::new(2, false, 99).solve(&ctx).delta_alpha;
        assert_eq!(a, b, "seed must not matter when shuffle=false");
    }
}
