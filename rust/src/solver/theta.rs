//! Empirical estimation of a local solver's approximation quality Θ
//! (Assumption 1, Eq. 12):
//!
//!   Θ ≈ [G(Δα*) − G(Δα)] / [G(Δα*) − G(0)]
//!
//! where Δα* is approximated by a long reference SDCA run. Used by the
//! rate-checking experiment (`experiments/rates.rs`) to plug measured Θ
//! into Theorems 8/10 and compare predicted vs observed round counts.

use crate::solver::sdca::SdcaSolver;
use crate::solver::{LocalSolveCtx, LocalSolver};
use crate::subproblem::subproblem_value;

/// Result of a Θ estimate on one block/state.
#[derive(Clone, Copy, Debug)]
pub struct ThetaEstimate {
    pub theta: f64,
    /// G_k(0) — the baseline value.
    pub g_zero: f64,
    /// G_k at the solver's output.
    pub g_solver: f64,
    /// G_k at the (approximate) optimum.
    pub g_star: f64,
}

/// Estimate Θ for `solver` on the given round state. `ref_epochs` controls
/// how long the reference SDCA runs to approximate Δα*.
pub fn estimate_theta(
    solver: &mut dyn LocalSolver,
    ctx: &LocalSolveCtx,
    ref_epochs: usize,
    seed: u64,
) -> ThetaEstimate {
    let nk = ctx.block.n_local();
    let zeros = vec![0.0; nk];
    let g_zero = subproblem_value(ctx.block, ctx.spec, ctx.w, ctx.alpha_local, &zeros);

    let out = solver.solve(ctx);
    let g_solver = subproblem_value(ctx.block, ctx.spec, ctx.w, ctx.alpha_local, &out.delta_alpha);

    let mut reference = SdcaSolver::new(nk * ref_epochs.max(1), seed);
    let ref_out = reference.solve(ctx);
    let g_star = subproblem_value(
        ctx.block,
        ctx.spec,
        ctx.w,
        ctx.alpha_local,
        &ref_out.delta_alpha,
    )
    .max(g_solver); // Δα* is at least as good as anything we saw

    let denom = g_star - g_zero;
    let theta = if denom <= 1e-15 {
        0.0 // subproblem already optimal: any solver is Θ=0
    } else {
        ((g_star - g_solver) / denom).clamp(0.0, 1.0)
    };
    ThetaEstimate {
        theta,
        g_zero,
        g_solver,
        g_star,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::Loss;
    use crate::solver::test_fixtures::fixture;

    #[test]
    fn theta_decreases_with_inner_work() {
        let (_d, _p, blocks, spec) = fixture(60, 8, 2, Loss::Hinge, 0.02);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha = vec![0.0; block.n_local()];
        let ctx = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha,
        };
        let theta_of = |h: usize| {
            let mut s = SdcaSolver::new(h, 11);
            estimate_theta(&mut s, &ctx, 60, 12).theta
        };
        let weak = theta_of(5);
        let strong = theta_of(3000);
        assert!(
            strong <= weak + 1e-9,
            "H=3000 Θ={strong} should be ≤ H=5 Θ={weak}"
        );
        assert!(strong < 0.2, "long run should be near-exact, Θ={strong}");
        assert!((0.0..=1.0).contains(&weak));
    }

    #[test]
    fn theta_zero_when_already_optimal() {
        // Start from a state where the subproblem optimum is ~0 gain:
        // run a long solve first, then re-estimate from that point.
        let (_d, _p, blocks, spec) = fixture(40, 6, 2, Loss::Squared, 0.1);
        let block = &blocks[0];
        let w = vec![0.0; block.d()];
        let alpha0 = vec![0.0; block.n_local()];
        let ctx0 = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha0,
        };
        let mut long = SdcaSolver::new(block.n_local() * 200, 1);
        let out = long.solve(&ctx0);
        let alpha1: Vec<f64> = alpha0
            .iter()
            .zip(&out.delta_alpha)
            .map(|(a, d)| a + d)
            .collect();
        // NOTE: w is *not* updated here — we only care that from (w, α₁) the
        // remaining subproblem gain is tiny relative to denominators.
        let ctx1 = LocalSolveCtx {
            block,
            spec: &spec,
            w: &w,
            alpha_local: &alpha1,
        };
        let mut s = SdcaSolver::new(block.n_local() * 50, 2);
        let est = estimate_theta(&mut s, &ctx1, 100, 3);
        assert!(est.theta < 0.5, "near-converged state should give small Θ");
    }
}
