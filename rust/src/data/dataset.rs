//! Labeled dataset container used everywhere in the library.

use crate::linalg::CsrMatrix;

/// A binary-classification / regression dataset: CSR feature rows plus one
/// label per row. For classification, labels are ±1 (paper: binary hinge
/// SVM); for regression (square loss) labels are real-valued.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: CsrMatrix,
    pub y: Vec<f64>,
    /// Precomputed ‖x_i‖² (the SDCA step denominator).
    pub row_norms_sq: Vec<f64>,
    /// Human-readable name (used by reports).
    pub name: String,
}

impl Dataset {
    pub fn new(name: &str, x: CsrMatrix, y: Vec<f64>) -> Dataset {
        assert_eq!(x.rows, y.len(), "rows ({}) != labels ({})", x.rows, y.len());
        let row_norms_sq = x.row_norms_sq();
        Dataset {
            x,
            y,
            row_norms_sq,
            name: name.to_string(),
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    pub fn density(&self) -> f64 {
        self.x.density()
    }

    /// Normalize all rows to unit L2 norm (the paper's ‖x_i‖ ≤ 1 setup) and
    /// refresh the cached norms.
    pub fn normalize_rows(&mut self) {
        self.x.normalize_rows();
        self.row_norms_sq = self.x.row_norms_sq();
    }

    /// Gather the given rows into a new dataset (order preserved; rows may
    /// repeat or be a full permutation). The cached row norms are gathered
    /// rather than recomputed, so a gathered dataset is bitwise consistent
    /// with the source — the property the permuted-contiguous shard layout
    /// relies on (see [`crate::data::Partition::apply_permutation`]).
    pub fn gather_rows(&self, rows: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(rows),
            y: rows.iter().map(|&r| self.y[r]).collect(),
            row_norms_sq: rows.iter().map(|&r| self.row_norms_sq[r]).collect(),
            name: self.name.clone(),
        }
    }

    /// Restrict to a subset of rows (order preserved).
    pub fn select(&self, rows: &[usize]) -> Dataset {
        self.gather_rows(rows)
    }

    /// Consuming variant of [`Dataset::gather_rows`] for full row
    /// permutations: bit-identical output (cached norms are gathered, not
    /// recomputed), but storage is replaced array by array so peak memory
    /// stays near one dataset instead of two. Used by
    /// [`crate::data::Partition::apply_permutation`] when it holds the
    /// only reference to the dataset (the ingest path).
    pub fn permute_rows(self, new_to_old: &[usize]) -> Dataset {
        assert_eq!(new_to_old.len(), self.n(), "permutation must cover all rows");
        let y = new_to_old.iter().map(|&r| self.y[r]).collect();
        let row_norms_sq = new_to_old.iter().map(|&r| self.row_norms_sq[r]).collect();
        Dataset {
            x: self.x.permute_rows(new_to_old),
            y,
            row_norms_sq,
            name: self.name,
        }
    }

    /// Max ‖x_i‖² over the dataset (the paper's r_max).
    pub fn r_max(&self) -> f64 {
        self.row_norms_sq.iter().fold(0.0f64, |m, &v| m.max(v))
    }

    /// Fraction of positive labels (classification sanity checks).
    pub fn positive_fraction(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.y.len() as f64
    }

    /// 0/1 error of a linear classifier w on this dataset. The decision
    /// boundary is [`crate::loss::misclassified`] — the same rule the
    /// serving path's [`crate::loss::classify`] resolves, so trained
    /// train-error and served labels can never drift apart.
    pub fn classification_error(&self, w: &[f64]) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        let mut wrong = 0usize;
        for i in 0..self.n() {
            if crate::loss::misclassified(self.x.row_dot(i, w), self.y[i]) {
                wrong += 1;
            }
        }
        wrong as f64 / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = CsrMatrix::from_dense(4, 2, &[1.0, 0.0, 0.0, 1.0, -1.0, 0.0, 0.0, -1.0]);
        Dataset::new("tiny", x, vec![1.0, 1.0, -1.0, -1.0])
    }

    #[test]
    fn basic_stats() {
        let d = tiny();
        assert_eq!(d.n(), 4);
        assert_eq!(d.d(), 2);
        assert_eq!(d.r_max(), 1.0);
        assert_eq!(d.positive_fraction(), 0.5);
    }

    #[test]
    fn classification_error_perfect_and_flipped() {
        let d = tiny();
        // w = (1,1) separates this data perfectly.
        assert_eq!(d.classification_error(&[1.0, 1.0]), 0.0);
        assert_eq!(d.classification_error(&[-1.0, -1.0]), 1.0);
        // zero margin counts as error
        assert_eq!(d.classification_error(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn select_preserves_labels() {
        let d = tiny();
        let s = d.select(&[2, 0]);
        assert_eq!(s.y, vec![-1.0, 1.0]);
        assert_eq!(s.n(), 2);
    }

    #[test]
    #[should_panic]
    fn mismatched_labels_panic() {
        let x = CsrMatrix::from_dense(2, 1, &[1.0, 2.0]);
        Dataset::new("bad", x, vec![1.0]);
    }

    #[test]
    fn permute_rows_matches_gather_rows_bitwise() {
        let d = tiny();
        let perm = [2usize, 0, 3, 1];
        let gathered = d.gather_rows(&perm);
        let permuted = d.clone().permute_rows(&perm);
        assert_eq!(permuted.y, gathered.y);
        assert_eq!(permuted.name, gathered.name);
        for i in 0..4 {
            assert_eq!(
                permuted.row_norms_sq[i].to_bits(),
                gathered.row_norms_sq[i].to_bits()
            );
            assert_eq!(permuted.x.row(i), gathered.x.row(i));
        }
    }

    #[test]
    fn gather_rows_carries_cached_norms_bitwise() {
        let d = tiny();
        let g = d.gather_rows(&[3, 1, 0]);
        assert_eq!(g.n(), 3);
        assert_eq!(g.y, vec![-1.0, 1.0, 1.0]);
        for (li, &gi) in [3usize, 1, 0].iter().enumerate() {
            assert_eq!(g.row_norms_sq[li].to_bits(), d.row_norms_sq[gi].to_bits());
            assert_eq!(g.x.row(li), d.x.row(gi));
        }
    }
}
