//! Data partitioning across the K workers.
//!
//! The paper's theory assumes a fixed partition {P_k} of [n] (Section 3);
//! the constants σ_k — and hence how safe a given σ' is — depend on how the
//! partition interacts with the data. We provide:
//!  * `random_balanced`  — the standard shuffled equal split (the paper's
//!    setup; balanced n_k = n/K up to remainder),
//!  * `contiguous`       — order-preserving block split (models un-shuffled
//!    ingestion; often adversarial for correlated data),
//!  * `by_label`         — pathological split grouping one class per worker
//!    (used in tests to stress σ'-safety).

use crate::util::rng::Pcg32;

/// A partition of row indices 0..n into K disjoint parts.
#[derive(Clone, Debug)]
pub struct Partition {
    pub parts: Vec<Vec<usize>>,
    pub n: usize,
}

impl Partition {
    pub fn k(&self) -> usize {
        self.parts.len()
    }

    /// Part sizes n_k.
    pub fn sizes(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.len()).collect()
    }

    /// max_k n_k.
    pub fn max_size(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }

    /// True if all parts have equal size (the balanced assumption of
    /// Corollaries 9/11 and the DisDCA-p equivalence).
    pub fn is_balanced(&self) -> bool {
        let s = self.sizes();
        s.iter().all(|&v| v == s[0])
    }

    /// Verify the partition is an exact cover of 0..n (used by tests and
    /// debug assertions in the coordinator).
    pub fn is_exact_cover(&self) -> bool {
        let mut seen = vec![false; self.n];
        let mut count = 0usize;
        for part in &self.parts {
            for &i in part {
                if i >= self.n || seen[i] {
                    return false;
                }
                seen[i] = true;
                count += 1;
            }
        }
        count == self.n
    }

    /// Map from row index to owning worker.
    pub fn owner_of(&self) -> Vec<usize> {
        let mut owner = vec![usize::MAX; self.n];
        for (k, part) in self.parts.iter().enumerate() {
            for &i in part {
                owner[i] = k;
            }
        }
        owner
    }
}

/// Shuffled equal split (sizes differ by at most 1).
pub fn random_balanced(n: usize, k: usize, seed: u64) -> Partition {
    assert!(k >= 1 && k <= n, "need 1 <= K ({k}) <= n ({n})");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg32::new(seed, 23);
    rng.shuffle(&mut idx);
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push(idx[pos..pos + sz].to_vec());
        pos += sz;
    }
    Partition { parts, n }
}

/// Order-preserving contiguous block split.
pub fn contiguous(n: usize, k: usize) -> Partition {
    assert!(k >= 1 && k <= n, "need 1 <= K ({k}) <= n ({n})");
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push((pos..pos + sz).collect());
        pos += sz;
    }
    Partition { parts, n }
}

/// Group rows by sign of the label, then split each group round-robin so
/// workers see maximally homogeneous labels. Pathological for averaging.
pub fn by_label(labels: &[f64], k: usize) -> Partition {
    let n = labels.len();
    assert!(k >= 1 && k <= n);
    let mut pos_rows: Vec<usize> = (0..n).filter(|&i| labels[i] > 0.0).collect();
    let mut neg_rows: Vec<usize> = (0..n).filter(|&i| labels[i] <= 0.0).collect();
    let mut ordered = Vec::with_capacity(n);
    ordered.append(&mut pos_rows);
    ordered.append(&mut neg_rows);
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push(ordered[pos..pos + sz].to_vec());
        pos += sz;
    }
    Partition { parts, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_balanced_is_exact_cover() {
        let p = random_balanced(103, 8, 5);
        assert_eq!(p.k(), 8);
        assert!(p.is_exact_cover());
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 12 || s == 13));
    }

    #[test]
    fn divisible_split_is_balanced() {
        let p = random_balanced(64, 8, 1);
        assert!(p.is_balanced());
        assert!(p.sizes().iter().all(|&s| s == 8));
    }

    #[test]
    fn contiguous_preserves_order() {
        let p = contiguous(10, 3);
        assert_eq!(p.parts[0], vec![0, 1, 2, 3]);
        assert_eq!(p.parts[1], vec![4, 5, 6]);
        assert_eq!(p.parts[2], vec![7, 8, 9]);
        assert!(p.is_exact_cover());
    }

    #[test]
    fn by_label_groups_classes() {
        let labels = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        let p = by_label(&labels, 2);
        assert!(p.is_exact_cover());
        // first worker gets all positives
        assert!(p.parts[0].iter().all(|&i| labels[i] > 0.0));
    }

    #[test]
    fn owner_map_consistent() {
        let p = random_balanced(20, 4, 9);
        let owner = p.owner_of();
        for (k, part) in p.parts.iter().enumerate() {
            for &i in part {
                assert_eq!(owner[i], k);
            }
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = random_balanced(50, 5, 3);
        let b = random_balanced(50, 5, 3);
        assert_eq!(a.parts, b.parts);
        let c = random_balanced(50, 5, 4);
        assert_ne!(a.parts, c.parts);
    }

    #[test]
    #[should_panic]
    fn more_workers_than_points_panics() {
        random_balanced(3, 5, 0);
    }
}
