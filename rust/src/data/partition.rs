//! Data partitioning across the K workers.
//!
//! The paper's theory assumes a fixed partition {P_k} of [n] (Section 3);
//! the constants σ_k — and hence how safe a given σ' is — depend on how the
//! partition interacts with the data. We provide:
//!  * `random_balanced`  — the standard shuffled equal split (the paper's
//!    setup; balanced n_k = n/K up to remainder),
//!  * `contiguous`       — order-preserving block split (models un-shuffled
//!    ingestion; often adversarial for correlated data),
//!  * `by_label`         — pathological split grouping one class per worker
//!    (used in tests to stress σ'-safety).
//!
//! ## The permuted-contiguous shard layout
//!
//! A partition's index-list form is what the theory speaks; the runtime
//! wants every part to be a *contiguous row range* so a worker's shard can
//! be a zero-copy [`CsrShard`](crate::linalg::CsrShard) view instead of a
//! cloned sub-matrix. [`Partition::apply_permutation`] bridges the two:
//! it reorders the dataset **once** (concatenating the parts in worker
//! order) and returns a [`ShardLayout`] — the shared `Arc<Dataset>`, the
//! `(start, len)` row range each worker occupies in it, and the
//! global↔local [`RowPermutation`] for scattering Δα back to the
//! caller's row order. In a contiguous layout a shard's index list is
//! fully derivable from its range, so the layout carries K `(start,
//! len)` pairs instead of K index vectors totalling n entries. A
//! partition that is already contiguous permutes nothing and keeps the
//! caller's `Arc`.

use crate::data::Dataset;
use crate::util::rng::Pcg32;
use std::sync::Arc;

/// A partition of row indices 0..n into K disjoint parts.
#[derive(Clone, Debug)]
pub struct Partition {
    pub parts: Vec<Vec<usize>>,
    pub n: usize,
}

impl Partition {
    pub fn k(&self) -> usize {
        self.parts.len()
    }

    /// Part sizes n_k.
    pub fn sizes(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.len()).collect()
    }

    /// max_k n_k.
    pub fn max_size(&self) -> usize {
        self.sizes().into_iter().max().unwrap_or(0)
    }

    /// True if all parts have equal size (the balanced assumption of
    /// Corollaries 9/11 and the DisDCA-p equivalence). An empty partition
    /// is vacuously balanced.
    pub fn is_balanced(&self) -> bool {
        let s = self.sizes();
        match s.first() {
            Some(&first) => s.iter().all(|&v| v == first),
            None => true,
        }
    }

    /// Verify the partition is an exact cover of 0..n (used by tests and
    /// debug assertions in the coordinator).
    pub fn is_exact_cover(&self) -> bool {
        let mut seen = vec![false; self.n];
        let mut count = 0usize;
        for part in &self.parts {
            for &i in part {
                if i >= self.n || seen[i] {
                    return false;
                }
                seen[i] = true;
                count += 1;
            }
        }
        count == self.n
    }

    /// Map from row index to owning worker.
    pub fn owner_of(&self) -> Vec<usize> {
        let mut owner = vec![usize::MAX; self.n];
        for (k, part) in self.parts.iter().enumerate() {
            for &i in part {
                owner[i] = k;
            }
        }
        owner
    }

    /// True when the parts tile `0..n` in order — part 0 is `0..n_0`,
    /// part 1 is `n_0..n_0+n_1`, and so on. Exactly the layouts whose
    /// shards can be zero-copy row-range views.
    pub fn is_contiguous_layout(&self) -> bool {
        let mut next = 0usize;
        for part in &self.parts {
            for &i in part {
                if i != next {
                    return false;
                }
                next += 1;
            }
        }
        next == self.n
    }

    /// The `(start, len)` row range each part occupies once the parts are
    /// laid out consecutively in worker order — the shard addressing of a
    /// permuted-contiguous layout. K pairs instead of K index lists
    /// totalling n entries.
    pub fn shard_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.k());
        let mut pos = 0usize;
        for part in &self.parts {
            out.push((pos, part.len()));
            pos += part.len();
        }
        out
    }

    /// Reorder `data` **once** so that every part becomes a contiguous row
    /// range, and return the resulting [`ShardLayout`]: the shared
    /// (possibly permuted) dataset, the per-worker `(start, len)` shard
    /// ranges over it, and the row maps back to the caller's original
    /// order.
    ///
    /// Permuted row `p` holds original row `layout.rows.new_to_old[p]`;
    /// within each part the original order of its index list is preserved,
    /// so per-shard contents — and therefore local-solver trajectories —
    /// are identical to the index-list semantics. A partition that is
    /// already contiguous returns the caller's `Arc` untouched (true
    /// zero-copy). When the caller passes in the **only** reference to the
    /// dataset, the reorder consumes it through
    /// [`Dataset::permute_rows`] — storage is replaced array by array, so
    /// ingest never holds two full datasets; a shared dataset falls back
    /// to [`Dataset::gather_rows`], leaving the caller's copy intact.
    pub fn apply_permutation(&self, data: Arc<Dataset>) -> ShardLayout {
        assert_eq!(self.n, data.n(), "partition n != dataset n");
        assert!(
            self.is_exact_cover(),
            "apply_permutation needs an exact cover of 0..n"
        );
        if self.is_contiguous_layout() {
            return ShardLayout {
                data,
                shards: self.shard_ranges(),
                rows: RowPermutation::identity(self.n),
            };
        }
        let mut new_to_old = Vec::with_capacity(self.n);
        for part in &self.parts {
            new_to_old.extend_from_slice(part);
        }
        let mut old_to_new = vec![0usize; self.n];
        for (new, &old) in new_to_old.iter().enumerate() {
            old_to_new[old] = new;
        }
        // Both branches are bit-identical; they differ only in peak memory.
        let permuted = match Arc::try_unwrap(data) {
            Ok(owned) => Arc::new(owned.permute_rows(&new_to_old)),
            Err(shared) => Arc::new(shared.gather_rows(&new_to_old)),
        };
        ShardLayout {
            data: permuted,
            shards: self.shard_ranges(),
            rows: RowPermutation {
                new_to_old,
                old_to_new,
            },
        }
    }
}

/// The global↔local row maps of a permuted-contiguous shard layout.
#[derive(Clone, Debug)]
pub struct RowPermutation {
    /// Permuted (layout) index → original index.
    pub new_to_old: Vec<usize>,
    /// Original index → permuted (layout) index.
    pub old_to_new: Vec<usize>,
}

impl RowPermutation {
    pub fn identity(n: usize) -> RowPermutation {
        RowPermutation {
            new_to_old: (0..n).collect(),
            old_to_new: (0..n).collect(),
        }
    }

    pub fn is_identity(&self) -> bool {
        self.new_to_old.iter().enumerate().all(|(i, &o)| i == o)
    }

    /// Scatter a layout-ordered vector back to original row order.
    pub fn to_original(&self, permuted: &[f64]) -> Vec<f64> {
        assert_eq!(permuted.len(), self.new_to_old.len());
        let mut out = vec![0.0; permuted.len()];
        for (new, &old) in self.new_to_old.iter().enumerate() {
            out[old] = permuted[new];
        }
        out
    }

    /// Gather an original-ordered vector into layout order.
    pub fn to_permuted(&self, original: &[f64]) -> Vec<f64> {
        assert_eq!(original.len(), self.new_to_old.len());
        self.new_to_old.iter().map(|&old| original[old]).collect()
    }
}

/// A partition's contiguous realization over a shared dataset: the output
/// of [`Partition::apply_permutation`]. All K shards are views into
/// `data`, so the layout owns at most one (permuted) copy of the dataset
/// regardless of K.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    /// The shared — possibly permuted — dataset every shard views into.
    pub data: Arc<Dataset>,
    /// Worker k's rows of `data` as a `(start, len)` range — the whole
    /// addressing of a contiguous layout; index lists are derivable as
    /// `start..start + len`.
    pub shards: Vec<(usize, usize)>,
    /// Maps between layout order and the caller's original row order.
    pub rows: RowPermutation,
}

impl ShardLayout {
    /// Number of shards K.
    pub fn k(&self) -> usize {
        self.shards.len()
    }
}

/// Shuffled equal split (sizes differ by at most 1).
pub fn random_balanced(n: usize, k: usize, seed: u64) -> Partition {
    assert!(k >= 1 && k <= n, "need 1 <= K ({k}) <= n ({n})");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Pcg32::new(seed, 23);
    rng.shuffle(&mut idx);
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push(idx[pos..pos + sz].to_vec());
        pos += sz;
    }
    Partition { parts, n }
}

/// Order-preserving contiguous block split.
pub fn contiguous(n: usize, k: usize) -> Partition {
    assert!(k >= 1 && k <= n, "need 1 <= K ({k}) <= n ({n})");
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push((pos..pos + sz).collect());
        pos += sz;
    }
    Partition { parts, n }
}

/// Group rows by sign of the label, then split each group round-robin so
/// workers see maximally homogeneous labels. Pathological for averaging.
pub fn by_label(labels: &[f64], k: usize) -> Partition {
    let n = labels.len();
    assert!(k >= 1 && k <= n);
    let mut pos_rows: Vec<usize> = (0..n).filter(|&i| labels[i] > 0.0).collect();
    let mut neg_rows: Vec<usize> = (0..n).filter(|&i| labels[i] <= 0.0).collect();
    let mut ordered = Vec::with_capacity(n);
    ordered.append(&mut pos_rows);
    ordered.append(&mut neg_rows);
    let base = n / k;
    let extra = n % k;
    let mut parts = Vec::with_capacity(k);
    let mut pos = 0;
    for j in 0..k {
        let sz = base + usize::from(j < extra);
        parts.push(ordered[pos..pos + sz].to_vec());
        pos += sz;
    }
    Partition { parts, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_balanced_is_exact_cover() {
        let p = random_balanced(103, 8, 5);
        assert_eq!(p.k(), 8);
        assert!(p.is_exact_cover());
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 12 || s == 13));
    }

    #[test]
    fn divisible_split_is_balanced() {
        let p = random_balanced(64, 8, 1);
        assert!(p.is_balanced());
        assert!(p.sizes().iter().all(|&s| s == 8));
    }

    #[test]
    fn contiguous_preserves_order() {
        let p = contiguous(10, 3);
        assert_eq!(p.parts[0], vec![0, 1, 2, 3]);
        assert_eq!(p.parts[1], vec![4, 5, 6]);
        assert_eq!(p.parts[2], vec![7, 8, 9]);
        assert!(p.is_exact_cover());
    }

    #[test]
    fn by_label_groups_classes() {
        let labels = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        let p = by_label(&labels, 2);
        assert!(p.is_exact_cover());
        // first worker gets all positives
        assert!(p.parts[0].iter().all(|&i| labels[i] > 0.0));
    }

    #[test]
    fn owner_map_consistent() {
        let p = random_balanced(20, 4, 9);
        let owner = p.owner_of();
        for (k, part) in p.parts.iter().enumerate() {
            for &i in part {
                assert_eq!(owner[i], k);
            }
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = random_balanced(50, 5, 3);
        let b = random_balanced(50, 5, 3);
        assert_eq!(a.parts, b.parts);
        let c = random_balanced(50, 5, 4);
        assert_ne!(a.parts, c.parts);
    }

    #[test]
    #[should_panic]
    fn more_workers_than_points_panics() {
        random_balanced(3, 5, 0);
    }

    #[test]
    fn empty_partition_is_balanced() {
        // K = 0: no parts at all — vacuously balanced, must not panic.
        let p = Partition {
            parts: Vec::new(),
            n: 0,
        };
        assert!(p.is_balanced());
        assert!(p.is_exact_cover());
        assert!(p.is_contiguous_layout());
    }

    #[test]
    fn contiguous_layout_detection() {
        assert!(contiguous(10, 3).is_contiguous_layout());
        let shuffled = random_balanced(40, 4, 1);
        assert!(!shuffled.is_contiguous_layout());
        // ordered parts but a gap is not contiguous
        let p = Partition {
            parts: vec![vec![0, 2], vec![1, 3]],
            n: 4,
        };
        assert!(!p.is_contiguous_layout());
    }

    #[test]
    fn apply_permutation_identity_keeps_arc() {
        use crate::data::synth::{generate, SynthConfig};
        let data = Arc::new(generate(&SynthConfig::new("ap", 12, 4).seed(1)));
        let part = contiguous(12, 3);
        let layout = part.apply_permutation(Arc::clone(&data));
        assert!(Arc::ptr_eq(&layout.data, &data), "identity must not copy");
        assert!(layout.rows.is_identity());
        assert_eq!(layout.shards, part.shard_ranges());
        assert_eq!(layout.shards, vec![(0, 4), (4, 4), (8, 4)]);
        assert_eq!(layout.k(), 3);
    }

    #[test]
    fn apply_permutation_makes_parts_contiguous_and_maps_back() {
        use crate::data::synth::{generate, SynthConfig};
        let data = Arc::new(generate(&SynthConfig::new("ap", 30, 5).seed(2)));
        let part = random_balanced(30, 4, 9);
        let layout = part.apply_permutation(Arc::clone(&data));
        // shards tile 0..n in worker order with the original part sizes
        let sizes: Vec<usize> = layout.shards.iter().map(|&(_, len)| len).collect();
        assert_eq!(sizes, part.sizes());
        let mut next = 0usize;
        for &(start, len) in &layout.shards {
            assert_eq!(start, next);
            next += len;
        }
        assert_eq!(next, 30);
        // permuted row p holds original row new_to_old[p], part order kept
        for (k, rows) in part.parts.iter().enumerate() {
            let (start, len) = layout.shards[k];
            assert_eq!(len, rows.len());
            for (li, &old) in rows.iter().enumerate() {
                let new = start + li;
                assert_eq!(layout.rows.new_to_old[new], old);
                assert_eq!(layout.rows.old_to_new[old], new);
                assert_eq!(layout.data.y[new], data.y[old]);
                assert_eq!(layout.data.x.row(new), data.x.row(old));
            }
        }
        // round-trip a vector through the maps
        let v: Vec<f64> = (0..30).map(|i| i as f64).collect();
        assert_eq!(layout.rows.to_original(&layout.rows.to_permuted(&v)), v);
    }
}
