//! Dataset substrate: container, synthetic generators (paper analogues),
//! LibSVM parsing, partitioners, and the shared data plane.
//!
//! Since the zero-copy refactor the dataset is a **shared** object: the
//! coordinator, the certificate evaluator, and all K workers read the same
//! `Arc<Dataset>`. A worker's shard is a row-range view into it (see
//! [`crate::subproblem::LocalBlock`] and
//! [`crate::linalg::CsrShard`]), produced by permuting the dataset *once*
//! into the [`partition::ShardLayout`] where every part is contiguous —
//! total resident data is 1× the dataset instead of the old leader copy
//! plus K cloned shards (≈2×).

pub mod dataset;
pub mod libsvm;
pub mod partition;
pub mod scale;
pub mod synth;

pub use dataset::Dataset;
pub use partition::{Partition, RowPermutation, ShardLayout};
