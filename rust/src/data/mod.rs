//! Dataset substrate: container, synthetic generators (paper analogues),
//! LibSVM parsing, and partitioners.

pub mod dataset;
pub mod libsvm;
pub mod partition;
pub mod scale;
pub mod synth;

pub use dataset::Dataset;
pub use partition::Partition;
