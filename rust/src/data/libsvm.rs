//! LibSVM / SVMlight format parser and writer.
//!
//! Format per line: `<label> <index>:<value> <index>:<value> ...` with
//! 1-based feature indices and optional `# comment` suffixes. This is the
//! format the paper's datasets (covtype, rcv1, news20, real-sim, epsilon)
//! ship in, so real corpora drop into every experiment unchanged via
//! `--data path.svm`.

use crate::data::dataset::Dataset;
use crate::linalg::CsrMatrix;
use std::io::Write;
use std::path::Path;

#[derive(Debug)]
pub enum LibsvmError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
    /// A non-finite label or value on the way in (parse) or out (save).
    /// The text format cannot round-trip NaN/Inf losslessly through every
    /// reader, so both directions refuse them.
    NonFinite { line: usize, msg: String },
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "io error: {e}"),
            LibsvmError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            LibsvmError::NonFinite { line, msg } => {
                write!(f, "line {line}: non-finite {msg}")
            }
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> LibsvmError {
        LibsvmError::Io(e)
    }
}

/// Parse LibSVM text. `expected_dim`: pass Some(d) to force the feature
/// dimension (indices beyond it error); None infers d from the max index.
pub fn parse_str(text: &str, expected_dim: Option<usize>) -> Result<Dataset, LibsvmError> {
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut max_col = 0usize;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or_else(|| LibsvmError::Parse {
            line: lineno + 1,
            msg: "empty line after comment strip".into(),
        })?;
        let label: f64 = label_tok.parse().map_err(|e| LibsvmError::Parse {
            line: lineno + 1,
            msg: format!("bad label {label_tok:?}: {e}"),
        })?;
        // Rust's f64 parser accepts "inf"/"nan" spellings, which would
        // otherwise poison the loss evaluations much later with no line
        // number attached.
        if !label.is_finite() {
            return Err(LibsvmError::NonFinite {
                line: lineno + 1,
                msg: format!("label {label_tok:?}"),
            });
        }
        let mut row = Vec::new();
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("expected index:value, got {tok:?}"),
            })?;
            let idx: usize = idx_s.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad index {idx_s:?}: {e}"),
            })?;
            if idx == 0 {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: "libsvm indices are 1-based; found 0".into(),
                });
            }
            let val: f64 = val_s.parse().map_err(|e| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad value {val_s:?}: {e}"),
            })?;
            if !val.is_finite() {
                return Err(LibsvmError::NonFinite {
                    line: lineno + 1,
                    msg: format!("value {val_s:?} at index {idx}"),
                });
            }
            let col = idx - 1;
            if let Some(d) = expected_dim {
                if col >= d {
                    return Err(LibsvmError::Parse {
                        line: lineno + 1,
                        msg: format!("index {idx} exceeds declared dimension {d}"),
                    });
                }
            }
            max_col = max_col.max(col);
            row.push((col, val));
        }
        // Duplicate indices within a row are ambiguous (sum? last wins?)
        // and every downstream CSR assumes strictly increasing columns —
        // reject them here with the offending line attached.
        let mut cols: Vec<usize> = row.iter().map(|&(c, _)| c).collect();
        cols.sort_unstable();
        let mut prev = None;
        for &c in &cols {
            if prev == Some(c) {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: format!("duplicate feature index {}", c + 1),
                });
            }
            prev = Some(c);
        }
        rows.push(row);
        labels.push(label);
    }

    let d = expected_dim.unwrap_or(if rows.is_empty() { 0 } else { max_col + 1 });
    let x = CsrMatrix::from_rows(d, &rows);
    Ok(Dataset::new("libsvm", x, labels))
}

/// Load from a file path.
pub fn load(path: &Path, expected_dim: Option<usize>) -> Result<Dataset, LibsvmError> {
    let text = std::fs::read_to_string(path)?;
    let mut ds = parse_str(&text, expected_dim)?;
    ds.name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    Ok(ds)
}

/// Write a dataset in LibSVM format. Non-finite labels or values are
/// refused ([`LibsvmError::NonFinite`]) rather than written: the text
/// format has no portable NaN/Inf spelling, so such a file would fail —
/// or worse, silently misparse — on the next reader.
pub fn save(ds: &Dataset, path: &Path) -> Result<(), LibsvmError> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for (i, &label) in ds.y.iter().enumerate() {
        if !label.is_finite() {
            return Err(LibsvmError::NonFinite {
                line: i + 1,
                msg: format!("label {label}"),
            });
        }
        write!(f, "{}", format_num(label))?;
        let (idx, vals) = ds.x.row(i);
        for (&c, &v) in idx.iter().zip(vals.iter()) {
            if !v.is_finite() {
                return Err(LibsvmError::NonFinite {
                    line: i + 1,
                    msg: format!("value {v} at index {}", c as usize + 1),
                });
            }
            write!(f, " {}:{}", c as usize + 1, format_num(v))?;
        }
        writeln!(f)?;
    }
    f.flush()?;
    Ok(())
}

fn format_num(v: f64) -> String {
    // `(-0.0) as i64` is 0, so the integer fast path below would turn a
    // negative-zero label into "0" and break bit-exact round-trips.
    if v == 0.0 && v.is_sign_negative() {
        return "-0".to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let txt = "+1 1:0.5 3:2\n-1 2:1.5\n";
        let ds = parse_str(txt, None).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(1).1, &[1.5]);
    }

    #[test]
    fn comments_and_blank_lines() {
        let txt = "# header\n\n1 1:1 # trailing\n";
        let ds = parse_str(txt, None).unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse_str("1 0:1\n", None).is_err());
    }

    #[test]
    fn rejects_bad_tokens() {
        assert!(parse_str("1 nocolon\n", None).is_err());
        assert!(parse_str("abc 1:1\n", None).is_err());
        assert!(parse_str("1 1:xyz\n", None).is_err());
    }

    #[test]
    fn dimension_enforcement() {
        assert!(parse_str("1 5:1\n", Some(3)).is_err());
        let ds = parse_str("1 2:1\n", Some(10)).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn rejects_duplicate_feature_index_with_line_number() {
        let err = parse_str("1 1:1\n-1 2:1 3:4 2:3\n", None).unwrap_err();
        match err {
            LibsvmError::Parse { line, msg } => {
                assert_eq!(line, 2);
                assert!(msg.contains("duplicate feature index 2"), "{msg}");
            }
            other => panic!("expected Parse, got {other:?}"),
        }
    }

    #[test]
    fn triple_duplicate_reports_first_collision() {
        // Regression for the windows→scan rewrite of duplicate detection:
        // three occurrences of one column still report the 1-based index
        // once, with the right line number.
        let err = parse_str("1 7:1 7:2 7:3\n", None).unwrap_err();
        match err {
            LibsvmError::Parse { line, msg } => {
                assert_eq!(line, 1);
                assert!(msg.contains("duplicate feature index 7"), "{msg}");
            }
            other => panic!("expected Parse, got {other:?}"),
        }
    }

    #[test]
    fn rejects_non_finite_input() {
        assert!(matches!(
            parse_str("1 1:inf\n", None),
            Err(LibsvmError::NonFinite { line: 1, .. })
        ));
        assert!(matches!(
            parse_str("1 1:1\nnan 1:1\n", None),
            Err(LibsvmError::NonFinite { line: 2, .. })
        ));
    }

    #[test]
    fn save_refuses_non_finite_state() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nonfinite.svm");
        let bad_label = Dataset::new(
            "bad",
            CsrMatrix::from_rows(1, &[vec![(0, 1.0)]]),
            vec![f64::NAN],
        );
        assert!(matches!(
            save(&bad_label, &path),
            Err(LibsvmError::NonFinite { line: 1, .. })
        ));
        let bad_value = Dataset::new(
            "bad",
            CsrMatrix::from_rows(2, &[vec![(1, f64::INFINITY)]]),
            vec![1.0],
        );
        let err = save(&bad_value, &path).unwrap_err();
        assert!(err.to_string().contains("index 2"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn negative_zero_label_roundtrips() {
        // `(-0.0) as i64 == 0`, so without the sign check format_num
        // would write "-0.0" as "0" and lose the sign bit.
        assert_eq!(format_num(-0.0), "-0");
        assert_eq!(format_num(0.0), "0");
        let dir = std::env::temp_dir().join("cocoa_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("negzero.svm");
        let ds = Dataset::new(
            "nz",
            CsrMatrix::from_rows(1, &[vec![(0, 1.0)], vec![(0, 2.0)]]),
            vec![-0.0, 1.0],
        );
        save(&ds, &path).unwrap();
        let back = load(&path, None).unwrap();
        assert_eq!(
            back.y[0].to_bits(),
            (-0.0f64).to_bits(),
            "-0.0 label lost its sign bit through save/load"
        );
        assert_eq!(back.y[1], 1.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        let txt = "1 1:0.25 4:-3\n-1 2:7\n";
        let ds = parse_str(txt, None).unwrap();
        save(&ds, &path).unwrap();
        let back = load(&path, None).unwrap();
        assert_eq!(back.y, ds.y);
        assert_eq!(back.x, ds.x);
        std::fs::remove_file(&path).ok();
    }
}
