//! Synthetic dataset generators.
//!
//! The paper evaluates on covtype, epsilon, rcv1, news20 and real-sim
//! (Table 2). Those corpora are not available offline, so — per the
//! substitution rule in DESIGN.md — we generate synthetic analogues that
//! match each dataset's *signature*: (n, d, sparsity pattern, label
//! structure). CoCoA+'s behaviour depends on exactly these quantities
//! (through σ_k, r_max, and the partition difficulty), not on the corpus
//! content, so the figure/table shapes are preserved. A LibSVM loader
//! (`data::libsvm`) lets the real files drop in unchanged when present.

use crate::data::dataset::Dataset;
use crate::linalg::CsrMatrix;
use crate::util::rng::Pcg32;

/// Parameters for the linear-margin generator.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub name: String,
    pub n: usize,
    pub d: usize,
    /// Expected fraction of nonzero features per row (1.0 → dense).
    pub density: f64,
    /// Label noise: probability of flipping the true label.
    pub label_noise: f64,
    /// Margin scale of the planted hyperplane (smaller → harder problem).
    pub margin: f64,
    /// If true, nonzero feature values are positive (tf-idf-like);
    /// otherwise Gaussian.
    pub nonneg_features: bool,
    pub seed: u64,
}

impl SynthConfig {
    pub fn new(name: &str, n: usize, d: usize) -> Self {
        SynthConfig {
            name: name.to_string(),
            n,
            d,
            density: 1.0,
            label_noise: 0.05,
            margin: 1.0,
            nonneg_features: false,
            seed: 42,
        }
    }
    pub fn density(mut self, v: f64) -> Self {
        self.density = v;
        self
    }
    pub fn label_noise(mut self, v: f64) -> Self {
        self.label_noise = v;
        self
    }
    pub fn nonneg(mut self, v: bool) -> Self {
        self.nonneg_features = v;
        self
    }
    pub fn seed(mut self, v: u64) -> Self {
        self.seed = v;
        self
    }
}

/// Generate a binary classification dataset with a planted hyperplane:
/// rows are (sparse) feature vectors, labels are sign(x·w*) with noise.
/// Rows are normalized to unit norm (paper assumption ‖x_i‖ ≤ 1).
pub fn generate(cfg: &SynthConfig) -> Dataset {
    let mut rng = Pcg32::new(cfg.seed, 17);
    // Planted dense hyperplane.
    let w_star: Vec<f64> = (0..cfg.d).map(|_| rng.gaussian()).collect();

    let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(cfg.n);
    let mut labels = Vec::with_capacity(cfg.n);
    // Expected nnz per row, at least 1.
    let nnz_target = ((cfg.d as f64 * cfg.density).round() as usize).max(1);
    for _ in 0..cfg.n {
        let row = if cfg.density >= 1.0 {
            (0..cfg.d)
                .map(|c| {
                    let v = if cfg.nonneg_features {
                        rng.next_f64() + 0.05
                    } else {
                        rng.gaussian()
                    };
                    (c, v)
                })
                .collect::<Vec<_>>()
        } else {
            // Poisson-ish nnz around the target (clamped), distinct columns.
            let jitter = (nnz_target as f64 * 0.5).max(1.0);
            let k = ((nnz_target as f64 + (rng.next_f64() - 0.5) * 2.0 * jitter).round()
                as isize)
                .clamp(1, cfg.d as isize) as usize;
            rng.sample_indices(cfg.d, k)
                .into_iter()
                .map(|c| {
                    let v = if cfg.nonneg_features {
                        rng.next_f64() + 0.05
                    } else {
                        rng.gaussian()
                    };
                    (c, v)
                })
                .collect()
        };
        // Label from the planted hyperplane before normalization (scale
        // invariant), with margin-proportional noise.
        let score: f64 = row.iter().map(|&(c, v)| v * w_star[c]).sum();
        let mut y = if score >= 0.0 { 1.0 } else { -1.0 };
        if rng.bernoulli(cfg.label_noise) {
            y = -y;
        }
        let _ = cfg.margin; // margin folds into noise for this generator
        rows.push(row);
        labels.push(y);
    }
    let mut x = CsrMatrix::from_rows(cfg.d, &rows);
    x.normalize_rows();
    Dataset::new(&cfg.name, x, labels)
}

/// Scaled-down analogues of the paper's datasets (Table 2).
/// `scale` divides n (and d for the very high-dimensional ones) so the
/// experiments run on one host; `scale=1.0` reproduces the paper's sizes.
pub fn paper_dataset(which: &str, scale: f64, seed: u64) -> Dataset {
    let s = |v: usize| ((v as f64 / scale).round() as usize).max(16);
    match which {
        // covtype: 522,911 × 54, 22.22% dense, low-dim dense-ish.
        "covtype" => generate(
            &SynthConfig::new("covtype", s(522_911), 54)
                .density(0.2222)
                .label_noise(0.2)
                .seed(seed),
        ),
        // epsilon: 400,000 × 2,000 fully dense.
        "epsilon" => generate(
            &SynthConfig::new("epsilon", s(400_000), s(2_000).max(64))
                .density(1.0)
                .label_noise(0.1)
                .seed(seed),
        ),
        // rcv1: 677,399 × 47,236 at 0.16% density, tf-idf-ish nonneg.
        "rcv1" => generate(
            &SynthConfig::new("rcv1", s(677_399), s(47_236).max(256))
                .density(0.0016f64.max(16.0 / s(47_236).max(256) as f64))
                .label_noise(0.05)
                .nonneg(true)
                .seed(seed),
        ),
        // news20: 19,996 × 1,355,191 extremely sparse.
        "news" => generate(
            &SynthConfig::new("news", s(19_996), s(1_355_191).max(512))
                .density((30.0 / s(1_355_191).max(512) as f64).min(1.0))
                .label_noise(0.03)
                .nonneg(true)
                .seed(seed),
        ),
        // real-sim: 72,309 × 20,958, ~0.25% dense.
        "real-sim" => generate(
            &SynthConfig::new("real-sim", s(72_309), s(20_958).max(256))
                .density(0.0025f64.max(16.0 / s(20_958).max(256) as f64))
                .label_noise(0.05)
                .nonneg(true)
                .seed(seed),
        ),
        other => panic!("unknown paper dataset {other:?} (covtype|epsilon|rcv1|news|real-sim)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_generator_shapes() {
        let d = generate(&SynthConfig::new("t", 50, 8).seed(1));
        assert_eq!(d.n(), 50);
        assert_eq!(d.d(), 8);
        assert!((d.density() - 1.0).abs() < 1e-9);
        // normalized rows
        assert!((d.r_max() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparse_generator_density() {
        let d = generate(&SynthConfig::new("t", 400, 200).density(0.05).seed(2));
        let dens = d.density();
        assert!(dens > 0.01 && dens < 0.12, "density {dens}");
        // every row must have at least one nonzero (normalize keeps unit norm)
        for i in 0..d.n() {
            assert!(d.x.row_nnz(i) >= 1);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SynthConfig::new("t", 30, 10).seed(7));
        let b = generate(&SynthConfig::new("t", 30, 10).seed(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&SynthConfig::new("t", 30, 10).seed(8));
        assert_ne!(a.x.values, c.x.values);
    }

    #[test]
    fn labels_mostly_linearly_separable() {
        // With low noise the planted hyperplane classifies well even after
        // normalization; check a long SDCA-free proxy: labels correlate with
        // the score of the plant (regenerate scores via dataset itself is
        // not possible, so just check both classes appear).
        let d = generate(&SynthConfig::new("t", 200, 16).label_noise(0.0).seed(3));
        let pf = d.positive_fraction();
        assert!(pf > 0.15 && pf < 0.85, "positive fraction {pf}");
    }

    #[test]
    fn paper_signatures() {
        let cov = paper_dataset("covtype", 1000.0, 1);
        assert_eq!(cov.d(), 54);
        assert!(cov.n() >= 500);
        let rcv = paper_dataset("rcv1", 1000.0, 1);
        assert!(rcv.density() < 0.2, "rcv1-like should be sparse");
        let eps = paper_dataset("epsilon", 1000.0, 1);
        assert!((eps.density() - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn unknown_paper_dataset_panics() {
        paper_dataset("mnist", 1.0, 0);
    }
}
