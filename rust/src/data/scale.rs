//! Feature preprocessing and train/test splitting.
//!
//! The paper assumes ‖x_i‖ ≤ 1 (Remark 7 and all corollaries build on
//! it); `Dataset::normalize_rows` handles that. This module adds the rest
//! of a practical ingestion pipeline: per-feature standardization (for
//! dense data), max-abs column scaling (sparsity-preserving, the standard
//! choice for tf-idf-like corpora), and seeded splits.

use crate::data::dataset::Dataset;
use crate::util::rng::Pcg32;

/// Per-column scaling x_ij ← x_ij / max_i |x_ij| — keeps sparsity, bounds
/// every feature in [−1, 1]. Columns that are entirely zero are left
/// untouched. Returns the scale factors.
pub fn max_abs_scale(data: &mut Dataset) -> Vec<f64> {
    let d = data.d();
    let mut maxes = vec![0.0f64; d];
    for &c in &data.x.indices {
        let _ = c;
    }
    for (j, &c) in data.x.indices.iter().enumerate() {
        maxes[c as usize] = maxes[c as usize].max(data.x.values[j].abs());
    }
    for (j, &c) in data.x.indices.clone().iter().enumerate() {
        let m = maxes[c as usize];
        if m > 0.0 {
            data.x.values[j] /= m;
        }
    }
    data.row_norms_sq = data.x.row_norms_sq();
    maxes
}

/// Per-column mean/std (computed over *all* entries including implicit
/// zeros). Standardizing destroys sparsity, so this densifies — intended
/// for low-dimensional dense data (covtype-style).
pub fn standardize(data: &Dataset) -> Dataset {
    let (n, d) = (data.n(), data.d());
    assert!(n > 1, "standardize needs n > 1");
    let dense = data.x.to_dense();
    let mut mean = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            mean[j] += dense[i * d + j];
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; d];
    for i in 0..n {
        for j in 0..d {
            let c = dense[i * d + j] - mean[j];
            var[j] += c * c;
        }
    }
    let std: Vec<f64> = var
        .iter()
        .map(|v| (v / (n - 1) as f64).sqrt())
        .collect();
    let mut out = vec![0.0f64; n * d];
    for i in 0..n {
        for j in 0..d {
            out[i * d + j] = if std[j] > 0.0 {
                (dense[i * d + j] - mean[j]) / std[j]
            } else {
                0.0
            };
        }
    }
    Dataset::new(
        &data.name,
        crate::linalg::CsrMatrix::from_dense(n, d, &out),
        data.y.clone(),
    )
}

/// Seeded shuffled split into (train, test) with `test_fraction` of rows
/// in the test set.
pub fn train_test_split(data: &Dataset, test_fraction: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_fraction));
    let n = data.n();
    let mut idx: Vec<usize> = (0..n).collect();
    Pcg32::new(seed, 41).shuffle(&mut idx);
    let n_test = ((n as f64) * test_fraction).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (data.select(train_idx), data.select(test_idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn max_abs_bounds_features() {
        let mut d = generate(&SynthConfig::new("t", 60, 10).density(0.4).seed(1));
        // un-normalize a bit
        for v in d.x.values.iter_mut() {
            *v *= 7.5;
        }
        max_abs_scale(&mut d);
        for &v in &d.x.values {
            assert!(v.abs() <= 1.0 + 1e-12);
        }
        // sparsity preserved
        assert!(d.density() < 0.6);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let d = generate(&SynthConfig::new("t", 200, 6).seed(2));
        let s = standardize(&d);
        let dense = s.x.to_dense();
        for j in 0..6 {
            let mean: f64 = (0..200).map(|i| dense[i * 6 + j]).sum::<f64>() / 200.0;
            let var: f64 = (0..200)
                .map(|i| (dense[i * 6 + j] - mean).powi(2))
                .sum::<f64>()
                / 199.0;
            assert!(mean.abs() < 1e-10, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-8, "col {j} var {var}");
        }
    }

    #[test]
    fn split_partitions_rows() {
        let d = generate(&SynthConfig::new("t", 100, 5).seed(3));
        let (train, test) = train_test_split(&d, 0.25, 9);
        assert_eq!(train.n(), 75);
        assert_eq!(test.n(), 25);
        // deterministic
        let (train2, _) = train_test_split(&d, 0.25, 9);
        assert_eq!(train.y, train2.y);
        let (train3, _) = train_test_split(&d, 0.25, 10);
        assert_ne!(train.y, train3.y);
    }
}
