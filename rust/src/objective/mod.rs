//! Primal/dual objectives and the duality-gap certificate (§2 of the paper).
//!
//! * Primal (1):  P(w) = (1/n) Σ ℓ_i(x_iᵀw) + (λ/2)‖w‖²
//! * Dual   (2):  D(α) = −(1/n) Σ ℓ*_i(−α_i) − (λ/2)‖Aα/(λn)‖²
//! * Map    (3):  w(α) = Aα/(λn)
//! * Gap    (4):  G(α) = P(w(α)) − D(α) ≥ 0   (weak duality)
//!
//! The gap is the paper's practical stopping certificate; we expose it both
//! from scratch (`duality_gap`) and from cached margins for the hot path.

use crate::data::Dataset;
use crate::linalg::dense;
use crate::loss::Loss;

/// Problem definition: dataset + loss + regularizer.
#[derive(Clone, Debug)]
pub struct Problem {
    pub data: Dataset,
    pub loss: Loss,
    pub lambda: f64,
}

impl Problem {
    pub fn new(data: Dataset, loss: Loss, lambda: f64) -> Problem {
        assert!(lambda > 0.0, "λ must be positive");
        Problem { data, loss, lambda }
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// w(α) = Aα/(λn), writing into `w`.
    pub fn primal_from_dual(&self, alpha: &[f64], w: &mut [f64]) {
        assert_eq!(alpha.len(), self.n());
        assert_eq!(w.len(), self.d());
        self.data.x.matvec_t(alpha, w);
        dense::scale(1.0 / (self.lambda * self.n() as f64), w);
    }

    /// P(w) from scratch.
    pub fn primal_value(&self, w: &[f64]) -> f64 {
        let n = self.n();
        let mut loss_sum = 0.0;
        for i in 0..n {
            let z = self.data.x.row_dot(i, w);
            loss_sum += self.loss.value(z, self.data.y[i]);
        }
        loss_sum / n as f64 + 0.5 * self.lambda * dense::norm_sq(w)
    }

    /// P(w) given precomputed margins z_i = x_iᵀw.
    pub fn primal_value_from_margins(&self, margins: &[f64], w_norm_sq: f64) -> f64 {
        let n = self.n();
        assert_eq!(margins.len(), n);
        let mut loss_sum = 0.0;
        for i in 0..n {
            loss_sum += self.loss.value(margins[i], self.data.y[i]);
        }
        loss_sum / n as f64 + 0.5 * self.lambda * w_norm_sq
    }

    /// D(α) given w = w(α) (the caller maintains the invariant).
    pub fn dual_value(&self, alpha: &[f64], w: &[f64]) -> f64 {
        let n = self.n();
        assert_eq!(alpha.len(), n);
        let mut conj_sum = 0.0;
        for i in 0..n {
            let c = self.loss.conjugate_neg(alpha[i], self.data.y[i]);
            if c.is_infinite() {
                return f64::NEG_INFINITY; // dual-infeasible α
            }
            conj_sum += c;
        }
        -conj_sum / n as f64 - 0.5 * self.lambda * dense::norm_sq(w)
    }

    /// Duality gap G(α) = P(w(α)) − D(α), recomputing w(α) from scratch.
    pub fn duality_gap(&self, alpha: &[f64]) -> f64 {
        let mut w = vec![0.0; self.d()];
        self.primal_from_dual(alpha, &mut w);
        self.primal_value(&w) - self.dual_value(alpha, &w)
    }

    /// Primal, dual, and gap from a consistent (α, w) pair.
    pub fn certificates(&self, alpha: &[f64], w: &[f64]) -> Certificates {
        let primal = self.primal_value(w);
        let dual = self.dual_value(alpha, w);
        Certificates {
            primal,
            dual,
            gap: primal - dual,
        }
    }

    /// The dual witness vector u (Eq. 17): −u_i ∈ ∂ℓ_i(x_iᵀw).
    pub fn dual_witness(&self, w: &[f64]) -> Vec<f64> {
        (0..self.n())
            .map(|i| {
                let z = self.data.x.row_dot(i, w);
                self.loss.dual_witness(z, self.data.y[i])
            })
            .collect()
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Certificates {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::linalg::CsrMatrix;

    fn small_problem(loss: Loss) -> Problem {
        let data = generate(&SynthConfig::new("t", 40, 6).seed(11));
        Problem::new(data, loss, 0.1)
    }

    #[test]
    fn gap_nonnegative_at_zero_and_random_alpha() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
        ] {
            let p = small_problem(loss);
            let n = p.n();
            let zero = vec![0.0; n];
            let g0 = p.duality_gap(&zero);
            assert!(g0 >= -1e-10, "{}: gap at 0 = {g0}", loss.name());
            // feasible random alpha: b = y*α in [0,1]
            let alpha: Vec<f64> = (0..n).map(|i| p.data.y[i] * ((i % 10) as f64 / 10.0)).collect();
            let g = p.duality_gap(&alpha);
            assert!(g >= -1e-10, "{}: gap = {g}", loss.name());
        }
    }

    #[test]
    fn gap_at_zero_bounded_by_one() {
        // Lemma 17: D(α*) − D(0) ≤ 1, and P(0) − D(0) = (1/n)Σℓ_i(0) ≤ 1.
        for loss in [Loss::Hinge, Loss::SmoothedHinge { mu: 0.5 }, Loss::Logistic] {
            let p = small_problem(loss);
            let zero = vec![0.0; p.n()];
            let g0 = p.duality_gap(&zero);
            assert!(g0 <= 1.0 + 1e-9, "{}: {g0}", loss.name());
        }
    }

    #[test]
    fn infeasible_alpha_gives_neg_inf_dual() {
        let p = small_problem(Loss::Hinge);
        let mut alpha = vec![0.0; p.n()];
        alpha[0] = -5.0 * p.data.y[0]; // way outside [0,1] box
        let mut w = vec![0.0; p.d()];
        p.primal_from_dual(&alpha, &mut w);
        assert_eq!(p.dual_value(&alpha, &w), f64::NEG_INFINITY);
    }

    #[test]
    fn primal_from_margins_matches_scratch() {
        let p = small_problem(Loss::Hinge);
        let w: Vec<f64> = (0..p.d()).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut margins = vec![0.0; p.n()];
        p.data.x.matvec(&w, &mut margins);
        let a = p.primal_value(&w);
        let b = p.primal_value_from_margins(&margins, dense::norm_sq(&w));
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn squared_loss_analytic_optimum_has_zero_gap() {
        // Ridge regression on a tiny exactly-solvable problem: at the
        // optimal α the gap must vanish.
        // Problem: X = I (2×2), y = (1, 2), λ arbitrary.
        let x = CsrMatrix::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let data = Dataset::new("tiny", x, vec![1.0, 2.0]);
        let lambda = 0.5;
        let p = Problem::new(data, Loss::Squared, lambda);
        let n = 2.0;
        // For X=I: w_j = α_j/(λn); optimal primal w_j = y_j/(1+λn).
        // Optimal dual α_j = λn·y_j/(1+λn).
        let scale = lambda * n / (1.0 + lambda * n);
        let alpha = vec![scale * 1.0, scale * 2.0];
        let gap = p.duality_gap(&alpha);
        assert!(gap.abs() < 1e-10, "gap {gap}");
    }

    #[test]
    fn witness_is_feasible_for_lipschitz_losses() {
        let p = small_problem(Loss::Hinge);
        let w: Vec<f64> = (0..p.d()).map(|i| (i as f64).cos()).collect();
        let u = p.dual_witness(&w);
        for (i, &ui) in u.iter().enumerate() {
            assert!(p.loss.conjugate_neg(ui, p.data.y[i]).is_finite());
        }
    }
}
