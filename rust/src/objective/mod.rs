//! Primal/dual objectives and the duality-gap certificate (§2 of the paper).
//!
//! * Primal (1):  P(w) = (1/n) Σ ℓ_i(x_iᵀw) + (λ/2)‖w‖²
//! * Dual   (2):  D(α) = −(1/n) Σ ℓ*_i(−α_i) − (λ/2)‖Aα/(λn)‖²
//! * Map    (3):  w(α) = Aα/(λn)
//! * Gap    (4):  G(α) = P(w(α)) − D(α) ≥ 0   (weak duality)
//!
//! The gap is the paper's practical stopping certificate. Both data-sum
//! terms decompose over any partition of the rows, so the certificate is
//! computed as a **shard-partial reduction**: every shard contributes a
//! [`CertPartial`] (its Σℓ_i over local margins and Σℓ*_i over its dual
//! variables, via [`cert_partial`]) and
//! [`Problem::certificates_from_partials`] combines K partials with the
//! ‖w‖² term. Central evaluation is the one-shard special case — the same
//! code path the worker pool uses, just with K = 1 — which keeps the
//! pooled and sequential executors bit-identical.

use crate::data::Dataset;
use crate::linalg::{dense, CsrShard};
use crate::loss::Loss;
use std::sync::Arc;

/// Problem definition: dataset + loss + regularizer. The dataset sits
/// behind an `Arc` so the coordinator, the workers' shard views, and any
/// baseline share one copy; cloning a `Problem` clones a pointer, not the
/// data.
#[derive(Clone, Debug)]
pub struct Problem {
    pub data: Arc<Dataset>,
    pub loss: Loss,
    pub lambda: f64,
}

impl Problem {
    pub fn new(data: Dataset, loss: Loss, lambda: f64) -> Problem {
        Problem::shared(Arc::new(data), loss, lambda)
    }

    /// Build over an already-shared dataset (the zero-copy path used by
    /// the trainer's permuted-contiguous layout).
    pub fn shared(data: Arc<Dataset>, loss: Loss, lambda: f64) -> Problem {
        assert!(lambda > 0.0, "λ must be positive");
        Problem { data, loss, lambda }
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn d(&self) -> usize {
        self.data.d()
    }

    /// w(α) = Aα/(λn), writing into `w`.
    pub fn primal_from_dual(&self, alpha: &[f64], w: &mut [f64]) {
        assert_eq!(alpha.len(), self.n());
        assert_eq!(w.len(), self.d());
        self.data.x.matvec_t(alpha, w);
        dense::scale(1.0 / (self.lambda * self.n() as f64), w);
    }

    /// P(w) from scratch.
    pub fn primal_value(&self, w: &[f64]) -> f64 {
        let n = self.n();
        let mut loss_sum = 0.0;
        for i in 0..n {
            let z = self.data.x.row_dot(i, w);
            loss_sum += self.loss.value(z, self.data.y[i]);
        }
        loss_sum / n as f64 + 0.5 * self.lambda * dense::norm_sq(w)
    }

    /// P(w) given precomputed margins z_i = x_iᵀw.
    pub fn primal_value_from_margins(&self, margins: &[f64], w_norm_sq: f64) -> f64 {
        let n = self.n();
        assert_eq!(margins.len(), n);
        let mut loss_sum = 0.0;
        for i in 0..n {
            loss_sum += self.loss.value(margins[i], self.data.y[i]);
        }
        loss_sum / n as f64 + 0.5 * self.lambda * w_norm_sq
    }

    /// D(α) given w = w(α) (the caller maintains the invariant).
    pub fn dual_value(&self, alpha: &[f64], w: &[f64]) -> f64 {
        let n = self.n();
        assert_eq!(alpha.len(), n);
        let mut conj_sum = 0.0;
        for i in 0..n {
            let c = self.loss.conjugate_neg(alpha[i], self.data.y[i]);
            if c.is_infinite() {
                return f64::NEG_INFINITY; // dual-infeasible α
            }
            conj_sum += c;
        }
        -conj_sum / n as f64 - 0.5 * self.lambda * dense::norm_sq(w)
    }

    /// Duality gap G(α) = P(w(α)) − D(α), recomputing w(α) from scratch.
    pub fn duality_gap(&self, alpha: &[f64]) -> f64 {
        let mut w = vec![0.0; self.d()];
        self.primal_from_dual(alpha, &mut w);
        self.primal_value(&w) - self.dual_value(alpha, &w)
    }

    /// Primal, dual, and gap from a consistent (α, w) pair — the central
    /// (single-shard) case of the partial/combine protocol.
    pub fn certificates(&self, alpha: &[f64], w: &[f64]) -> Certificates {
        assert_eq!(alpha.len(), self.n());
        let partial = cert_partial(self.loss, self.data.x.as_shard(), &self.data.y, alpha, w);
        self.certificates_from_partials([partial], w)
    }

    /// Reduce shard partials plus the ‖w‖² term into certificates (the
    /// leader's side of the distributed gap evaluation). Partials must
    /// cover the n rows exactly once; they are summed in iteration order,
    /// so a fixed shard order gives bit-reproducible results.
    pub fn certificates_from_partials<I>(&self, partials: I, w: &[f64]) -> Certificates
    where
        I: IntoIterator<Item = CertPartial>,
    {
        assert_eq!(w.len(), self.d());
        let mut loss_sum = 0.0;
        let mut conj_sum = 0.0;
        for p in partials {
            loss_sum += p.loss_sum;
            conj_sum += p.conj_sum;
        }
        let n = self.n() as f64;
        let reg = 0.5 * self.lambda * dense::norm_sq(w);
        let primal = loss_sum / n + reg;
        // Any dual-infeasible coordinate drives conj_sum to +∞ → D = −∞,
        // matching `dual_value`'s early return. NaN (from NaN iterates)
        // propagates so the Driver's NaN guard still fires.
        let dual = if conj_sum == f64::INFINITY {
            f64::NEG_INFINITY
        } else {
            -conj_sum / n - reg
        };
        Certificates {
            primal,
            dual,
            gap: primal - dual,
        }
    }

    /// The dual witness vector u (Eq. 17): −u_i ∈ ∂ℓ_i(x_iᵀw).
    pub fn dual_witness(&self, w: &[f64]) -> Vec<f64> {
        (0..self.n())
            .map(|i| {
                let z = self.data.x.row_dot(i, w);
                self.loss.dual_witness(z, self.data.y[i])
            })
            .collect()
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Certificates {
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
}

/// One shard's contribution to the duality-gap certificate: the two
/// data-dependent sums of Eq. (1)/(2) restricted to the shard's rows.
/// Workers compute these in parallel over their own views; the leader
/// reduces K of them in worker-id order.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CertPartial {
    /// Σ_{i∈shard} ℓ(x_iᵀw; y_i) — primal loss over the shard's margins.
    pub loss_sum: f64,
    /// Σ_{i∈shard} ℓ*(−α_i; y_i) — dual conjugate sum; +∞ as soon as any
    /// local coordinate is dual-infeasible.
    pub conj_sum: f64,
}

/// Compute a shard's [`CertPartial`] against the shared `w`: one pass
/// computing the local margins z_i = x_iᵀw, the loss sum over them, and
/// the conjugate sum over the shard's dual variables. This is the single
/// code path used by the worker pool, the sequential executor, and
/// central evaluation — what makes all three produce identical partials.
pub fn cert_partial(
    loss: Loss,
    x: CsrShard<'_>,
    y: &[f64],
    alpha: &[f64],
    w: &[f64],
) -> CertPartial {
    assert_eq!(x.rows(), y.len());
    assert_eq!(x.rows(), alpha.len());
    // Margins via the blocked multi-row kernel (bit-identical to per-row
    // row_dot calls), then one pass accumulating the two sums in row
    // order. One margins buffer per certificate evaluation — certificate
    // cadence is per-round at most, never per-coordinate.
    let mut margins = vec![0.0; x.rows()];
    x.rows_dot(0, w, &mut margins);
    let mut loss_sum = 0.0;
    let mut conj_sum = 0.0;
    for ((&z, &yi), &ai) in margins.iter().zip(y).zip(alpha) {
        loss_sum += loss.value(z, yi);
        conj_sum += loss.conjugate_neg(ai, yi);
    }
    CertPartial { loss_sum, conj_sum }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::linalg::CsrMatrix;

    fn small_problem(loss: Loss) -> Problem {
        let data = generate(&SynthConfig::new("t", 40, 6).seed(11));
        Problem::new(data, loss, 0.1)
    }

    #[test]
    fn gap_nonnegative_at_zero_and_random_alpha() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
        ] {
            let p = small_problem(loss);
            let n = p.n();
            let zero = vec![0.0; n];
            let g0 = p.duality_gap(&zero);
            assert!(g0 >= -1e-10, "{}: gap at 0 = {g0}", loss.name());
            // feasible random alpha: b = y*α in [0,1]
            let alpha: Vec<f64> = (0..n).map(|i| p.data.y[i] * ((i % 10) as f64 / 10.0)).collect();
            let g = p.duality_gap(&alpha);
            assert!(g >= -1e-10, "{}: gap = {g}", loss.name());
        }
    }

    #[test]
    fn gap_at_zero_bounded_by_one() {
        // Lemma 17: D(α*) − D(0) ≤ 1, and P(0) − D(0) = (1/n)Σℓ_i(0) ≤ 1.
        for loss in [Loss::Hinge, Loss::SmoothedHinge { mu: 0.5 }, Loss::Logistic] {
            let p = small_problem(loss);
            let zero = vec![0.0; p.n()];
            let g0 = p.duality_gap(&zero);
            assert!(g0 <= 1.0 + 1e-9, "{}: {g0}", loss.name());
        }
    }

    #[test]
    fn infeasible_alpha_gives_neg_inf_dual() {
        let p = small_problem(Loss::Hinge);
        let mut alpha = vec![0.0; p.n()];
        alpha[0] = -5.0 * p.data.y[0]; // way outside [0,1] box
        let mut w = vec![0.0; p.d()];
        p.primal_from_dual(&alpha, &mut w);
        assert_eq!(p.dual_value(&alpha, &w), f64::NEG_INFINITY);
    }

    #[test]
    fn primal_from_margins_matches_scratch() {
        let p = small_problem(Loss::Hinge);
        let w: Vec<f64> = (0..p.d()).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut margins = vec![0.0; p.n()];
        p.data.x.matvec(&w, &mut margins);
        let a = p.primal_value(&w);
        let b = p.primal_value_from_margins(&margins, dense::norm_sq(&w));
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn squared_loss_analytic_optimum_has_zero_gap() {
        // Ridge regression on a tiny exactly-solvable problem: at the
        // optimal α the gap must vanish.
        // Problem: X = I (2×2), y = (1, 2), λ arbitrary.
        let x = CsrMatrix::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let data = Dataset::new("tiny", x, vec![1.0, 2.0]);
        let lambda = 0.5;
        let p = Problem::new(data, Loss::Squared, lambda);
        let n = 2.0;
        // For X=I: w_j = α_j/(λn); optimal primal w_j = y_j/(1+λn).
        // Optimal dual α_j = λn·y_j/(1+λn).
        let scale = lambda * n / (1.0 + lambda * n);
        let alpha = vec![scale * 1.0, scale * 2.0];
        let gap = p.duality_gap(&alpha);
        assert!(gap.abs() < 1e-10, "gap {gap}");
    }

    #[test]
    fn shard_partials_combine_to_central_certificates() {
        for loss in [
            Loss::Hinge,
            Loss::SmoothedHinge { mu: 0.5 },
            Loss::Logistic,
            Loss::Squared,
            Loss::Absolute,
        ] {
            let p = small_problem(loss);
            let n = p.n();
            let alpha: Vec<f64> = (0..n)
                .map(|i| p.data.y[i] * ((i % 10) as f64 / 10.0))
                .collect();
            let mut w = vec![0.0; p.d()];
            p.primal_from_dual(&alpha, &mut w);
            let central = p.certificates(&alpha, &w);
            // split the rows into 3 uneven shards
            let cuts = [0usize, n / 3, n / 2, n];
            let partials: Vec<CertPartial> = cuts
                .windows(2)
                .map(|c| {
                    cert_partial(
                        p.loss,
                        p.data.x.shard(c[0], c[1] - c[0]),
                        &p.data.y[c[0]..c[1]],
                        &alpha[c[0]..c[1]],
                        &w,
                    )
                })
                .collect();
            let combined = p.certificates_from_partials(partials, &w);
            assert!(
                (combined.primal - central.primal).abs() < 1e-12,
                "{}: primal {} vs {}",
                loss.name(),
                combined.primal,
                central.primal
            );
            assert!((combined.dual - central.dual).abs() < 1e-12, "{}", loss.name());
            assert!((combined.gap - central.gap).abs() < 1e-12, "{}", loss.name());
        }
    }

    #[test]
    fn infeasible_shard_partial_gives_neg_inf_dual() {
        let p = small_problem(Loss::Hinge);
        let mut alpha = vec![0.0; p.n()];
        alpha[1] = -3.0 * p.data.y[1];
        let w = vec![0.0; p.d()];
        let partial = cert_partial(p.loss, p.data.x.as_shard(), &p.data.y, &alpha, &w);
        assert_eq!(partial.conj_sum, f64::INFINITY);
        let certs = p.certificates_from_partials([partial], &w);
        assert_eq!(certs.dual, f64::NEG_INFINITY);
        assert_eq!(certs.gap, f64::INFINITY);
    }

    #[test]
    fn witness_is_feasible_for_lipschitz_losses() {
        let p = small_problem(Loss::Hinge);
        let w: Vec<f64> = (0..p.d()).map(|i| (i as f64).cos()).collect();
        let u = p.dual_witness(&w);
        for (i, &ui) in u.iter().enumerate() {
            assert!(p.loss.conjugate_neg(ui, p.data.y[i]).is_finite());
        }
    }
}
