//! `cocoa` CLI — leader entrypoint for training runs, dataset generation,
//! partition diagnostics, and paper-experiment regeneration.
//!
//! Subcommands:
//!   train        train with any optimizer (--method) on synthetic or LibSVM data
//!   gen-data     write a synthetic dataset in LibSVM format
//!   sigma        report partition constants σ_k, σ, and the Table-1 ratio
//!   experiment   regenerate a paper table/figure: table1|table2|fig1|fig2|fig3|rates|all
//!   artifacts-check   load + smoke-run the AOT artifacts via PJRT
//!   serve        HTTP prediction service from a training checkpoint
//!   trace-check  validate a --trace-out flight-recorder file
//!   trace-summary  per-phase wall-clock budget table of a --trace-out file
//!   worker       internal: socket-executor worker process (spawned by the leader)
//!
//! Run `cocoa help` for flags.

use cocoa::driver::{build_method, CsvStream, ProgressLog};
use cocoa::prelude::*;
use cocoa::serve::{serve, Model, ServeConfig};
use cocoa::telemetry::Recorder;
use cocoa::util::cli::Args;
use cocoa::util::logging;

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    if let Some(level) = args.get_opt("log").and_then(logging::parse_level) {
        logging::set_level(level);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => cmd_train(&args),
        "gen-data" => cmd_gen_data(&args),
        "sigma" => cmd_sigma(&args),
        "experiment" => cocoa::experiments::run_from_cli(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "serve" => cmd_serve(&args),
        "trace-check" => cmd_trace_check(&args),
        "trace-summary" => cmd_trace_summary(&args),
        "worker" => cocoa::coordinator::socket::worker_main(&args),
        "help" | "--help" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "cocoa — CoCoA+ distributed primal-dual optimization (ICML 2015 reproduction)

USAGE: cocoa <SUBCOMMAND> [flags]

SUBCOMMANDS
  train            --data <path.svm> | --dataset <covtype|epsilon|rcv1|news|real-sim>
                   --method <{methods}>
                   --k <workers> --lambda <λ> --loss <hinge|smoothed_hinge|logistic|squared>
                   --rounds <max> --gap-tol <ε> --gap-every <N certificate cadence>
                   --scale <dataset downscale> --seed <s>
                   CoCoA variants: --sigma-prime <σ'> --epochs <local epochs>
                                   --parallel <true|false>  (--variant <plus|avg> still accepted)
                                   --executor <auto|sequential|pooled|socket>  (socket = worker processes)
                   mb-* variants:  --batch <per-worker batch size>  (mb-sdca: --beta <scaling>)
                   admm:           --rho <penalty> --local-iters <inner steps>
                   --checkpoint-out <path>   write the full primal-dual state (w, α) after
                                             the run (cocoa-plus|cocoa only) for `serve`
                   --trace-out <path>        record a Chrome trace-event file of the run
                                             (open in Perfetto / chrome://tracing); with
                                             --executor socket also prints the measured-vs-
                                             simulated communication report
                   History streams to results/train/<method>_<dataset>.csv while running.
  gen-data         --dataset <name> --scale <s> --seed <s> --out <path.svm>
  sigma            --dataset <name> --scale <s> --ks 16,32,64 --seed <s>
  experiment       table1|table2|fig1|fig2|fig3|rates|ablation|all  [--quick] [--scale s]
  artifacts-check  --artifacts <dir>
  serve            --checkpoint <path> [--addr 127.0.0.1:8080] [--threads <n>]
                   [--read-timeout-ms <ms>] [--trace-out <path>]
                   HTTP prediction service: GET /healthz /metrics, POST /predict
                   /reload /retrain /quit (see rustdoc for body shapes)
  trace-check      <trace.json>  validate a --trace-out file (fields + span nesting)
  trace-summary    <trace.json>  aggregate a --trace-out file into a per-phase
                   wall-clock budget table (round/broadcast/compute/barrier/
                   reduce/send/recv), sorted by total time
  worker           internal: spawned by the socket executor (--connect <addr> --worker <id>)

GLOBAL FLAGS
  --log <error|warn|info|debug|trace>   (or COCOA_LOG env var)
  Results are written under ./results (or COCOA_RESULTS_DIR).",
        methods = MethodName::usage()
    );
}

fn load_data(args: &Args) -> Dataset {
    if let Some(path) = args.get_opt("data") {
        cocoa::data::libsvm::load(std::path::Path::new(path), None)
            .unwrap_or_else(|e| panic!("failed to load {path}: {e}"))
    } else {
        let name = args.get_str("dataset", "covtype");
        let scale = args.get_f64("scale", 500.0);
        let seed = args.get_u64("seed", 42);
        cocoa::data::synth::paper_dataset(&name, scale, seed)
    }
}

/// Replace path-hostile characters in a dataset label so it can name an
/// output file (`--data some/path.svm` keeps only the final component).
fn file_label(name: &str) -> String {
    let base = name.rsplit(['/', '\\']).next().unwrap_or(name);
    base.chars()
        .map(|c| {
            if c.is_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

fn cmd_train(args: &Args) -> i32 {
    // --method selects any optimizer; the legacy --variant plus|avg flag
    // keeps selecting between the two CoCoA regimes when --method is
    // absent. Validated before the (possibly expensive) data step.
    let method_name = match args.get_opt("method") {
        Some(s) => MethodName::parse(s)
            .unwrap_or_else(|| panic!("unknown --method {s:?} ({})", MethodName::usage())),
        None => match args.get_str("variant", "plus").as_str() {
            "plus" | "add" => MethodName::CocoaPlus,
            "avg" | "cocoa" => MethodName::Cocoa,
            other => panic!("unknown --variant {other:?} (plus|avg)"),
        },
    };

    let data = load_data(args);
    let n = data.n();
    let k = args.get_usize("k", 8);
    let lambda = args.get_f64("lambda", 1e-4);
    let loss = Loss::parse(&args.get_str("loss", "hinge")).expect("unknown --loss");
    let seed = args.get_u64("seed", 42);

    let mut opts = BuildOpts::new(k);
    opts.seed = seed;
    // --epochs means local epochs per round for CoCoA variants and total
    // local epochs for one-shot (whose useful default is much higher).
    let epochs_default = if method_name == MethodName::OneShot {
        50.0
    } else {
        1.0
    };
    opts.epochs = args.get_f64("epochs", epochs_default);
    opts.parallel = args.get_bool("parallel", true);
    if let Some(ex) = args.get_opt("executor") {
        opts.executor = ExecutorChoice::parse(ex)
            .unwrap_or_else(|| panic!("unknown --executor {ex:?} (auto|sequential|pooled|socket)"));
    }
    opts.batch_per_worker = args.get_usize("batch", 16);
    opts.beta = args.get_f64("beta", 1.0);
    opts.rho = args.get_f64("rho", 1.0);
    opts.local_iters = args.get_usize("local-iters", 50);
    if let Some(sp) = args.get_opt("sigma-prime") {
        opts.sigma_prime = Some(sp.parse().expect("--sigma-prime must be a float"));
    }
    let trace_out = args.get_opt("trace-out");
    let recorder = match trace_out {
        Some(path) => match Recorder::to_file(std::path::Path::new(path)) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cannot open trace file {path}: {e}");
                return 1;
            }
        },
        None => Recorder::disabled(),
    };
    opts.recorder = recorder.clone();

    let part = cocoa::data::partition::random_balanced(n, k, seed);
    let dataset_label = data.name.clone();
    println!(
        "method={} dataset={} n={} d={} density={:.4} | K={k} λ={lambda} loss={}",
        method_name.as_str(),
        dataset_label,
        n,
        data.d(),
        data.density(),
        loss.name()
    );
    let problem = Problem::new(data, loss, lambda);
    let mut method = build_method(method_name, problem, part, &opts);
    println!("series: {}", method.label());

    // One-shot averaging is a single communication round by construction,
    // and its gap certificate may legitimately be infinite (dual-infeasible
    // scaled α) — uncertifiable, not divergent.
    let one_shot = method_name == MethodName::OneShot;
    let max_rounds = if one_shot {
        1
    } else {
        args.get_usize("rounds", 100)
    };
    // Primal-only methods (mb-sgd, admm) have no dual certificate: their
    // gap column holds the raw primal value (no P* is available from the
    // CLI), so the gap tolerance only applies when explicitly requested.
    let primal_only = matches!(method_name, MethodName::MbSgd | MethodName::Admm);
    let gap_tol = if primal_only && !args.has("gap-tol") {
        f64::NEG_INFINITY
    } else {
        args.get_f64("gap-tol", 1e-4)
    };
    // Primal-only methods compare a raw primal objective, which can be a
    // legitimate finite value above any duality-gap-scale threshold:
    // match their run() wrappers and only flag true overflow.
    let divergence_default = if one_shot {
        f64::INFINITY
    } else if primal_only {
        f64::MAX
    } else {
        1e6
    };
    let stop = StopPolicy::new(max_rounds)
        .with_gap_tol(gap_tol)
        .with_divergence_gap(args.get_f64("divergence-gap", divergence_default));
    let mut driver = Driver::new(stop)
        .with_gap_every(args.get_usize("gap-every", 1))
        .with_recorder(&recorder)
        .with_observer(Box::new(ProgressLog::new(10)));

    // Outputs are named by method + dataset so comparison runs coexist.
    let out_path = cocoa::report::results_dir().join(format!(
        "train/{}_{}.csv",
        method_name.as_str(),
        file_label(&dataset_label)
    ));
    let mut streamed = false;
    match CsvStream::create(&out_path) {
        Ok(obs) => {
            driver = driver.with_observer(Box::new(obs));
            streamed = true;
        }
        Err(e) => eprintln!("warning: cannot stream history to {}: {e}", out_path.display()),
    }

    let hist = driver.run(method.as_mut());
    for r in &hist.records {
        println!(
            "round {:>4}  vecs {:>7}  sim_t {:>9.3}s  P {:.6e}  D {:.6e}  gap {:.6e}",
            r.round, r.comm_vectors, r.sim_time_s, r.primal, r.dual, r.gap
        );
    }
    let train_err = method
        .train_error()
        .map(|e| format!("{e:.4}"))
        .unwrap_or_else(|| "-".to_string());
    println!(
        "stopped: {:?}; final gap {:.3e}; train error {train_err}",
        hist.stop,
        hist.final_gap()
    );
    if let Some(notes) = method.runtime_notes() {
        println!("runtime: {notes}");
    }
    if let Some(report) = method.comm_report() {
        println!("{report}");
    }
    // The run summary renders through the same telemetry::metrics
    // registry `GET /metrics` uses — one implementation for both
    // reporting surfaces.
    let registry = cocoa::telemetry::metrics::Registry::new();
    registry
        .counter("train.rounds_total")
        .add(hist.rounds_run() as u64);
    registry
        .counter("train.comm_vectors_total")
        .add(hist.records.last().map_or(0, |r| r.comm_vectors as u64));
    // compute_s is cumulative per record; the deltas are the measured
    // compute between certificate evaluations (= per round at the
    // default --gap-every 1).
    let compute = registry.histogram("train.compute_per_eval_us");
    let mut prev_compute = 0.0f64;
    for r in &hist.records {
        let delta = (r.compute_s - prev_compute).max(0.0);
        compute.observe_us((delta * 1e6) as u64);
        prev_compute = r.compute_s;
    }
    for line in registry.summary_lines() {
        println!("metric {line}");
    }
    if streamed {
        println!("history written to {}", out_path.display());
    }
    if let Some(out) = args.get_opt("checkpoint-out") {
        match method.checkpoint() {
            Some(ck) => match ck.save(std::path::Path::new(out)) {
                Ok(()) => println!("checkpoint written to {out}"),
                Err(e) => {
                    eprintln!("cannot write checkpoint to {out}: {e}");
                    return 1;
                }
            },
            None => {
                eprintln!(
                    "--checkpoint-out: --method {} has no checkpointable dual state \
                     (use cocoa-plus or cocoa)",
                    method_name.as_str()
                );
                return 2;
            }
        }
    }
    if let Some(path) = trace_out {
        // The method and driver own the last un-flushed rings; drop them
        // so every buffered event reaches the file before the trailer.
        drop(method);
        drop(driver);
        match recorder.finish() {
            Ok(sum) => println!(
                "trace written to {path}: {} event(s), {} dropped",
                sum.events, sum.dropped
            ),
            Err(e) => {
                eprintln!("cannot finalize trace {path}: {e}");
                return 1;
            }
        }
    }
    0
}

/// `cocoa trace-check`: validate a `--trace-out` file (required fields,
/// per-lane span nesting) and print its summary.
fn cmd_trace_check(args: &Args) -> i32 {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: cocoa trace-check <trace.json>");
        return 2;
    };
    match cocoa::telemetry::checker::check_file(std::path::Path::new(path)) {
        Ok(check) => {
            println!(
                "{path}: OK — {} event(s) on {} lane(s), max nesting depth {}, {} dropped",
                check.events, check.lanes, check.max_depth, check.dropped
            );
            0
        }
        Err(e) => {
            eprintln!("{path}: INVALID — {e}");
            1
        }
    }
}

/// `cocoa trace-summary`: aggregate a `--trace-out` file into a per-phase
/// wall-clock budget table (where did the round's time actually go?).
fn cmd_trace_summary(args: &Args) -> i32 {
    let Some(path) = args.positional.get(1) else {
        eprintln!("usage: cocoa trace-summary <trace.json>");
        return 2;
    };
    match cocoa::telemetry::summary::summarize_file(std::path::Path::new(path)) {
        Ok(budget) => {
            print!("{}", budget.render());
            0
        }
        Err(e) => {
            eprintln!("{path}: cannot summarize — {e}");
            1
        }
    }
}

/// `cocoa serve`: load a checkpoint, rebuild the model, and serve
/// predictions over HTTP until `POST /quit`.
fn cmd_serve(args: &Args) -> i32 {
    let Some(ck_path) = args.get_opt("checkpoint") else {
        eprintln!(
            "serve needs --checkpoint <path> (produce one with `cocoa train --checkpoint-out`)"
        );
        return 2;
    };
    let ck = match cocoa::coordinator::checkpoint::Checkpoint::load(std::path::Path::new(ck_path)) {
        Ok(ck) => ck,
        Err(e) => {
            eprintln!("cannot load checkpoint {ck_path}: {e}");
            return 1;
        }
    };
    let model = match Model::from_checkpoint(ck, ck_path) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("checkpoint {ck_path} is not servable: {e}");
            return 1;
        }
    };
    println!(
        "model: loss={} d={} n_train={} lambda={} ({})",
        model.loss.name(),
        model.d(),
        model.n_train,
        model.lambda,
        model.source
    );
    let mut cfg = ServeConfig::new(&args.get_str("addr", "127.0.0.1:8080"));
    cfg.threads = args.get_usize("threads", cfg.threads).max(1);
    let timeout_ms = args.get_u64("read-timeout-ms", cfg.read_timeout.as_millis() as u64);
    cfg.read_timeout = std::time::Duration::from_millis(timeout_ms.max(1));
    let trace_out = args.get_opt("trace-out");
    let recorder = match trace_out {
        Some(path) => match Recorder::to_file(std::path::Path::new(path)) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cannot open trace file {path}: {e}");
                return 1;
            }
        },
        None => Recorder::disabled(),
    };
    cfg.trace = recorder.clone();
    let handle = match serve(model, cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("cannot bind server: {e}");
            return 1;
        }
    };
    // Tests and scripts parse this line for the actual port (--addr
    // host:0 lets the kernel pick); stdout is line-buffered even piped.
    println!(
        "serving on http://{}/  (GET /healthz /metrics; POST /predict /reload /retrain /quit)",
        handle.addr()
    );
    handle.wait();
    println!("server stopped");
    if let Some(path) = trace_out {
        // wait() already sealed the file (ServerHandle finishes its
        // recorder after joining the workers); this reads the totals.
        match recorder.finish() {
            Ok(sum) => println!(
                "trace written to {path}: {} event(s), {} dropped",
                sum.events, sum.dropped
            ),
            Err(e) => {
                eprintln!("cannot finalize trace {path}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_gen_data(args: &Args) -> i32 {
    let name = args.get_str("dataset", "covtype");
    let scale = args.get_f64("scale", 500.0);
    let seed = args.get_u64("seed", 42);
    let out = args.get_str("out", "data.svm");
    let data = cocoa::data::synth::paper_dataset(&name, scale, seed);
    cocoa::data::libsvm::save(&data, std::path::Path::new(&out)).expect("write failed");
    println!(
        "wrote {}: n={} d={} density={:.4}",
        out,
        data.n(),
        data.d(),
        data.density()
    );
    0
}

fn cmd_sigma(args: &Args) -> i32 {
    let data = load_data(args);
    let n = data.n();
    let ks = args.get_usize_list("ks", &[4, 8, 16]);
    let seed = args.get_u64("seed", 42);
    println!("dataset={} n={} d={}", data.name, n, data.d());
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>10}",
        "K", "sigma=Σσ_k·n_k", "n²/K bound", "ratio", "σ_max"
    );
    for &k in &ks {
        if k > n {
            continue;
        }
        let part = cocoa::data::partition::random_balanced(n, k, seed);
        let ps = cocoa::subproblem::sigma::partition_sigma(&data, &part, seed);
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>14.3} {:>10.3}",
            k,
            ps.sigma_sum,
            (n * n) as f64 / k as f64,
            ps.table1_ratio(n),
            ps.sigma_max()
        );
    }
    0
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check(_args: &Args) -> i32 {
    eprintln!(
        "artifacts-check needs the PJRT runtime, which this build excludes: the `xla` \
         feature additionally requires the unvendored xla/anyhow/thiserror crates, so it \
         only builds in an environment with those dependencies available (see rust/Cargo.toml)"
    );
    2
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check(args: &Args) -> i32 {
    let dir = args.get_str("artifacts", "artifacts");
    match cocoa::runtime::artifact::Manifest::load(std::path::Path::new(&dir)) {
        Ok(manifest) => {
            println!("manifest OK: {} artifacts", manifest.entries.len());
            match cocoa::runtime::smoke_test(&manifest) {
                Ok(report) => {
                    println!("{report}");
                    0
                }
                Err(e) => {
                    eprintln!("artifact execution failed: {e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("failed to load artifacts from {dir}: {e} (run `make artifacts`)");
            1
        }
    }
}
