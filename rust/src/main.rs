//! `cocoa` CLI — leader entrypoint for training runs, dataset generation,
//! partition diagnostics, and paper-experiment regeneration.
//!
//! Subcommands:
//!   train        train a model with CoCoA/CoCoA+ on synthetic or LibSVM data
//!   gen-data     write a synthetic dataset in LibSVM format
//!   sigma        report partition constants σ_k, σ, and the Table-1 ratio
//!   experiment   regenerate a paper table/figure: table1|table2|fig1|fig2|fig3|rates|all
//!   artifacts-check   load + smoke-run the AOT artifacts via PJRT
//!
//! Run `cocoa <subcommand> --help` for flags.

use cocoa::prelude::*;
use cocoa::util::cli::Args;
use cocoa::util::logging;

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    if let Some(level) = args.get_opt("log").and_then(logging::parse_level) {
        logging::set_level(level);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "train" => cmd_train(&args),
        "gen-data" => cmd_gen_data(&args),
        "sigma" => cmd_sigma(&args),
        "experiment" => cocoa::experiments::run_from_cli(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "help" | "--help" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "cocoa — CoCoA+ distributed primal-dual optimization (ICML 2015 reproduction)

USAGE: cocoa <SUBCOMMAND> [flags]

SUBCOMMANDS
  train            --data <path.svm> | --dataset <covtype|epsilon|rcv1|news|real-sim>
                   --k <workers> --lambda <λ> --loss <hinge|smoothed_hinge|logistic|squared>
                   --variant <plus|avg> --sigma-prime <σ'> --epochs <local epochs>
                   --rounds <max> --gap-tol <ε> --scale <dataset downscale> --seed <s>
  gen-data         --dataset <name> --scale <s> --seed <s> --out <path.svm>
  sigma            --dataset <name> --scale <s> --ks 16,32,64 --seed <s>
  experiment       table1|table2|fig1|fig2|fig3|rates|all  [--quick] [--scale s]
  artifacts-check  --artifacts <dir>

GLOBAL FLAGS
  --log <error|warn|info|debug|trace>   (or COCOA_LOG env var)
  Results are written under ./results (or COCOA_RESULTS_DIR)."
    );
}

fn load_data(args: &Args) -> Dataset {
    if let Some(path) = args.get_opt("data") {
        cocoa::data::libsvm::load(std::path::Path::new(path), None)
            .unwrap_or_else(|e| panic!("failed to load {path}: {e}"))
    } else {
        let name = args.get_str("dataset", "covtype");
        let scale = args.get_f64("scale", 500.0);
        let seed = args.get_u64("seed", 42);
        cocoa::data::synth::paper_dataset(&name, scale, seed)
    }
}

fn cmd_train(args: &Args) -> i32 {
    let data = load_data(args);
    let n = data.n();
    let k = args.get_usize("k", 8);
    let lambda = args.get_f64("lambda", 1e-4);
    let loss = Loss::parse(&args.get_str("loss", "hinge")).expect("unknown --loss");
    let seed = args.get_u64("seed", 42);
    let epochs = args.get_f64("epochs", 1.0);
    let variant = args.get_str("variant", "plus");

    let part = cocoa::data::partition::random_balanced(n, k, seed);
    let solver = SolverSpec::SdcaEpochs { epochs };
    let mut cfg = match variant.as_str() {
        "plus" | "add" => CocoaConfig::cocoa_plus(k, loss, lambda, solver),
        "avg" | "cocoa" => CocoaConfig::cocoa(k, loss, lambda, solver),
        other => panic!("unknown --variant {other:?} (plus|avg)"),
    }
    .with_rounds(args.get_usize("rounds", 100))
    .with_gap_tol(args.get_f64("gap-tol", 1e-4))
    .with_seed(seed);
    if let Some(sp) = args.get_opt("sigma-prime") {
        cfg = cfg.with_sigma_prime(sp.parse().expect("--sigma-prime must be a float"));
    }

    println!(
        "dataset={} n={} d={} density={:.4} | K={k} λ={lambda} loss={} γ={} σ'={}",
        data.name,
        n,
        data.d(),
        data.density(),
        loss.name(),
        cfg.gamma(),
        cfg.effective_sigma_prime()
    );
    let problem = Problem::new(data, loss, lambda);
    let mut trainer = Trainer::new(problem, part, cfg);
    let hist = trainer.run();
    for r in &hist.records {
        println!(
            "round {:>4}  vecs {:>7}  sim_t {:>9.3}s  P {:.6e}  D {:.6e}  gap {:.6e}",
            r.round, r.comm_vectors, r.sim_time_s, r.primal, r.dual, r.gap
        );
    }
    println!(
        "stopped: {:?}; final gap {:.3e}; train error {:.4}",
        hist.stop,
        hist.final_gap(),
        trainer.problem.data.classification_error(&trainer.w)
    );
    println!(
        "runtime: {} executor; {}",
        trainer.executor_kind(),
        trainer.comm_stats().runtime_summary()
    );
    let csv = hist.to_csv();
    if let Ok(p) = cocoa::report::write_result("train/last_run.csv", &csv) {
        println!("history written to {}", p.display());
    }
    0
}

fn cmd_gen_data(args: &Args) -> i32 {
    let name = args.get_str("dataset", "covtype");
    let scale = args.get_f64("scale", 500.0);
    let seed = args.get_u64("seed", 42);
    let out = args.get_str("out", "data.svm");
    let data = cocoa::data::synth::paper_dataset(&name, scale, seed);
    cocoa::data::libsvm::save(&data, std::path::Path::new(&out)).expect("write failed");
    println!(
        "wrote {}: n={} d={} density={:.4}",
        out,
        data.n(),
        data.d(),
        data.density()
    );
    0
}

fn cmd_sigma(args: &Args) -> i32 {
    let data = load_data(args);
    let n = data.n();
    let ks = args.get_usize_list("ks", &[4, 8, 16]);
    let seed = args.get_u64("seed", 42);
    println!("dataset={} n={} d={}", data.name, n, data.d());
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>10}",
        "K", "sigma=Σσ_k·n_k", "n²/K bound", "ratio", "σ_max"
    );
    for &k in &ks {
        if k > n {
            continue;
        }
        let part = cocoa::data::partition::random_balanced(n, k, seed);
        let ps = cocoa::subproblem::sigma::partition_sigma(&data, &part, seed);
        println!(
            "{:>6} {:>14.2} {:>14.2} {:>14.3} {:>10.3}",
            k,
            ps.sigma_sum,
            (n * n) as f64 / k as f64,
            ps.table1_ratio(n),
            ps.sigma_max()
        );
    }
    0
}

#[cfg(not(feature = "xla"))]
fn cmd_artifacts_check(_args: &Args) -> i32 {
    eprintln!(
        "artifacts-check needs the PJRT runtime, which this build excludes: the `xla` \
         feature additionally requires the unvendored xla/anyhow/thiserror crates, so it \
         only builds in an environment with those dependencies available (see rust/Cargo.toml)"
    );
    2
}

#[cfg(feature = "xla")]
fn cmd_artifacts_check(args: &Args) -> i32 {
    let dir = args.get_str("artifacts", "artifacts");
    match cocoa::runtime::artifact::Manifest::load(std::path::Path::new(&dir)) {
        Ok(manifest) => {
            println!("manifest OK: {} artifacts", manifest.entries.len());
            match cocoa::runtime::smoke_test(&manifest) {
                Ok(report) => {
                    println!("{report}");
                    0
                }
                Err(e) => {
                    eprintln!("artifact execution failed: {e}");
                    1
                }
            }
        }
        Err(e) => {
            eprintln!("failed to load artifacts from {dir}: {e} (run `make artifacts`)");
            1
        }
    }
}
