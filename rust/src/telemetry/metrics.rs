//! Shared metrics primitives: relaxed-atomic counters, gauges, and
//! fixed log-spaced histograms, plus a name-indexed [`Registry`] that
//! renders a stable JSON snapshot. This generalizes what
//! `serve/metrics.rs` hand-rolled for the HTTP layer so the training
//! CLI summary and `GET /metrics` read through one implementation.
//!
//! Recording is always a single relaxed atomic op — metrics must cost
//! the predict and round hot paths nanoseconds — and snapshots are
//! read relaxed and independently: momentarily inconsistent under
//! load, monotone per metric, which is all a scraper needs.

use crate::util::json::{jarr, jnum, jobj, jstr, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram bucket upper bounds in microseconds (log-spaced); a final
/// implicit +∞ bucket catches the rest. Fixed buckets keep recording a
/// single atomic increment.
pub const BUCKET_US: [u64; 10] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 100_000, 1_000_000,
];

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Current-level gauge (queue depth, in-flight requests). Decrements
/// saturate at zero so a spurious extra `dec` cannot wrap to 2⁶⁴−1.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .0
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed log-spaced latency histogram over [`BUCKET_US`] plus an
/// overflow bucket, with a running sum and count.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKET_US.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation in microseconds. Bucket bounds are
    /// inclusive upper edges (an exact 50µs lands in `le=50`).
    pub fn observe_us(&self, us: u64) {
        let idx = BUCKET_US.partition_point(|&le| us > le);
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Per-bucket counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }

    /// `{buckets: [{le_us, count}...], sum_us, count}` — the exact
    /// shape `GET /metrics` has always rendered for `latency`.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, count)| {
                let le = match BUCKET_US.get(i) {
                    Some(&b) => jnum(b as f64),
                    None => jstr("inf"),
                };
                jobj(vec![
                    ("le_us", le),
                    ("count", jnum(count.load(Ordering::Relaxed) as f64)),
                ])
            })
            .collect();
        jobj(vec![
            ("buckets", jarr(buckets)),
            ("sum_us", jnum(self.sum_us() as f64)),
            ("count", jnum(self.count() as f64)),
        ])
    }
}

/// One registered metric: a shared handle plus its kind.
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A name-indexed collection of metrics. Handles are `Arc`s handed out
/// once (get-or-create) and then recorded through lock-free; the inner
/// lock is only taken on registration and snapshot. Names sort
/// lexicographically in the snapshot so output is stable.
#[derive(Debug, Default)]
pub struct Registry {
    entries: Mutex<Vec<(String, Metric)>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn lookup(&self, name: &str) -> Option<Metric> {
        let entries = match self.entries.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| m.clone())
    }

    fn register(&self, name: &str, metric: Metric) {
        let mut entries = match self.entries.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if !entries.iter().any(|(n, _)| n == name) {
            entries.push((name.to_string(), metric));
        }
    }

    /// Get or create the counter registered under `name`. A name
    /// already registered with a different kind yields a fresh
    /// unregistered handle (first registration wins) rather than a
    /// panic — metric names are code, not input.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(Metric::Counter(c)) = self.lookup(name) {
            return c;
        }
        let c = Arc::new(Counter::new());
        self.register(name, Metric::Counter(c.clone()));
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(Metric::Gauge(g)) = self.lookup(name) {
            return g;
        }
        let g = Arc::new(Gauge::new());
        self.register(name, Metric::Gauge(g.clone()));
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(Metric::Histogram(h)) = self.lookup(name) {
            return h;
        }
        let h = Arc::new(Histogram::new());
        self.register(name, Metric::Histogram(h.clone()));
        h
    }

    /// Snapshot every registered metric as one JSON object, names
    /// sorted. Counters/gauges render as numbers, histograms as the
    /// `{buckets, sum_us, count}` object.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(String, Metric)> = {
            let g = match self.entries.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            g.clone()
        };
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let mut out = Json::obj();
        for (name, metric) in &entries {
            let val = match metric {
                Metric::Counter(c) => jnum(c.get() as f64),
                Metric::Gauge(g) => jnum(g.get() as f64),
                Metric::Histogram(h) => h.to_json(),
            };
            out.set(name, val);
        }
        out
    }

    /// One `name=value` line per metric (histograms summarized as
    /// `count/mean_us`), names sorted — the training CLI summary.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut entries: Vec<(String, Metric)> = {
            let g = match self.entries.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            g.clone()
        };
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        entries
            .iter()
            .map(|(name, metric)| match metric {
                Metric::Counter(c) => format!("{name}={}", c.get()),
                Metric::Gauge(g) => format!("{name}={}", g.get()),
                Metric::Histogram(h) => {
                    let count = h.count();
                    let mean = if count > 0 {
                        h.sum_us() as f64 / count as f64
                    } else {
                        0.0
                    };
                    format!("{name}: count={count} mean_us={mean:.1}")
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);

        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // saturates, no wrap
        assert_eq!(g.get(), 0);

        let h = Histogram::new();
        h.observe_us(80);
        h.observe_us(3);
        h.observe_us(50); // inclusive upper edge
        h.observe_us(2_000_000); // overflow bucket
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), BUCKET_US.len() + 1);
        assert_eq!(counts[0], 2, "le=50 bucket: {counts:?}");
        assert_eq!(counts[1], 1, "le=100 bucket: {counts:?}");
        assert_eq!(counts[BUCKET_US.len()], 1, "+∞ bucket: {counts:?}");
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_us(), 80 + 3 + 50 + 2_000_000);
    }

    #[test]
    fn registry_get_or_create_shares_handles() {
        let r = Registry::new();
        let a = r.counter("wire.frames_sent");
        let b = r.counter("wire.frames_sent");
        a.add(3);
        b.add(2);
        assert_eq!(a.get(), 5, "same name must share one counter");
        let j = r.to_json();
        assert_eq!(j.get("wire.frames_sent").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn registry_snapshot_is_sorted_and_typed() {
        let r = Registry::new();
        r.gauge("z.depth").set(7);
        r.counter("a.total").add(1);
        r.histogram("m.latency").observe_us(10);
        let lines = r.summary_lines();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a.total="), "{lines:?}");
        assert!(lines[1].starts_with("m.latency:"), "{lines:?}");
        assert!(lines[2].starts_with("z.depth="), "{lines:?}");
        let j = r.to_json();
        assert!(j.get("m.latency").unwrap().get("buckets").is_some());
    }

    #[test]
    fn registry_concurrent_recording_loses_nothing() {
        // The metrics-registry concurrency contract: N threads hammer
        // shared handles; every increment must land.
        let r = std::sync::Arc::new(Registry::new());
        let threads: u64 = 8;
        let per_thread: u64 = 5_000;
        let mut joins = Vec::new();
        for t in 0..threads {
            let r = r.clone();
            joins.push(std::thread::spawn(move || {
                let c = r.counter("hammer.total");
                let g = r.gauge("hammer.flight");
                let h = r.histogram("hammer.lat");
                for i in 0..per_thread {
                    c.inc();
                    g.inc();
                    h.observe_us((t * 37 + i) % 2_000);
                    g.dec();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(r.counter("hammer.total").get(), threads * per_thread);
        assert_eq!(r.gauge("hammer.flight").get(), 0);
        let h = r.histogram("hammer.lat");
        assert_eq!(h.count(), threads * per_thread);
        let bucket_sum: u64 = h.bucket_counts().iter().sum();
        assert_eq!(bucket_sum, threads * per_thread, "every observation bucketed");
    }
}
