//! Flight recorder: dependency-free span/event tracing and shared
//! metrics for every runtime in the crate.
//!
//! The design is observe-only by construction:
//!
//! * Each traced actor (the Driver's leader loop, every pool worker
//!   thread, the socket leader, every serve worker) owns a private
//!   [`Ring`] — a bounded event buffer flushed by the owning thread, so
//!   recording a span is a clock read plus a `Vec` push with **no
//!   cross-thread synchronization** on the hot path. Rings only take
//!   the shared sink lock when full (or on drop), never per event.
//! * Timestamps come exclusively from
//!   [`crate::util::timer::trace_now_us`] — the one sanctioned
//!   wall-clock read — so the `determinism` lint invariant (no ad-hoc
//!   clock reads on the training path) holds for this module too, and
//!   recorded time can never feed back into control flow.
//! * Export is a **streaming** Chrome trace-event JSON file
//!   ([`writer::TraceWriter`], loadable in Perfetto or
//!   `chrome://tracing`): events are written incrementally as rings
//!   flush; nothing is materialized. `cocoa train --trace-out
//!   trace.json` and `cocoa serve --trace-out trace.json` enable it,
//!   and `cocoa trace-check` ([`checker`]) validates the result.
//!
//! Logical thread ids are stable across executors: tid 0 is the
//! driver/leader, tid 1+k is worker k (thread, process, or serve
//! worker). The `rust/tests/determinism.rs` suite re-runs the
//! three-executor bit-identity invariant with tracing enabled, locking
//! in that the recorder perturbs nothing.
//!
//! [`metrics`] generalizes the serve layer's relaxed-atomic counters
//! and log-spaced histograms into a [`metrics::Registry`] shared by
//! `GET /metrics` and the training CLI summary.

pub mod checker;
pub mod metrics;
pub mod ring;
pub mod summary;
pub mod writer;

pub use ring::{Ring, TraceEvent};

use std::fs::File;
use std::io::BufWriter;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use writer::TraceWriter;

/// What a finished recorder reports: how many events reached the file
/// and how many were dropped (sink closed early or I/O error).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    pub events: u64,
    pub dropped: u64,
}

/// The sink every [`Ring`] flushes into. Private: rings and the
/// recorder are the only doors.
pub(crate) struct Shared {
    sink: Mutex<Option<TraceWriter<BufWriter<File>>>>,
    events: AtomicU64,
    dropped: AtomicU64,
}

impl Shared {
    /// Drain `buf` into the sink. Called by the owning thread of a ring
    /// (flush-on-full, or on ring drop); the only lock in the subsystem.
    pub(crate) fn flush(&self, buf: &mut Vec<TraceEvent>) {
        if buf.is_empty() {
            return;
        }
        let mut guard = match self.sink.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(w) = guard.as_mut() {
            let mut written = 0u64;
            let mut failed = false;
            for ev in buf.drain(..) {
                if failed {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                match w.write_event(&ev) {
                    Ok(()) => written += 1,
                    Err(_) => {
                        failed = true;
                        self.dropped.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            self.events.fetch_add(written, Ordering::Relaxed);
            if failed {
                // An I/O error on the sink disables tracing for the rest
                // of the run; the run itself must never be affected.
                *guard = None;
                crate::log_warn!("telemetry: trace sink I/O error; tracing disabled");
            }
        } else {
            self.dropped.fetch_add(buf.len() as u64, Ordering::Relaxed);
            buf.clear();
        }
    }
}

/// Handle to a trace session. Cheap to clone (all clones share one
/// sink); [`Recorder::disabled`] is a zero-cost no-op recorder so
/// untraced runs pay nothing — every config embeds one by default.
#[derive(Clone, Default)]
pub struct Recorder {
    shared: Option<Arc<Shared>>,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.shared.is_some() {
            f.write_str("Recorder(enabled)")
        } else {
            f.write_str("Recorder(disabled)")
        }
    }
}

impl Recorder {
    /// A recorder that records nothing: `ring()` hands out no-op rings
    /// whose every method returns immediately.
    pub fn disabled() -> Recorder {
        Recorder { shared: None }
    }

    /// Open `path` (truncating) and stream a Chrome trace-event file
    /// into it. The file is completed by [`Recorder::finish`].
    pub fn to_file(path: &Path) -> std::io::Result<Recorder> {
        let out = BufWriter::new(File::create(path)?);
        let writer = TraceWriter::new(out)?;
        Ok(Recorder {
            shared: Some(Arc::new(Shared {
                sink: Mutex::new(Some(writer)),
                events: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            })),
        })
    }

    pub fn enabled(&self) -> bool {
        self.shared.is_some()
    }

    /// A per-actor event buffer writing under logical thread id `tid`
    /// (0 = driver/leader, 1+k = worker k). Hand each thread its own.
    pub fn ring(&self, tid: u32) -> Ring {
        Ring::new(tid, self.shared.clone())
    }

    /// Close the JSON file (writes the trailer) and report totals.
    /// Idempotent: later calls (and late ring flushes) are counted as
    /// dropped instead of corrupting the file. All rings should be
    /// dropped (flushed) before calling this.
    pub fn finish(&self) -> std::io::Result<TraceSummary> {
        let Some(shared) = self.shared.as_ref() else {
            return Ok(TraceSummary::default());
        };
        let mut guard = match shared.sink.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(w) = guard.take() {
            let dropped = shared.dropped.load(Ordering::Relaxed);
            w.finish(dropped)?;
        }
        Ok(TraceSummary {
            events: shared.events.load(Ordering::Relaxed),
            dropped: shared.dropped.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = Recorder::disabled();
        assert!(!rec.enabled());
        let mut ring = rec.ring(3);
        assert!(!ring.enabled());
        assert_eq!(ring.now(), 0);
        let t = ring.now();
        ring.complete("x", "test", t, None);
        ring.flush();
        let sum = rec.finish().unwrap();
        assert_eq!(sum, TraceSummary::default());
    }

    #[test]
    fn file_recorder_round_trips_through_checker() {
        let path = std::env::temp_dir().join("cocoa_telemetry_mod_test.json");
        let rec = Recorder::to_file(&path).unwrap();
        assert!(rec.enabled());
        {
            let mut ring = rec.ring(0);
            let t0 = ring.now();
            let mut inner = rec.ring(1);
            let t1 = inner.now();
            inner.complete("compute", "worker", t1, Some(("round", 0.0)));
            drop(inner);
            ring.complete("round", "driver", t0, Some(("round", 0.0)));
            ring.instant("marker", "test", None);
        } // rings flush on drop
        let sum = rec.finish().unwrap();
        assert_eq!(sum.events, 3);
        assert_eq!(sum.dropped, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let check = crate::telemetry::checker::check_str(&text).unwrap();
        assert_eq!(check.events, 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flush_after_finish_counts_dropped() {
        let path = std::env::temp_dir().join("cocoa_telemetry_drop_test.json");
        let rec = Recorder::to_file(&path).unwrap();
        let mut ring = rec.ring(0);
        ring.instant("early", "test", None);
        ring.flush();
        rec.finish().unwrap();
        ring.instant("late", "test", None);
        ring.flush();
        let sum = rec.finish().unwrap();
        assert_eq!(sum.events, 1);
        assert_eq!(sum.dropped, 1);
        // the file stayed valid despite the late event
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::telemetry::checker::check_str(&text).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
