//! Trace-file validation: parses a Chrome trace-event JSON document and
//! checks the structural contract the recorder promises — required
//! fields on every event, and **well-formed span nesting** per logical
//! thread (complete events on one `(pid, tid)` lane either nest or are
//! disjoint; partial overlap means a broken recorder). Backs the
//! `cocoa trace-check` subcommand, the CI trace smoke step, and the
//! telemetry test suite. This is a parse surface (`no_panic` lint):
//! hostile or truncated input must come back as `Err`, never a crash.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Summary of a validated trace.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    /// Total events in `traceEvents`.
    pub events: usize,
    /// Distinct `(pid, tid)` lanes carrying complete events.
    pub lanes: usize,
    /// Deepest span nesting observed on any lane.
    pub max_depth: usize,
    /// `otherData.dropped_events` if present.
    pub dropped: u64,
}

fn req_str<'a>(ev: &'a Json, key: &str, i: usize) -> Result<&'a str, String> {
    ev.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("event {i}: missing or non-string {key:?}"))
}

fn req_uint(ev: &Json, key: &str, i: usize) -> Result<u64, String> {
    let x = ev
        .get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("event {i}: missing or non-numeric {key:?}"))?;
    if !(x.is_finite() && x >= 0.0 && x == x.trunc()) {
        return Err(format!("event {i}: {key:?} must be a non-negative integer, got {x}"));
    }
    Ok(x as u64)
}

/// Validate a trace document already parsed to [`Json`].
pub fn check_value(doc: &Json) -> Result<TraceCheck, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing \"traceEvents\" array")?;

    // Collect complete ("X") spans per (pid, tid) lane.
    let mut lanes: BTreeMap<(u64, u64), Vec<(u64, u64)>> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let name = req_str(ev, "name", i)?;
        if name.is_empty() {
            return Err(format!("event {i}: empty name"));
        }
        let ph = req_str(ev, "ph", i)?;
        if ph != "X" {
            // Metadata/instant phases carry no duration; nothing to nest.
            continue;
        }
        let ts = req_uint(ev, "ts", i)?;
        let dur = req_uint(ev, "dur", i)?;
        let pid = req_uint(ev, "pid", i)?;
        let tid = req_uint(ev, "tid", i)?;
        ts.checked_add(dur)
            .ok_or_else(|| format!("event {i}: ts+dur overflows"))?;
        lanes.entry((pid, tid)).or_default().push((ts, dur));
    }

    // Nesting check per lane: sort by (start, longest-first) and sweep
    // with a stack of enclosing end times. A span must fit entirely
    // inside the innermost still-open span (or be disjoint from all).
    let mut max_depth = 0usize;
    for ((pid, tid), spans) in lanes.iter_mut() {
        spans.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        let mut stack: Vec<u64> = Vec::new();
        for &(ts, dur) in spans.iter() {
            let end = ts.saturating_add(dur);
            while stack.last().is_some_and(|&open_end| open_end <= ts) {
                stack.pop();
            }
            if let Some(&open_end) = stack.last() {
                if end > open_end {
                    return Err(format!(
                        "lane (pid={pid}, tid={tid}): span [{ts}, {end}] partially \
                         overlaps an enclosing span ending at {open_end}"
                    ));
                }
            }
            stack.push(end);
            max_depth = max_depth.max(stack.len());
        }
    }

    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|v| v.as_f64())
        .map(|x| x.max(0.0) as u64)
        .unwrap_or(0);

    Ok(TraceCheck {
        events: events.len(),
        lanes: lanes.len(),
        max_depth,
        dropped,
    })
}

/// Parse and validate a trace document from its JSON text.
pub fn check_str(text: &str) -> Result<TraceCheck, String> {
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    check_value(&doc)
}

/// Read, parse, and validate a trace file.
pub fn check_file(path: &std::path::Path) -> Result<TraceCheck, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    check_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(events: &str) -> String {
        format!("{{\"traceEvents\":[{events}]}}")
    }

    fn ev(name: &str, ts: u64, dur: u64, tid: u64) -> String {
        format!(
            "{{\"name\":\"{name}\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur},\
             \"pid\":0,\"tid\":{tid}}}"
        )
    }

    #[test]
    fn accepts_properly_nested_spans() {
        let text = trace(&[
            ev("round", 0, 100, 0),
            ev("broadcast", 5, 10, 0),
            ev("barrier", 20, 70, 0),
            ev("recv", 25, 30, 0),
            ev("compute", 10, 50, 1),
        ]
        .join(","));
        let c = check_str(&text).unwrap();
        assert_eq!(c.events, 5);
        assert_eq!(c.lanes, 2);
        assert_eq!(c.max_depth, 3); // round ⊃ barrier ⊃ recv
    }

    #[test]
    fn rejects_partial_overlap() {
        let text = trace(&[ev("a", 0, 50, 0), ev("b", 30, 40, 0)].join(","));
        let err = check_str(&text).unwrap_err();
        assert!(err.contains("partially"), "{err}");
    }

    #[test]
    fn sibling_spans_may_touch() {
        // b starts exactly where a ends: disjoint, not overlapping.
        let text = trace(&[ev("a", 0, 30, 0), ev("b", 30, 30, 0)].join(","));
        assert!(check_str(&text).is_ok());
    }

    #[test]
    fn rejects_malformed_events() {
        assert!(check_str("{}").is_err(), "no traceEvents");
        assert!(check_str("not json").is_err());
        let no_name = trace("{\"ph\":\"X\",\"ts\":0,\"dur\":1,\"pid\":0,\"tid\":0}");
        assert!(check_str(&no_name).is_err());
        let neg_ts = trace(
            "{\"name\":\"a\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":-5,\"dur\":1,\"pid\":0,\"tid\":0}",
        );
        assert!(check_str(&neg_ts).is_err());
        let frac = trace(
            "{\"name\":\"a\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":1.5,\"dur\":1,\"pid\":0,\"tid\":0}",
        );
        assert!(check_str(&frac).is_err());
    }

    #[test]
    fn non_x_phases_are_structural_only() {
        let text = trace(
            "{\"name\":\"meta\",\"ph\":\"M\"},\
             {\"name\":\"a\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":0,\"dur\":1,\"pid\":0,\"tid\":0}",
        );
        let c = check_str(&text).unwrap();
        assert_eq!(c.events, 2);
        assert_eq!(c.lanes, 1);
    }

    #[test]
    fn reads_dropped_from_trailer() {
        let text = "{\"traceEvents\":[],\"otherData\":{\"dropped_events\":7}}";
        let c = check_str(text).unwrap();
        assert_eq!(c.dropped, 7);
        assert_eq!(c.events, 0);
    }
}
