//! Per-actor event buffers. A [`Ring`] is owned by exactly one thread;
//! recording is a clock read plus a `Vec` push, and the shared sink is
//! only touched when the buffer fills or the ring is dropped — the
//! recording hot path never contends with other actors.

use super::Shared;
use crate::util::timer::trace_now_us;
use std::sync::Arc;

/// Flush threshold: a full ring is drained into the sink by its owner.
/// 4096 events × 56 bytes keeps the buffer comfortably in cache while
/// making flushes (the only locking) rare.
pub(crate) const RING_CAPACITY: usize = 4096;

/// One Chrome trace-event "complete" record (`ph: "X"`): a named span
/// on a logical thread, microsecond timestamps relative to the process
/// trace epoch. `dur_us == 0` records an instant. Names and categories
/// are `&'static str` so recording never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u32,
    /// Optional single numeric argument rendered under `args`.
    pub arg: Option<(&'static str, f64)>,
}

/// A bounded, single-owner event buffer bound to one logical thread id.
/// All methods are no-ops when the ring came from a disabled
/// [`Recorder`](super::Recorder).
pub struct Ring {
    tid: u32,
    buf: Vec<TraceEvent>,
    shared: Option<Arc<Shared>>,
}

impl std::fmt::Debug for Ring {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Ring(tid={}, buffered={}, {})",
            self.tid,
            self.buf.len(),
            if self.shared.is_some() { "enabled" } else { "disabled" }
        )
    }
}

impl Default for Ring {
    fn default() -> Ring {
        Ring::disabled()
    }
}

impl Ring {
    pub(crate) fn new(tid: u32, shared: Option<Arc<Shared>>) -> Ring {
        let cap = if shared.is_some() { RING_CAPACITY } else { 0 };
        Ring {
            tid,
            buf: Vec::with_capacity(cap),
            shared,
        }
    }

    /// A ring that records nothing (what untraced runs carry around).
    pub fn disabled() -> Ring {
        Ring::new(0, None)
    }

    pub fn enabled(&self) -> bool {
        self.shared.is_some()
    }

    pub fn tid(&self) -> u32 {
        self.tid
    }

    /// Microseconds since the trace epoch — the span-start timestamp to
    /// pass back into [`Ring::complete`]. Returns 0 (and reads no
    /// clock) when disabled.
    pub fn now(&self) -> u64 {
        if self.shared.is_some() {
            trace_now_us()
        } else {
            0
        }
    }

    /// Record a span that started at `start_us` (from [`Ring::now`])
    /// and ends now.
    pub fn complete(
        &mut self,
        name: &'static str,
        cat: &'static str,
        start_us: u64,
        arg: Option<(&'static str, f64)>,
    ) {
        if self.shared.is_none() {
            return;
        }
        let end = trace_now_us();
        self.span_at(name, cat, start_us, end, arg);
    }

    /// Record a span over an explicit `[start_us, end_us]` interval —
    /// used where the duration was measured elsewhere (a worker
    /// process's reported compute time rendered on its lane).
    pub fn span_at(
        &mut self,
        name: &'static str,
        cat: &'static str,
        start_us: u64,
        end_us: u64,
        arg: Option<(&'static str, f64)>,
    ) {
        if self.shared.is_none() {
            return;
        }
        self.push(TraceEvent {
            name,
            cat,
            ts_us: start_us,
            dur_us: end_us.saturating_sub(start_us),
            tid: self.tid,
            arg,
        });
    }

    /// Record a zero-duration marker at the current time.
    pub fn instant(&mut self, name: &'static str, cat: &'static str, arg: Option<(&'static str, f64)>) {
        if self.shared.is_none() {
            return;
        }
        let now = trace_now_us();
        self.push(TraceEvent {
            name,
            cat,
            ts_us: now,
            dur_us: 0,
            tid: self.tid,
            arg,
        });
    }

    fn push(&mut self, ev: TraceEvent) {
        self.buf.push(ev);
        if self.buf.len() >= RING_CAPACITY {
            self.flush();
        }
    }

    /// Drain buffered events into the shared sink (the owning thread is
    /// the only caller, so this is the lone synchronization point).
    pub fn flush(&mut self) {
        match self.shared.as_ref() {
            Some(shared) => shared.flush(&mut self.buf),
            None => self.buf.clear(),
        }
    }
}

impl Drop for Ring {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Recorder;

    #[test]
    fn ring_flushes_when_full() {
        let path = std::env::temp_dir().join("cocoa_ring_full_test.json");
        let rec = Recorder::to_file(&path).unwrap();
        let mut ring = rec.ring(2);
        for i in 0..(RING_CAPACITY + 10) {
            ring.instant("tick", "test", Some(("i", i as f64)));
        }
        // one flush-on-full already happened; the remainder is buffered
        drop(ring);
        let sum = rec.finish().unwrap();
        assert_eq!(sum.events, (RING_CAPACITY + 10) as u64);
        assert_eq!(sum.dropped, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn span_at_clamps_reversed_intervals() {
        let path = std::env::temp_dir().join("cocoa_ring_clamp_test.json");
        let rec = Recorder::to_file(&path).unwrap();
        let mut ring = rec.ring(1);
        ring.span_at("weird", "test", 100, 40, None); // end < start → dur 0
        drop(ring);
        rec.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let check = crate::telemetry::checker::check_str(&text).unwrap();
        assert_eq!(check.events, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn timestamps_are_monotonic_per_ring() {
        let rec = Recorder::disabled();
        let ring = rec.ring(0);
        assert_eq!(ring.now(), 0);
        let a = trace_now_us();
        let b = trace_now_us();
        assert!(b >= a);
    }
}
