//! Streaming JSON output: a push-style [`JsonWriter`] (begin/end
//! containers, keys, scalars — nothing materialized) and the Chrome
//! trace-event [`TraceWriter`] built on it.
//!
//! The scalar encoding is **byte-identical** to
//! [`crate::util::json::Json`]'s compact serializer (same integer
//! short-circuit, same float formatting, same string escapes, `null`
//! for non-finite numbers), so callers can migrate materialize-then-
//! write paths to streaming without changing a single output byte —
//! `History::write_json` locks this in with a parity test. This is an
//! export surface (`no_panic` lint): every failure is an `io::Error`,
//! never a crash.

use std::io::{self, Write};

#[derive(Clone, Copy, Debug)]
enum Frame {
    Obj { first: bool },
    Arr { first: bool },
}

/// Incremental JSON writer. The caller drives the grammar (a key in an
/// object, then its value; values in arrays); the writer inserts
/// separators. Misuse (a value with no key inside an object, ending a
/// container that was never opened) yields `InvalidInput` errors.
#[derive(Debug)]
pub struct JsonWriter<W: Write> {
    out: W,
    stack: Vec<Frame>,
    /// Inside an object, set by `key()` and consumed by the next value.
    keyed: bool,
}

fn misuse(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidInput, format!("JsonWriter misuse: {what}"))
}

impl<W: Write> JsonWriter<W> {
    pub fn new(out: W) -> JsonWriter<W> {
        JsonWriter {
            out,
            stack: Vec::new(),
            keyed: false,
        }
    }

    /// Comma/position bookkeeping before any value (scalar or container
    /// open). In an object a preceding `key()` is required.
    fn pre_value(&mut self) -> io::Result<()> {
        match self.stack.last_mut() {
            Some(Frame::Arr { first }) => {
                if !*first {
                    self.out.write_all(b",")?;
                }
                *first = false;
                Ok(())
            }
            Some(Frame::Obj { .. }) => {
                if !self.keyed {
                    return Err(misuse("value inside object without key()"));
                }
                self.keyed = false;
                Ok(())
            }
            None => Ok(()),
        }
    }

    /// Write an object key (with its separator and colon). Valid only
    /// directly inside an object.
    pub fn key(&mut self, k: &str) -> io::Result<()> {
        match self.stack.last_mut() {
            Some(Frame::Obj { first }) => {
                if self.keyed {
                    return Err(misuse("key() twice without a value"));
                }
                if !*first {
                    self.out.write_all(b",")?;
                }
                *first = false;
            }
            _ => return Err(misuse("key() outside object")),
        }
        write_escaped(&mut self.out, k)?;
        self.out.write_all(b":")?;
        self.keyed = true;
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.pre_value()?;
        self.stack.push(Frame::Obj { first: true });
        self.out.write_all(b"{")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.pre_value()?;
        self.stack.push(Frame::Arr { first: true });
        self.out.write_all(b"[")
    }

    /// Close the innermost open container.
    pub fn end(&mut self) -> io::Result<()> {
        if self.keyed {
            return Err(misuse("end() with dangling key"));
        }
        match self.stack.pop() {
            Some(Frame::Obj { .. }) => self.out.write_all(b"}"),
            Some(Frame::Arr { .. }) => self.out.write_all(b"]"),
            None => Err(misuse("end() with nothing open")),
        }
    }

    /// A number, encoded exactly like `Json::Num`: integral finite
    /// values below 1e15 print as integers, other finite values via
    /// Rust's shortest-roundtrip float formatting, non-finite as null.
    pub fn num(&mut self, x: f64) -> io::Result<()> {
        self.pre_value()?;
        if x.is_finite() {
            if x == x.trunc() && x.abs() < 1e15 {
                write!(self.out, "{}", x as i64)
            } else {
                write!(self.out, "{}", x)
            }
        } else {
            self.out.write_all(b"null")
        }
    }

    /// An exact unsigned integer (no f64 round-trip — used for
    /// microsecond timestamps).
    pub fn uint(&mut self, x: u64) -> io::Result<()> {
        self.pre_value()?;
        write!(self.out, "{x}")
    }

    pub fn str_val(&mut self, s: &str) -> io::Result<()> {
        self.pre_value()?;
        write_escaped(&mut self.out, s)
    }

    pub fn bool_val(&mut self, b: bool) -> io::Result<()> {
        self.pre_value()?;
        self.out.write_all(if b { b"true" } else { b"false" })
    }

    pub fn null(&mut self) -> io::Result<()> {
        self.pre_value()?;
        self.out.write_all(b"null")
    }

    /// True when every opened container has been closed.
    pub fn is_complete(&self) -> bool {
        self.stack.is_empty() && !self.keyed
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

/// String escaping identical to `util::json::write_escaped`.
fn write_escaped<W: Write>(out: &mut W, s: &str) -> io::Result<()> {
    out.write_all(b"\"")?;
    for c in s.chars() {
        match c {
            '"' => out.write_all(b"\\\"")?,
            '\\' => out.write_all(b"\\\\")?,
            '\n' => out.write_all(b"\\n")?,
            '\r' => out.write_all(b"\\r")?,
            '\t' => out.write_all(b"\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => {
                let mut buf = [0u8; 4];
                out.write_all(c.encode_utf8(&mut buf).as_bytes())?;
            }
        }
    }
    out.write_all(b"\"")
}

use super::TraceEvent;

/// Streams a Chrome trace-event JSON file: `{"traceEvents":[...]}`
/// plus a small metadata object in the trailer. Events are written as
/// they arrive; the file is valid once [`TraceWriter::finish`] runs.
#[derive(Debug)]
pub struct TraceWriter<W: Write> {
    w: JsonWriter<W>,
    events: u64,
}

impl<W: Write> TraceWriter<W> {
    /// Write the header (`{"traceEvents":[`) and hand back the writer.
    pub fn new(out: W) -> io::Result<TraceWriter<W>> {
        let mut w = JsonWriter::new(out);
        w.begin_obj()?;
        w.key("traceEvents")?;
        w.begin_arr()?;
        Ok(TraceWriter { w, events: 0 })
    }

    /// Append one complete (`ph: "X"`) event.
    pub fn write_event(&mut self, ev: &TraceEvent) -> io::Result<()> {
        self.w.begin_obj()?;
        self.w.key("name")?;
        self.w.str_val(ev.name)?;
        self.w.key("cat")?;
        self.w.str_val(ev.cat)?;
        self.w.key("ph")?;
        self.w.str_val("X")?;
        self.w.key("ts")?;
        self.w.uint(ev.ts_us)?;
        self.w.key("dur")?;
        self.w.uint(ev.dur_us)?;
        self.w.key("pid")?;
        self.w.uint(0)?;
        self.w.key("tid")?;
        self.w.uint(u64::from(ev.tid))?;
        if let Some((k, v)) = ev.arg {
            self.w.key("args")?;
            self.w.begin_obj()?;
            self.w.key(k)?;
            self.w.num(v)?;
            self.w.end()?;
        }
        self.w.end()?;
        self.events += 1;
        Ok(())
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    /// Close the event array, write trailer metadata, and flush.
    pub fn finish(mut self, dropped: u64) -> io::Result<W> {
        self.w.end()?; // traceEvents
        self.w.key("displayTimeUnit")?;
        self.w.str_val("ms")?;
        self.w.key("otherData")?;
        self.w.begin_obj()?;
        self.w.key("dropped_events")?;
        self.w.uint(dropped)?;
        self.w.key("tool")?;
        self.w.str_val("cocoa-telemetry")?;
        self.w.end()?;
        self.w.end()?; // root
        let mut out = self.w.into_inner();
        out.flush()?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{jarr, jnum, jobj, jstr, Json};

    /// Stream the same document `Json::write` would produce and compare
    /// bytes — the parity contract streaming callers rely on.
    #[test]
    fn scalar_encoding_matches_json_compact_bytes() {
        let values = [
            0.0,
            -0.0,
            3.0,
            -3.0,
            3.5,
            1e-9,
            -2.5e3,
            1e15,           // at the integer-format cutoff
            999999999999999.0, // just below it
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        for &x in &values {
            let mut buf = Vec::new();
            let mut w = JsonWriter::new(&mut buf);
            w.num(x).unwrap();
            assert_eq!(
                String::from_utf8(buf).unwrap(),
                jnum(x).to_string_compact(),
                "mismatch for {x}"
            );
        }
    }

    #[test]
    fn structured_document_matches_json_compact_bytes() {
        // Keys in alphabetical order mirror the BTreeMap-backed writer.
        let tree = jobj(vec![
            ("alpha", jarr(vec![jnum(1.0), jnum(2.5), Json::Null])),
            ("beta", jobj(vec![("nested", jstr("va\"l\n"))])),
            ("gamma", Json::Bool(true)),
            ("delta", jarr(vec![])),
        ]);
        let mut buf = Vec::new();
        let mut w = JsonWriter::new(&mut buf);
        w.begin_obj().unwrap();
        w.key("alpha").unwrap();
        w.begin_arr().unwrap();
        w.num(1.0).unwrap();
        w.num(2.5).unwrap();
        w.null().unwrap();
        w.end().unwrap();
        w.key("beta").unwrap();
        w.begin_obj().unwrap();
        w.key("nested").unwrap();
        w.str_val("va\"l\n").unwrap();
        w.end().unwrap();
        w.key("delta").unwrap();
        w.begin_arr().unwrap();
        w.end().unwrap();
        w.key("gamma").unwrap();
        w.bool_val(true).unwrap();
        w.end().unwrap();
        assert!(w.is_complete());
        assert_eq!(String::from_utf8(buf).unwrap(), tree.to_string_compact());
    }

    #[test]
    fn misuse_is_an_error_not_a_panic() {
        let mut w = JsonWriter::new(Vec::new());
        assert!(w.end().is_err(), "end with nothing open");
        let mut w = JsonWriter::new(Vec::new());
        w.begin_obj().unwrap();
        assert!(w.num(1.0).is_err(), "object value without key");
        w.key("k").unwrap();
        assert!(w.end().is_err(), "end with dangling key");
    }

    #[test]
    fn trace_writer_emits_parseable_chrome_trace() {
        let tw = TraceWriter::new(Vec::new()).unwrap();
        let mut tw = tw;
        tw.write_event(&TraceEvent {
            name: "round",
            cat: "driver",
            ts_us: 10,
            dur_us: 90,
            tid: 0,
            arg: Some(("round", 0.0)),
        })
        .unwrap();
        tw.write_event(&TraceEvent {
            name: "compute",
            cat: "worker",
            ts_us: 20,
            dur_us: 50,
            tid: 1,
            arg: None,
        })
        .unwrap();
        assert_eq!(tw.events(), 2);
        let bytes = tw.finish(0).unwrap();
        let j = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("dur").unwrap().as_f64(), Some(90.0));
        assert_eq!(evs[1].get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("otherData").unwrap().get("tool").unwrap().as_str(),
            Some("cocoa-telemetry")
        );
    }
}
