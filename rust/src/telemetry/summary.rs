//! Per-phase wall-clock aggregation of a recorded trace file — the
//! engine behind `cocoa trace-summary`. Where [`super::checker`] asks
//! "is this trace structurally valid?", this module asks "where did the
//! round actually spend its time?": every complete (`ph: "X"`) span is
//! bucketed by name (`round`, `broadcast`, `compute`, `barrier`,
//! `reduce`, `send`, `recv`, …) and reported as a count / total / max /
//! share-of-wall table. Like the checker, this is a parse surface:
//! hostile or truncated input must come back as `Err`, never a crash.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Aggregate of all spans sharing one name.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseStat {
    pub name: String,
    /// Number of spans with this name.
    pub count: usize,
    /// Summed duration in seconds (lanes overlap, so totals can exceed
    /// the wall clock — that is the point of the table).
    pub total_s: f64,
    /// Longest single span in seconds.
    pub max_s: f64,
}

/// The per-phase wall-clock budget of one trace.
#[derive(Clone, Debug, Default)]
pub struct TraceBudget {
    /// Phases sorted by total time, largest first.
    pub phases: Vec<PhaseStat>,
    /// Total events in the file (all phases, including metadata).
    pub events: usize,
    /// Wall-clock extent in seconds: latest span end − earliest span
    /// start across all lanes.
    pub wall_s: f64,
}

fn span_fields(ev: &Json, i: usize) -> Result<Option<(&str, u64, u64)>, String> {
    let name = ev
        .get("name")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("event {i}: missing or non-string \"name\""))?;
    let ph = ev
        .get("ph")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("event {i}: missing or non-string \"ph\""))?;
    if ph != "X" {
        return Ok(None);
    }
    let uint = |key: &str| -> Result<u64, String> {
        let x = ev
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("event {i}: missing or non-numeric {key:?}"))?;
        if !(x.is_finite() && x >= 0.0 && x == x.trunc()) {
            return Err(format!(
                "event {i}: {key:?} must be a non-negative integer, got {x}"
            ));
        }
        Ok(x as u64)
    };
    Ok(Some((name, uint("ts")?, uint("dur")?)))
}

/// Aggregate a trace document already parsed to [`Json`].
pub fn summarize_value(doc: &Json) -> Result<TraceBudget, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing \"traceEvents\" array")?;

    let mut by_name: BTreeMap<String, PhaseStat> = BTreeMap::new();
    let mut t_min = u64::MAX;
    let mut t_max = 0u64;
    for (i, ev) in events.iter().enumerate() {
        let Some((name, ts, dur)) = span_fields(ev, i)? else {
            continue;
        };
        let end = ts
            .checked_add(dur)
            .ok_or_else(|| format!("event {i}: ts+dur overflows"))?;
        t_min = t_min.min(ts);
        t_max = t_max.max(end);
        let secs = dur as f64 * 1e-6;
        let stat = by_name.entry(name.to_string()).or_default();
        if stat.count == 0 {
            stat.name = name.to_string();
        }
        stat.count += 1;
        stat.total_s += secs;
        stat.max_s = stat.max_s.max(secs);
    }

    let mut phases: Vec<PhaseStat> = by_name.into_values().collect();
    // Largest total first; name breaks ties so the order is stable.
    phases.sort_by(|a, b| {
        b.total_s
            .partial_cmp(&a.total_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.name.cmp(&b.name))
    });
    let wall_s = if t_max > t_min {
        (t_max - t_min) as f64 * 1e-6
    } else {
        0.0
    };
    Ok(TraceBudget {
        phases,
        events: events.len(),
        wall_s,
    })
}

/// Parse and aggregate a trace document from its JSON text.
pub fn summarize_str(text: &str) -> Result<TraceBudget, String> {
    let doc = Json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    summarize_value(&doc)
}

/// Read, parse, and aggregate a trace file.
pub fn summarize_file(path: &std::path::Path) -> Result<TraceBudget, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    summarize_str(&text)
}

impl TraceBudget {
    /// Render the budget as an aligned text table (what `cocoa
    /// trace-summary` prints). Totals can sum past 100% of wall because
    /// lanes run concurrently.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} events, wall {:.6} s\n",
            self.events, self.wall_s
        ));
        out.push_str(&format!(
            "{:<12} {:>7} {:>12} {:>12} {:>8}\n",
            "phase", "count", "total_s", "max_s", "% wall"
        ));
        for p in &self.phases {
            let share = if self.wall_s > 0.0 {
                100.0 * p.total_s / self.wall_s
            } else {
                0.0
            };
            out.push_str(&format!(
                "{:<12} {:>7} {:>12.6} {:>12.6} {:>7.1}%\n",
                p.name, p.count, p.total_s, p.max_s, share
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(events: &str) -> String {
        format!("{{\"traceEvents\":[{events}]}}")
    }

    fn ev(name: &str, ts: u64, dur: u64, tid: u64) -> String {
        format!(
            "{{\"name\":\"{name}\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur},\
             \"pid\":0,\"tid\":{tid}}}"
        )
    }

    #[test]
    fn aggregates_by_name_across_lanes() {
        let text = trace(&[
            ev("round", 0, 100, 0),
            ev("send", 5, 10, 1),
            ev("send", 5, 20, 2),
            ev("compute", 30, 60, 1),
        ]
        .join(","));
        let b = summarize_str(&text).unwrap();
        assert_eq!(b.events, 4);
        assert!((b.wall_s - 100e-6).abs() < 1e-12);
        // sorted by total: round (100) > compute (60) > send (30)
        let names: Vec<&str> = b.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["round", "compute", "send"]);
        let send = &b.phases[2];
        assert_eq!(send.count, 2);
        assert!((send.total_s - 30e-6).abs() < 1e-12);
        assert!((send.max_s - 20e-6).abs() < 1e-12);
    }

    #[test]
    fn renders_every_phase_row() {
        let text = trace(&[ev("reduce", 0, 50, 0), ev("barrier", 50, 25, 0)].join(","));
        let table = summarize_str(&text).unwrap().render();
        assert!(table.contains("reduce"), "{table}");
        assert!(table.contains("barrier"), "{table}");
        assert!(table.contains("% wall"), "{table}");
    }

    #[test]
    fn non_span_phases_are_skipped_but_counted() {
        let text = trace(
            "{\"name\":\"meta\",\"ph\":\"M\"},\
             {\"name\":\"a\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":0,\"dur\":1,\"pid\":0,\"tid\":0}",
        );
        let b = summarize_str(&text).unwrap();
        assert_eq!(b.events, 2);
        assert_eq!(b.phases.len(), 1);
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(summarize_str("not json").is_err());
        assert!(summarize_str("{}").is_err());
        let frac = trace(
            "{\"name\":\"a\",\"cat\":\"t\",\"ph\":\"X\",\"ts\":1.5,\"dur\":1,\"pid\":0,\"tid\":0}",
        );
        assert!(summarize_str(&frac).is_err());
    }

    #[test]
    fn empty_trace_is_a_zero_budget() {
        let b = summarize_str("{\"traceEvents\":[]}").unwrap();
        assert_eq!(b.events, 0);
        assert_eq!(b.wall_s, 0.0);
        assert!(b.phases.is_empty());
    }
}
