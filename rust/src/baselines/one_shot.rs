//! One-shot averaging (Zinkevich et al. 2010 / Zhang et al. 2013) — the
//! single-communication-round baseline of §6.
//!
//! Each worker solves its *local* ERM (on its partition only, with the
//! global λ) to near-optimality with serial SDCA, then the leader averages
//! the K local models once. The paper's point — and what the experiment
//! shows — is that this cannot converge to the true optimum for all
//! regularizers/partitions: the residual gap does not go to zero no
//! matter how much local compute is spent.

use crate::coordinator::comm::CommModel;
use crate::data::Partition;
use crate::driver::{Method, StepStats};
use crate::linalg::dense;
use crate::objective::{Certificates, Problem};
use crate::subproblem::{LocalBlock, SubproblemSpec};
use crate::util::rng::Pcg32;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct OneShotConfig {
    pub k: usize,
    /// Local SDCA epochs each worker spends on its own subproblem.
    pub local_epochs: usize,
    pub seed: u64,
    pub comm: CommModel,
}

impl OneShotConfig {
    pub fn new(k: usize) -> OneShotConfig {
        OneShotConfig {
            k,
            local_epochs: 50,
            seed: 42,
            comm: CommModel::ec2_like(),
        }
    }
}

pub struct OneShotResult {
    pub w: Vec<f64>,
    pub certs: Certificates,
    pub sim_time_s: f64,
    pub comm_vectors: usize,
}

/// The local solves + single averaging round: returns the averaged model,
/// the scaled global dual point, and the measured max-worker compute
/// seconds. Shared by [`run`] and the stepwise [`OneShot`] method.
fn solve_and_average(
    problem: &Problem,
    partition: &Partition,
    cfg: &OneShotConfig,
) -> (Vec<f64>, Vec<f64>, f64) {
    assert_eq!(partition.k(), cfg.k);
    let n = problem.n();
    let d = problem.d();
    let lambda = problem.lambda;
    // Shard views over one shared (permuted) dataset — no per-worker
    // matrix clones; `partition.parts[k]` still scatters back to the
    // caller's row order (block k's local row i holds caller row
    // `partition.parts[k][i]`).
    let blocks = LocalBlock::split(&problem.data, partition);

    let mut w_avg = vec![0.0; d];
    let mut alpha_global = vec![0.0; n];
    let mut max_compute = 0.0f64;

    for (k, block) in blocks.iter().enumerate() {
        let t0 = Instant::now();
        let nk = block.n_local();
        let x = block.x();
        let y = block.y();
        let norms = block.norms_sq();
        // Solve the local ERM: min (1/n_k) Σ ℓ + (λ/2)‖w‖² via its dual;
        // serial SDCA = our SDCA machinery with σ'=1, K=1 on the local data.
        let spec = SubproblemSpec {
            loss: problem.loss,
            lambda,
            n_global: nk,
            sigma_prime: 1.0,
            k: 1,
        };
        let mut alpha_local = vec![0.0; nk];
        let mut v = vec![0.0; d];
        let mut rng = Pcg32::new(cfg.seed, 3000 + k as u64);
        for _ in 0..cfg.local_epochs * nk {
            let i = rng.gen_range(nk);
            let q = norms[i];
            if q == 0.0 {
                continue;
            }
            let xv = x.row_dot(i, &v);
            let coef = spec.coef(q);
            let dlt = spec.loss.coordinate_delta(alpha_local[i], y[i], xv, coef);
            if dlt != 0.0 {
                alpha_local[i] += dlt;
                x.row_axpy(i, spec.v_scale() * dlt, &mut v);
            }
        }
        // local model w_k = A_k α_k/(λ n_k) == v (σ'=1, n_global=n_k)
        dense::axpy(1.0 / cfg.k as f64, &v, &mut w_avg);
        // Scatter duals scaled so that w(α_global) = w_avg on the global
        // problem: α_global_i = α_local_i · n/(n_k·K).
        let scale = n as f64 / (nk as f64 * cfg.k as f64);
        for (li, &gi) in partition.parts[k].iter().enumerate() {
            alpha_global[gi] = alpha_local[li] * scale;
        }
        max_compute = max_compute.max(t0.elapsed().as_secs_f64());
    }
    (w_avg, alpha_global, max_compute)
}

/// Certify the averaged model on the *global* problem. The dual is
/// evaluated at the concatenated local duals divided by K (a feasible
/// point whose map is exactly the averaged w, so the gap certificate is
/// meaningful).
///
/// NOTE: the scaled α_global may be dual-infeasible for box-constrained
/// losses (scale > 1) — in that case we certify with primal only and an
/// infinite gap, which is itself the paper's point.
fn certify(problem: &Problem, alpha_global: &[f64], w_avg: &[f64]) -> Certificates {
    let primal = problem.primal_value(w_avg);
    let dual = problem.dual_value(alpha_global, w_avg);
    Certificates {
        primal,
        dual,
        gap: primal - dual,
    }
}

/// Run one-shot averaging end-to-end (the original single-call API).
pub fn run(problem: &Problem, partition: &Partition, cfg: &OneShotConfig) -> OneShotResult {
    let (w_avg, alpha_global, max_compute) = solve_and_average(problem, partition, cfg);
    let certs = certify(problem, &alpha_global, &w_avg);
    OneShotResult {
        w: w_avg,
        certs,
        sim_time_s: max_compute + cfg.comm.round_time(problem.d()),
        comm_vectors: cfg.comm.round_vectors(cfg.k),
    }
}

/// One-shot averaging as a stepwise [`Method`]: the first
/// [`Method::step`] performs the local solves and the single averaging
/// round; later steps are free no-ops (no compute, no communication), so
/// a [`Driver`](crate::driver::Driver) can run it alongside iterative
/// methods under any round budget without inflating its clock.
pub struct OneShot {
    pub cfg: OneShotConfig,
    pub problem: Problem,
    partition: Partition,
    /// The averaged model (zeros until the first step).
    pub w: Vec<f64>,
    certs: Option<Certificates>,
}

impl OneShot {
    pub fn new(problem: Problem, partition: Partition, cfg: OneShotConfig) -> OneShot {
        assert_eq!(partition.k(), cfg.k);
        assert_eq!(partition.n, problem.n());
        let d = problem.d();
        OneShot {
            cfg,
            problem,
            partition,
            w: vec![0.0; d],
            certs: None,
        }
    }

    /// Whether the single averaging round has happened yet.
    pub fn done(&self) -> bool {
        self.certs.is_some()
    }
}

impl Method for OneShot {
    fn step(&mut self) -> StepStats {
        if self.certs.is_some() {
            return StepStats {
                compute_s: 0.0,
                comm_vectors: 0,
            };
        }
        let (w_avg, alpha_global, max_compute) =
            solve_and_average(&self.problem, &self.partition, &self.cfg);
        self.certs = Some(certify(&self.problem, &alpha_global, &w_avg));
        self.w = w_avg;
        StepStats {
            compute_s: max_compute,
            comm_vectors: self.cfg.comm.round_vectors(self.cfg.k),
        }
    }

    fn eval(&mut self) -> Certificates {
        match self.certs {
            Some(c) => c,
            None => {
                let alpha = vec![0.0; self.problem.n()];
                self.problem.certificates(&alpha, &self.w)
            }
        }
    }

    fn comm_vectors_per_round(&self) -> usize {
        self.cfg.comm.round_vectors(self.cfg.k)
    }

    fn w(&self) -> &[f64] {
        &self.w
    }

    fn label(&self) -> String {
        format!(
            "one_shot(K={},epochs={})",
            self.cfg.k, self.cfg.local_epochs
        )
    }

    fn comm_model(&self) -> CommModel {
        self.cfg.comm
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CocoaConfig, SolverSpec, Trainer};
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    #[test]
    fn one_shot_beats_zero_but_not_cocoa_plus() {
        let data = generate(&SynthConfig::new("t", 120, 10).seed(3));
        let problem = Problem::new(data, Loss::Hinge, 0.01);
        let part = random_balanced(120, 4, 7);

        let os = run(&problem, &part, &OneShotConfig::new(4));
        let p_zero = problem.primal_value(&vec![0.0; problem.d()]);
        assert!(
            os.certs.primal < p_zero,
            "one-shot should beat the zero model"
        );

        // CoCoA+ with modest work reaches a much better primal.
        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            0.01,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(60)
        .with_parallel(false);
        let mut t = Trainer::new(problem.clone(), part, cfg);
        t.run();
        let p_cocoa = t.problem.primal_value(&t.w);
        assert!(
            p_cocoa <= os.certs.primal + 1e-9,
            "CoCoA+ ({p_cocoa}) should match or beat one-shot ({})",
            os.certs.primal
        );
    }

    #[test]
    fn single_communication_round() {
        let data = generate(&SynthConfig::new("t", 60, 6).seed(1));
        let problem = Problem::new(data, Loss::Hinge, 0.05);
        let part = random_balanced(60, 3, 2);
        let os = run(&problem, &part, &OneShotConfig::new(3));
        assert_eq!(os.comm_vectors, 3); // one vector per worker, once
        assert!(os.sim_time_s > 0.0);
    }

    #[test]
    fn residual_suboptimality_persists_with_more_local_work() {
        // More local epochs must not drive the averaged model to the true
        // optimum (structural bias of one-shot averaging).
        let data = generate(&SynthConfig::new("t", 120, 10).seed(5));
        let problem = Problem::new(data, Loss::Hinge, 0.005);
        let part = random_balanced(120, 6, 7);

        // Good reference: long CoCoA+ run.
        let cfg = CocoaConfig::cocoa_plus(
            6,
            Loss::Hinge,
            0.005,
            SolverSpec::SdcaEpochs { epochs: 2.0 },
        )
        .with_rounds(150)
        .with_gap_tol(1e-7)
        .with_parallel(false);
        let mut t = Trainer::new(problem.clone(), part.clone(), cfg);
        t.run();
        let p_star = t.problem.primal_value(&t.w);

        let mut cfg_os = OneShotConfig::new(6);
        cfg_os.local_epochs = 20;
        let sub20 = run(&problem, &part, &cfg_os).certs.primal - p_star;
        cfg_os.local_epochs = 120;
        let sub120 = run(&problem, &part, &cfg_os).certs.primal - p_star;
        assert!(sub20 > 0.0);
        // 6× the local work buys little: suboptimality stays within 50%.
        assert!(
            sub120 > sub20 * 0.2,
            "one-shot bias should persist: {sub20} → {sub120}"
        );
    }
}
