//! Serial (single-machine) SDCA — Shalev-Shwartz & Zhang (2013c).
//!
//! Two roles here: (i) the ground-truth reference used to estimate D(α*)
//! and P(w*) for suboptimality axes (Fig. 2 needs "time to ε_D-accurate"),
//! and (ii) the K=1 sanity baseline every distributed method must match.

use crate::coordinator::comm::CommModel;
use crate::driver::{Method, StepStats};
use crate::objective::{Certificates, Problem};
use crate::util::rng::Pcg32;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct SerialSdcaConfig {
    pub max_epochs: usize,
    pub gap_tol: f64,
    /// Check the gap every `check_every` epochs.
    pub check_every: usize,
    pub seed: u64,
}

impl Default for SerialSdcaConfig {
    fn default() -> Self {
        SerialSdcaConfig {
            max_epochs: 400,
            gap_tol: 1e-8,
            check_every: 10,
            seed: 7,
        }
    }
}

pub struct SerialSdcaResult {
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    pub certs: Certificates,
    pub epochs_run: usize,
}

/// Serial SDCA as a stepwise optimizer: one [`SerialSdca::epoch`] (= n
/// random coordinate steps) per [`Method::step`]. Communicates nothing,
/// so its simulated clock is pure measured compute — the single-machine
/// reference line every distributed method is compared against.
pub struct SerialSdca {
    pub cfg: SerialSdcaConfig,
    pub problem: Problem,
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    rng: Pcg32,
    epochs_run: usize,
}

impl SerialSdca {
    pub fn new(problem: Problem, cfg: SerialSdcaConfig) -> SerialSdca {
        let n = problem.n();
        let d = problem.d();
        SerialSdca {
            rng: Pcg32::new(cfg.seed, 4000),
            cfg,
            problem,
            alpha: vec![0.0; n],
            w: vec![0.0; d],
            epochs_run: 0,
        }
    }

    /// One epoch: n random coordinate-ascent steps (K=1, σ'=1 — coef
    /// q/(λn)).
    pub fn epoch(&mut self) {
        sdca_epoch(&self.problem, &mut self.alpha, &mut self.w, &mut self.rng);
        self.epochs_run += 1;
    }

    pub fn epochs_run(&self) -> usize {
        self.epochs_run
    }
}

impl Method for SerialSdca {
    fn step(&mut self) -> StepStats {
        let t0 = Instant::now();
        self.epoch();
        StepStats {
            compute_s: t0.elapsed().as_secs_f64(),
            comm_vectors: 0,
        }
    }

    fn eval(&mut self) -> Certificates {
        self.problem.certificates(&self.alpha, &self.w)
    }

    fn comm_vectors_per_round(&self) -> usize {
        0
    }

    fn w(&self) -> &[f64] {
        &self.w
    }

    fn label(&self) -> String {
        format!("serial_sdca(seed={})", self.cfg.seed)
    }

    fn comm_model(&self) -> CommModel {
        CommModel::disabled()
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.w))
    }
}

/// One SDCA epoch (n random coordinate steps) on `problem`, updating
/// (α, w) in place. The K=1, σ'=1 case: coef = q/(λn). Shared by the
/// borrowing [`solve`] and the owning stepwise [`SerialSdca`].
fn sdca_epoch(problem: &Problem, alpha: &mut [f64], w: &mut [f64], rng: &mut Pcg32) {
    let n = problem.n();
    let lambda = problem.lambda;
    let loss = problem.loss;
    let inv_ln = 1.0 / (lambda * n as f64);
    for _ in 0..n {
        let i = rng.gen_range(n);
        let q = problem.data.row_norms_sq[i];
        if q == 0.0 {
            continue;
        }
        let z = problem.data.x.row_dot(i, w);
        let delta = loss.coordinate_delta(alpha[i], problem.data.y[i], z, q * inv_ln);
        if delta != 0.0 {
            alpha[i] += delta;
            problem.data.x.row_axpy(i, delta * inv_ln, w);
        }
    }
}

/// Run serial SDCA to high accuracy on the full problem (borrows the
/// problem — no dataset copy).
pub fn solve(problem: &Problem, cfg: &SerialSdcaConfig) -> SerialSdcaResult {
    let n = problem.n();
    let d = problem.d();
    let mut alpha = vec![0.0; n];
    let mut w = vec![0.0; d];
    let mut rng = Pcg32::new(cfg.seed, 4000);

    let mut epochs_run = 0;
    for epoch in 0..cfg.max_epochs {
        sdca_epoch(problem, &mut alpha, &mut w, &mut rng);
        epochs_run = epoch + 1;
        if epoch % cfg.check_every == 0 {
            let certs = problem.certificates(&alpha, &w);
            if certs.gap <= cfg.gap_tol {
                return SerialSdcaResult {
                    alpha,
                    w,
                    certs,
                    epochs_run,
                };
            }
        }
    }
    let certs = problem.certificates(&alpha, &w);
    SerialSdcaResult {
        alpha,
        w,
        certs,
        epochs_run,
    }
}

/// Estimate the optimal dual value D(α*) (used as the Fig. 2 target).
pub fn estimate_d_star(problem: &Problem, seed: u64) -> f64 {
    let cfg = SerialSdcaConfig {
        max_epochs: 600,
        gap_tol: 1e-9,
        check_every: 20,
        seed,
    };
    let res = solve(problem, &cfg);
    // The primal value is an upper bound on D(α*); midpoint of the final
    // bracket is the best single-number estimate.
    0.5 * (res.certs.primal + res.certs.dual)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    #[test]
    fn reaches_tiny_gap() {
        let data = generate(&SynthConfig::new("t", 80, 8).seed(2));
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let res = solve(&p, &SerialSdcaConfig::default());
        assert!(res.certs.gap < 1e-6, "gap {}", res.certs.gap);
        // w consistent with alpha
        let mut w_ref = vec![0.0; p.d()];
        p.primal_from_dual(&res.alpha, &mut w_ref);
        let err: f64 = w_ref
            .iter()
            .zip(&res.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9);
    }

    #[test]
    fn d_star_brackets() {
        let data = generate(&SynthConfig::new("t", 60, 6).seed(4));
        let p = Problem::new(data, Loss::Hinge, 0.1);
        let d_star = estimate_d_star(&p, 1);
        let res = solve(&p, &SerialSdcaConfig::default());
        // D(α*) must lie between the achieved dual and primal.
        assert!(d_star >= res.certs.dual - 1e-9);
        assert!(d_star <= res.certs.primal + 1e-9);
    }

    #[test]
    fn smooth_loss_converges_too() {
        let data = generate(&SynthConfig::new("t", 60, 6).seed(5));
        let p = Problem::new(data, Loss::SmoothedHinge { mu: 0.5 }, 0.05);
        let res = solve(&p, &SerialSdcaConfig::default());
        assert!(res.certs.gap < 1e-6, "gap {}", res.certs.gap);
    }
}
