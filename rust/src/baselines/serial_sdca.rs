//! Serial (single-machine) SDCA — Shalev-Shwartz & Zhang (2013c).
//!
//! Two roles here: (i) the ground-truth reference used to estimate D(α*)
//! and P(w*) for suboptimality axes (Fig. 2 needs "time to ε_D-accurate"),
//! and (ii) the K=1 sanity baseline every distributed method must match.

use crate::objective::{Certificates, Problem};
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct SerialSdcaConfig {
    pub max_epochs: usize,
    pub gap_tol: f64,
    /// Check the gap every `check_every` epochs.
    pub check_every: usize,
    pub seed: u64,
}

impl Default for SerialSdcaConfig {
    fn default() -> Self {
        SerialSdcaConfig {
            max_epochs: 400,
            gap_tol: 1e-8,
            check_every: 10,
            seed: 7,
        }
    }
}

pub struct SerialSdcaResult {
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    pub certs: Certificates,
    pub epochs_run: usize,
}

/// Run serial SDCA to high accuracy on the full problem.
pub fn solve(problem: &Problem, cfg: &SerialSdcaConfig) -> SerialSdcaResult {
    let n = problem.n();
    let d = problem.d();
    let lambda = problem.lambda;
    let loss = problem.loss;
    let mut alpha = vec![0.0; n];
    let mut w = vec![0.0; d];
    let mut rng = Pcg32::new(cfg.seed, 4000);
    let inv_ln = 1.0 / (lambda * n as f64);

    let mut epochs_run = 0;
    for epoch in 0..cfg.max_epochs {
        for _ in 0..n {
            let i = rng.gen_range(n);
            let q = problem.data.row_norms_sq[i];
            if q == 0.0 {
                continue;
            }
            let z = problem.data.x.row_dot(i, &w);
            // Serial SDCA is the K=1, σ'=1 case: coef = q/(λn).
            let delta = loss.coordinate_delta(alpha[i], problem.data.y[i], z, q * inv_ln);
            if delta != 0.0 {
                alpha[i] += delta;
                problem.data.x.row_axpy(i, delta * inv_ln, &mut w);
            }
        }
        epochs_run = epoch + 1;
        if epoch % cfg.check_every == 0 {
            let certs = problem.certificates(&alpha, &w);
            if certs.gap <= cfg.gap_tol {
                return SerialSdcaResult {
                    alpha,
                    w,
                    certs,
                    epochs_run,
                };
            }
        }
    }
    let certs = problem.certificates(&alpha, &w);
    SerialSdcaResult {
        alpha,
        w,
        certs,
        epochs_run,
    }
}

/// Estimate the optimal dual value D(α*) (used as the Fig. 2 target).
pub fn estimate_d_star(problem: &Problem, seed: u64) -> f64 {
    let cfg = SerialSdcaConfig {
        max_epochs: 600,
        gap_tol: 1e-9,
        check_every: 20,
        seed,
    };
    let res = solve(problem, &cfg);
    // The primal value is an upper bound on D(α*); midpoint of the final
    // bracket is the best single-number estimate.
    0.5 * (res.certs.primal + res.certs.dual)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    #[test]
    fn reaches_tiny_gap() {
        let data = generate(&SynthConfig::new("t", 80, 8).seed(2));
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let res = solve(&p, &SerialSdcaConfig::default());
        assert!(res.certs.gap < 1e-6, "gap {}", res.certs.gap);
        // w consistent with alpha
        let mut w_ref = vec![0.0; p.d()];
        p.primal_from_dual(&res.alpha, &mut w_ref);
        let err: f64 = w_ref
            .iter()
            .zip(&res.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-9);
    }

    #[test]
    fn d_star_brackets() {
        let data = generate(&SynthConfig::new("t", 60, 6).seed(4));
        let p = Problem::new(data, Loss::Hinge, 0.1);
        let d_star = estimate_d_star(&p, 1);
        let res = solve(&p, &SerialSdcaConfig::default());
        // D(α*) must lie between the achieved dual and primal.
        assert!(d_star >= res.certs.dual - 1e-9);
        assert!(d_star <= res.certs.primal + 1e-9);
    }

    #[test]
    fn smooth_loss_converges_too() {
        let data = generate(&SynthConfig::new("t", 60, 6).seed(5));
        let p = Problem::new(data, Loss::SmoothedHinge { mu: 0.5 }, 0.05);
        let res = solve(&p, &SerialSdcaConfig::default());
        assert!(res.certs.gap < 1e-6, "gap {}", res.certs.gap);
    }
}
