//! Distributed mini-batch SDCA (Shalev-Shwartz & Zhang 2013a-style) — a
//! related-work baseline (§6 "Mini-Batch Methods").
//!
//! Per round, every worker proposes closed-form SDCA updates for a random
//! mini-batch of its coordinates, all computed against the *stale* shared
//! w, and the leader applies them scaled by β_agg/(K·b) · b_safe — we use
//! the standard safe scaling 1/(β_safe) with β_safe = K·b (the aggregate
//! batch size), which is exactly the conservative rate degradation the
//! paper contrasts CoCoA+ against.

use crate::coordinator::comm::CommModel;
use crate::coordinator::history::History;
use crate::data::Partition;
use crate::driver::{Driver, Method, StepStats, StopPolicy};
use crate::objective::{Certificates, Problem};
use crate::subproblem::LocalBlock;
use crate::util::rng::Pcg32;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct MiniBatchSdcaConfig {
    pub k: usize,
    /// Coordinates per worker per round.
    pub batch_per_worker: usize,
    /// Aggregation scaling β ∈ (0, K·b]; the safe default is 1 (i.e. the
    /// update is divided by the full aggregate batch). Larger values are
    /// more aggressive and may diverge — mirroring the σ' story.
    pub beta: f64,
    pub max_rounds: usize,
    pub gap_tol: f64,
    pub gap_every: usize,
    pub seed: u64,
    pub comm: CommModel,
}

impl MiniBatchSdcaConfig {
    pub fn new(k: usize) -> MiniBatchSdcaConfig {
        MiniBatchSdcaConfig {
            k,
            batch_per_worker: 16,
            beta: 1.0,
            max_rounds: 1000,
            gap_tol: 1e-4,
            gap_every: 10,
            seed: 42,
            comm: CommModel::ec2_like(),
        }
    }
}

pub struct MiniBatchSdca {
    pub cfg: MiniBatchSdcaConfig,
    pub problem: Problem,
    blocks: Vec<LocalBlock>,
    /// Caller-order row index lists per worker: block k's local row `i`
    /// holds `parts[k][i]` of `problem.data` (α and w stay in the
    /// caller's row order here, unlike the trainer's layout order).
    parts: Vec<Vec<usize>>,
    pub alpha: Vec<f64>,
    pub w: Vec<f64>,
    rngs: Vec<Pcg32>,
}

impl MiniBatchSdca {
    pub fn new(problem: Problem, partition: Partition, cfg: MiniBatchSdcaConfig) -> MiniBatchSdca {
        assert_eq!(partition.k(), cfg.k);
        assert_eq!(partition.n, problem.n());
        let blocks = LocalBlock::split(&problem.data, &partition);
        let rngs = (0..cfg.k)
            .map(|k| Pcg32::new(cfg.seed, 2000 + k as u64))
            .collect();
        let (n, d) = (problem.n(), problem.d());
        MiniBatchSdca {
            cfg,
            problem,
            blocks,
            parts: partition.parts,
            alpha: vec![0.0; n],
            w: vec![0.0; d],
            rngs,
        }
    }

    /// One synchronous round; returns max worker compute seconds.
    pub fn round(&mut self) -> f64 {
        let lambda = self.problem.lambda;
        let n = self.problem.n() as f64;
        let loss = self.problem.loss;
        let agg = self.cfg.beta / (self.cfg.k as f64 * self.cfg.batch_per_worker as f64);

        struct Prop {
            global_i: usize,
            delta: f64,
        }
        let mut proposals: Vec<Prop> = Vec::new();
        let mut max_compute = 0.0f64;
        for (k, block) in self.blocks.iter().enumerate() {
            let t0 = Instant::now();
            let nk = block.n_local();
            let x = block.x();
            let y = block.y();
            let norms = block.norms_sq();
            let b = self.cfg.batch_per_worker.min(nk);
            for _ in 0..b {
                let i = self.rngs[k].gen_range(nk);
                let q = norms[i];
                if q == 0.0 {
                    continue;
                }
                let gi = self.parts[k][i];
                let xv = x.row_dot(i, &self.w);
                // Plain serial-SDCA curvature (σ'=1): coef = q/(λn).
                let coef = q / (lambda * n);
                let d = loss.coordinate_delta(self.alpha[gi], y[i], xv, coef);
                proposals.push(Prop {
                    global_i: gi,
                    delta: d,
                });
            }
            max_compute = max_compute.max(t0.elapsed().as_secs_f64());
        }

        // Leader applies the β-scaled aggregate.
        for p in &proposals {
            let step = agg * p.delta;
            self.alpha[p.global_i] += step;
            self.problem
                .data
                .x
                .row_axpy(p.global_i, step / (lambda * n), &mut self.w);
        }
        max_compute
    }

    /// Run under the config's stopping policy through the shared
    /// [`Driver`] loop.
    pub fn run(&mut self) -> History {
        let mut driver = Driver::new(
            StopPolicy::new(self.cfg.max_rounds)
                .with_gap_tol(self.cfg.gap_tol)
                .with_divergence_gap(1e6),
        )
        .with_gap_every(self.cfg.gap_every);
        driver.run(self)
    }
}

impl Method for MiniBatchSdca {
    fn step(&mut self) -> StepStats {
        let compute_s = self.round();
        StepStats {
            compute_s,
            comm_vectors: self.cfg.comm.round_vectors(self.cfg.k),
        }
    }

    fn eval(&mut self) -> Certificates {
        self.problem.certificates(&self.alpha, &self.w)
    }

    fn comm_vectors_per_round(&self) -> usize {
        self.cfg.comm.round_vectors(self.cfg.k)
    }

    fn w(&self) -> &[f64] {
        &self.w
    }

    fn label(&self) -> String {
        format!(
            "minibatch_sdca(K={},b={},beta={})",
            self.cfg.k, self.cfg.batch_per_worker, self.cfg.beta
        )
    }

    fn comm_model(&self) -> CommModel {
        self.cfg.comm
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    fn setup(k: usize, beta: f64) -> MiniBatchSdca {
        let data = generate(&SynthConfig::new("t", 100, 8).seed(3));
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let part = random_balanced(100, k, 7);
        let mut cfg = MiniBatchSdcaConfig::new(k);
        cfg.beta = beta;
        MiniBatchSdca::new(p, part, cfg)
    }

    #[test]
    fn safe_beta_reduces_gap() {
        let mut s = setup(4, 1.0);
        let g0 = s.problem.duality_gap(&s.alpha);
        for _ in 0..400 {
            s.round();
        }
        let g1 = s.problem.certificates(&s.alpha, &s.w).gap;
        assert!(g1 < g0 * 0.8, "mini-batch SDCA made no progress: {g0} → {g1}");
    }

    #[test]
    fn w_alpha_stay_consistent() {
        let mut s = setup(3, 1.0);
        for _ in 0..50 {
            s.round();
        }
        let mut w_ref = vec![0.0; s.problem.d()];
        s.problem.primal_from_dual(&s.alpha, &mut w_ref);
        let err = w_ref
            .iter()
            .zip(&s.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-9, "w drift {err}");
    }

    #[test]
    fn run_emits_history() {
        let mut s = setup(2, 1.0);
        s.cfg.max_rounds = 30;
        let h = s.run();
        assert!(!h.records.is_empty());
    }
}
