//! Distributed mini-batch (sub)gradient descent — the "mini-batch SGD"
//! baseline of Figure 2.
//!
//! Pegasos-style step sizes η_t = 1/(λ(t+t₀)) on the regularized objective:
//! per round every worker computes the subgradient of its sampled local
//! mini-batch against the *stale* shared w, the leader averages the K
//! contributions and takes one step. Communication per round is identical
//! to CoCoA (one vector per worker), but the per-round progress is a
//! single gradient step — exactly the contrast the paper draws.

use crate::coordinator::comm::CommModel;
use crate::coordinator::history::History;
use crate::data::Partition;
use crate::driver::{Driver, Method, StepStats, StopPolicy};
use crate::linalg::dense;
use crate::objective::{Certificates, Problem};
use crate::subproblem::LocalBlock;
use crate::util::rng::Pcg32;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct MiniBatchSgdConfig {
    pub k: usize,
    /// Mini-batch size per worker per round.
    pub batch_per_worker: usize,
    pub max_rounds: usize,
    pub gap_tol: f64,
    pub gap_every: usize,
    /// Step offset t₀ in η_t = 1/(λ(t+t₀)) for stability.
    pub t0: f64,
    pub seed: u64,
    pub comm: CommModel,
}

impl MiniBatchSgdConfig {
    pub fn new(k: usize) -> MiniBatchSgdConfig {
        MiniBatchSgdConfig {
            k,
            batch_per_worker: 16,
            max_rounds: 1000,
            gap_tol: 1e-4,
            gap_every: 10,
            t0: 1.0,
            seed: 42,
            comm: CommModel::ec2_like(),
        }
    }
}

pub struct MiniBatchSgd {
    pub cfg: MiniBatchSgdConfig,
    pub problem: Problem,
    blocks: Vec<LocalBlock>,
    pub w: Vec<f64>,
    rngs: Vec<Pcg32>,
    /// Rounds taken so far (drives the η_t schedule under the step API).
    t: usize,
    /// Externally estimated P(w*) — when set, the history's `gap` column
    /// holds primal suboptimality against it.
    p_star: Option<f64>,
}

impl MiniBatchSgd {
    pub fn new(problem: Problem, partition: Partition, cfg: MiniBatchSgdConfig) -> MiniBatchSgd {
        assert_eq!(partition.k(), cfg.k);
        assert_eq!(partition.n, problem.n());
        let blocks = LocalBlock::split(&problem.data, &partition);
        let rngs = (0..cfg.k)
            .map(|k| Pcg32::new(cfg.seed, 1000 + k as u64))
            .collect();
        let d = problem.d();
        MiniBatchSgd {
            cfg,
            problem,
            blocks,
            w: vec![0.0; d],
            rngs,
            t: 0,
            p_star: None,
        }
    }

    /// Set (or clear) the primal-suboptimality target P(w*) that
    /// [`Method::eval`] reports against.
    pub fn set_primal_target(&mut self, p_star: Option<f64>) {
        self.p_star = p_star;
    }

    /// One synchronous round; returns max worker compute seconds.
    pub fn round(&mut self, t: usize) -> f64 {
        let lambda = self.problem.lambda;
        let loss = self.problem.loss;
        let eta = 1.0 / (lambda * (t as f64 + self.cfg.t0));
        let d = self.problem.d();

        // Each worker's averaged subgradient of the loss term on its batch.
        let mut grad = vec![0.0; d];
        let mut max_compute = 0.0f64;
        for (k, block) in self.blocks.iter().enumerate() {
            let t0 = Instant::now();
            let nk = block.n_local();
            let x = block.x();
            let y = block.y();
            let b = self.cfg.batch_per_worker.min(nk);
            let mut local = vec![0.0; d];
            for _ in 0..b {
                let i = self.rngs[k].gen_range(nk);
                let z = x.row_dot(i, &self.w);
                let g = loss.subgradient(z, y[i]);
                if g != 0.0 {
                    x.row_axpy(i, g / b as f64, &mut local);
                }
            }
            dense::axpy(1.0 / self.cfg.k as f64, &local, &mut grad);
            max_compute = max_compute.max(t0.elapsed().as_secs_f64());
        }

        // w ← (1 − ηλ)·w − η·grad  (regularizer folded in).
        let shrink = 1.0 - eta * lambda;
        for (wi, gi) in self.w.iter_mut().zip(&grad) {
            *wi = shrink * *wi - eta * *gi;
        }
        max_compute
    }

    /// Run to a *primal suboptimality* target through the shared
    /// [`Driver`] loop. SGD has no dual certificate (the paper makes this
    /// point explicitly) — we report the primal value and, when `p_star`
    /// is provided, suboptimality against it (and only then can the gap
    /// tolerance stop the run).
    pub fn run(&mut self, p_star: Option<f64>) -> History {
        self.p_star = p_star;
        let gap_tol = if p_star.is_some() {
            self.cfg.gap_tol
        } else {
            f64::NEG_INFINITY
        };
        // f64::MAX: an overflowed (infinite) primal flags divergence, as
        // the old hand-rolled loop did, while any finite value runs on.
        let mut driver = Driver::new(
            StopPolicy::new(self.cfg.max_rounds)
                .with_gap_tol(gap_tol)
                .with_divergence_gap(f64::MAX),
        )
        .with_gap_every(self.cfg.gap_every);
        driver.run(self)
    }
}

impl Method for MiniBatchSgd {
    fn step(&mut self) -> StepStats {
        let compute_s = self.round(self.t);
        self.t += 1;
        StepStats {
            compute_s,
            comm_vectors: self.cfg.comm.round_vectors(self.cfg.k),
        }
    }

    fn eval(&mut self) -> Certificates {
        let primal = self.problem.primal_value(&self.w);
        let gap = match self.p_star {
            Some(ps) => primal - ps,
            None => primal,
        };
        Certificates {
            primal,
            dual: f64::NEG_INFINITY,
            gap,
        }
    }

    fn comm_vectors_per_round(&self) -> usize {
        self.cfg.comm.round_vectors(self.cfg.k)
    }

    fn w(&self) -> &[f64] {
        &self.w
    }

    fn label(&self) -> String {
        format!(
            "minibatch_sgd(K={},b={})",
            self.cfg.k, self.cfg.batch_per_worker
        )
    }

    fn comm_model(&self) -> CommModel {
        self.cfg.comm
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.w))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::history::StopReason;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    fn setup(k: usize) -> MiniBatchSgd {
        let data = generate(&SynthConfig::new("t", 100, 8).seed(3));
        let p = Problem::new(data, Loss::Hinge, 0.05);
        let part = random_balanced(100, k, 7);
        MiniBatchSgd::new(p, part, MiniBatchSgdConfig::new(k))
    }

    #[test]
    fn primal_decreases_over_training() {
        let mut s = setup(4);
        let p0 = s.problem.primal_value(&s.w);
        for t in 0..300 {
            s.round(t);
        }
        let p1 = s.problem.primal_value(&s.w);
        assert!(p1 < p0, "SGD failed to reduce primal: {p0} → {p1}");
    }

    #[test]
    fn run_records_history() {
        let mut s = setup(2);
        s.cfg.max_rounds = 50;
        let h = s.run(None);
        assert!(!h.records.is_empty());
        assert!(h.records.last().unwrap().primal.is_finite());
        // without p*, stop reason is MaxRounds
        assert_eq!(h.stop, StopReason::MaxRounds);
    }

    #[test]
    fn reaches_suboptimality_with_target() {
        let mut s = setup(2);
        s.cfg.max_rounds = 2000;
        s.cfg.gap_tol = 0.05;
        // crude p* estimate: long run first
        let mut probe = setup(2);
        for t in 0..3000 {
            probe.round(t);
        }
        let p_star = probe.problem.primal_value(&probe.w);
        let h = s.run(Some(p_star));
        assert_eq!(h.stop, StopReason::GapReached);
    }
}
