//! Consensus ADMM for distributed SVM training (Forero, Cano & Giannakis
//! 2010; Boyd et al. 2011) — the alternating-direction baseline of §6.
//!
//! Splitting: min Σ_k f_k(w_k) + (λ/2)‖z‖²  s.t. w_k = z  ∀k, where
//! f_k(w) = (1/n) Σ_{i∈P_k} ℓ_i(x_iᵀw). Scaled-dual iterations:
//!
//!   w_k ← argmin f_k(w) + (ρ/2)‖w − z + u_k‖²      (inexact, local)
//!   z   ← ρ Σ_k (w_k + u_k) / (λ + Kρ)
//!   u_k ← u_k + w_k − z
//!
//! The w-update is solved inexactly by subgradient descent on the
//! ρ-strongly-convex augmented local objective — mirroring the paper's
//! point that ADMM-style methods need nontrivial subproblem work per
//! round and carry a ρ whose tuning is "often unclear", in contrast to
//! CoCoA+'s tune-free safe σ'. Communication per round matches CoCoA
//! (one d-vector per worker up, one broadcast down).

use crate::coordinator::comm::CommModel;
use crate::coordinator::history::History;
use crate::data::Partition;
use crate::driver::{Driver, Method, StepStats, StopPolicy};
use crate::linalg::dense;
use crate::objective::{Certificates, Problem};
use crate::subproblem::LocalBlock;
use crate::util::rng::Pcg32;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub k: usize,
    /// Augmented-Lagrangian penalty ρ.
    pub rho: f64,
    /// Inexact local subgradient steps per round.
    pub local_iters: usize,
    pub max_rounds: usize,
    /// Stop when primal suboptimality vs `p_star` (if given to run) ≤ tol.
    pub tol: f64,
    pub gap_every: usize,
    pub seed: u64,
    pub comm: CommModel,
}

impl AdmmConfig {
    pub fn new(k: usize) -> AdmmConfig {
        AdmmConfig {
            k,
            rho: 1.0,
            local_iters: 50,
            max_rounds: 500,
            tol: 1e-3,
            gap_every: 5,
            seed: 42,
            comm: CommModel::ec2_like(),
        }
    }
}

pub struct Admm {
    pub cfg: AdmmConfig,
    pub problem: Problem,
    blocks: Vec<LocalBlock>,
    /// Local models w_k.
    pub w_local: Vec<Vec<f64>>,
    /// Scaled duals u_k.
    pub u: Vec<Vec<f64>>,
    /// Consensus iterate z.
    pub z: Vec<f64>,
    rngs: Vec<Pcg32>,
    /// Externally estimated P(w*) — when set, the history's `gap` column
    /// holds primal suboptimality against it.
    p_star: Option<f64>,
}

impl Admm {
    pub fn new(problem: Problem, partition: Partition, cfg: AdmmConfig) -> Admm {
        assert_eq!(partition.k(), cfg.k);
        assert_eq!(partition.n, problem.n());
        assert!(cfg.rho > 0.0, "ρ must be positive");
        let blocks = LocalBlock::split(&problem.data, &partition);
        let d = problem.d();
        let rngs = (0..cfg.k)
            .map(|k| Pcg32::new(cfg.seed, 5000 + k as u64))
            .collect();
        Admm {
            cfg: cfg.clone(),
            problem,
            blocks,
            w_local: vec![vec![0.0; d]; cfg.k],
            u: vec![vec![0.0; d]; cfg.k],
            z: vec![0.0; d],
            rngs,
            p_star: None,
        }
    }

    /// Set (or clear) the primal-suboptimality target P(w*) that
    /// [`Method::eval`] reports against.
    pub fn set_primal_target(&mut self, p_star: Option<f64>) {
        self.p_star = p_star;
    }

    /// Inexact w_k update: subgradient descent on
    /// f_k(w) + (ρ/2)‖w − c‖², c = z − u_k (ρ-strongly convex → 1/(ρt) steps).
    fn local_w_update(&mut self, kid: usize) {
        let block = &self.blocks[kid];
        let n = self.problem.n() as f64;
        let loss = self.problem.loss;
        let rho = self.cfg.rho;
        let d = self.problem.d();
        let nk = block.n_local();
        let x = block.x();
        let y = block.y();
        let mut c = vec![0.0; d];
        dense::sub(&self.z, &self.u[kid], &mut c);
        let w = &mut self.w_local[kid];
        // warm start from the previous w_k
        for t in 1..=self.cfg.local_iters {
            let eta = 1.0 / (rho * (t as f64 + 5.0));
            // stochastic subgradient of f_k on a sampled point (scaled by
            // n_k/n to match f_k's 1/n normalization), plus the prox term.
            let i = self.rngs[kid].gen_range(nk);
            let z_i = x.row_dot(i, w);
            let g = loss.subgradient(z_i, y[i]) * (nk as f64 / n);
            // w ← w − η(g·x_i + ρ(w − c))
            let shrink = 1.0 - eta * rho;
            for (wj, cj) in w.iter_mut().zip(&c) {
                *wj = shrink * *wj + eta * rho * *cj;
            }
            if g != 0.0 {
                x.row_axpy(i, -eta * g, w);
            }
        }
    }

    /// One ADMM round; returns max worker compute seconds.
    pub fn round(&mut self) -> f64 {
        let k = self.cfg.k;
        let d = self.problem.d();
        let rho = self.cfg.rho;
        let lambda = self.problem.lambda;

        let mut max_compute = 0.0f64;
        for kid in 0..k {
            let t0 = Instant::now();
            self.local_w_update(kid);
            max_compute = max_compute.max(t0.elapsed().as_secs_f64());
        }
        // z-update (leader)
        let mut acc = vec![0.0; d];
        for (wk, uk) in self.w_local.iter().zip(&self.u) {
            for ((aj, wj), uj) in acc.iter_mut().zip(wk).zip(uk) {
                *aj += *wj + *uj;
            }
        }
        let scale = rho / (lambda + k as f64 * rho);
        for (zj, aj) in self.z.iter_mut().zip(&acc) {
            *zj = scale * *aj;
        }
        // u-update
        for (uk, wk) in self.u.iter_mut().zip(&self.w_local) {
            for ((uj, wj), zj) in uk.iter_mut().zip(wk).zip(&self.z) {
                *uj += *wj - *zj;
            }
        }
        max_compute
    }

    /// Primal residual ‖w_k − z‖ aggregated (consensus violation).
    pub fn consensus_residual(&self) -> f64 {
        self.w_local
            .iter()
            .map(|w| dense::distance(w, &self.z))
            .fold(0.0f64, f64::max)
    }

    /// Run through the shared [`Driver`] loop, reporting primal values of
    /// the consensus iterate (ADMM has no dual certificate in this form —
    /// the paper's §6 point about primal-only baselines). Only when
    /// `p_star` is provided can the tolerance stop the run.
    pub fn run(&mut self, p_star: Option<f64>) -> History {
        self.p_star = p_star;
        let gap_tol = if p_star.is_some() {
            self.cfg.tol
        } else {
            f64::NEG_INFINITY
        };
        // f64::MAX: an overflowed (infinite) primal flags divergence, as
        // the old hand-rolled loop did, while any finite value runs on.
        let mut driver = Driver::new(
            StopPolicy::new(self.cfg.max_rounds)
                .with_gap_tol(gap_tol)
                .with_divergence_gap(f64::MAX),
        )
        .with_gap_every(self.cfg.gap_every);
        driver.run(self)
    }
}

impl Method for Admm {
    fn step(&mut self) -> StepStats {
        let compute_s = self.round();
        StepStats {
            compute_s,
            comm_vectors: self.cfg.comm.round_vectors(self.cfg.k),
        }
    }

    fn eval(&mut self) -> Certificates {
        let primal = self.problem.primal_value(&self.z);
        let gap = match self.p_star {
            Some(ps) => primal - ps,
            None => primal,
        };
        Certificates {
            primal,
            dual: f64::NEG_INFINITY,
            gap,
        }
    }

    fn comm_vectors_per_round(&self) -> usize {
        self.cfg.comm.round_vectors(self.cfg.k)
    }

    fn w(&self) -> &[f64] {
        &self.z
    }

    fn label(&self) -> String {
        format!(
            "admm(K={},rho={},iters={})",
            self.cfg.k, self.cfg.rho, self.cfg.local_iters
        )
    }

    fn comm_model(&self) -> CommModel {
        self.cfg.comm
    }

    fn train_error(&self) -> Option<f64> {
        Some(self.problem.data.classification_error(&self.z))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::serial_sdca;
    use crate::data::partition::random_balanced;
    use crate::data::synth::{generate, SynthConfig};
    use crate::loss::Loss;

    fn setup(k: usize, rho: f64) -> Admm {
        let data = generate(&SynthConfig::new("admm", 150, 10).seed(3));
        let p = Problem::new(data, Loss::Hinge, 1e-2);
        let part = random_balanced(150, k, 7);
        let mut cfg = AdmmConfig::new(k);
        cfg.rho = rho;
        Admm::new(p, part, cfg)
    }

    #[test]
    fn consensus_residual_shrinks() {
        // With stochastic local solves the residual settles into a small
        // noise ball rather than decaying monotonically: compare the first
        // round's violation against the settled level, with slack.
        let mut a = setup(4, 1.0);
        a.round();
        let early = a.consensus_residual();
        for _ in 0..120 {
            a.round();
        }
        let late = a.consensus_residual();
        assert!(
            late < early * 1.5,
            "consensus violation grew: {early} → {late}"
        );
        assert!(late < 0.2, "consensus not approximately reached: {late}");
    }

    #[test]
    fn primal_approaches_optimum() {
        let mut a = setup(3, 1.0);
        let p_star = serial_sdca::solve(&a.problem, &Default::default()).certs.primal;
        let p0 = a.problem.primal_value(&a.z);
        for _ in 0..300 {
            a.round();
        }
        let p_end = a.problem.primal_value(&a.z);
        assert!(p_end < p0, "no progress: {p0} → {p_end}");
        let sub0 = p0 - p_star;
        let sub_end = p_end - p_star;
        assert!(
            sub_end < sub0 * 0.2,
            "ADMM should close most of the suboptimality: {sub0} → {sub_end}"
        );
    }

    #[test]
    fn cocoa_plus_beats_admm_per_round_budget() {
        // The §6 comparison: at an equal communication budget, CoCoA+'s
        // certificate-driven progress dominates ADMM's.
        use crate::coordinator::{CocoaConfig, SolverSpec, Trainer};
        let data = generate(&SynthConfig::new("vs", 150, 10).seed(5));
        let p_star = {
            let p = Problem::new(data.clone(), Loss::Hinge, 1e-2);
            serial_sdca::solve(&p, &Default::default()).certs.primal
        };
        let part = random_balanced(150, 4, 9);
        let rounds = 25;

        let mut admm = Admm::new(
            Problem::new(data.clone(), Loss::Hinge, 1e-2),
            part.clone(),
            AdmmConfig::new(4),
        );
        for _ in 0..rounds {
            admm.round();
        }
        let admm_sub = admm.problem.primal_value(&admm.z) - p_star;

        let cfg = CocoaConfig::cocoa_plus(
            4,
            Loss::Hinge,
            1e-2,
            SolverSpec::SdcaEpochs { epochs: 1.0 },
        )
        .with_rounds(rounds)
        .with_gap_tol(0.0)
        .with_parallel(false);
        let mut t = Trainer::new(Problem::new(data, Loss::Hinge, 1e-2), part, cfg);
        t.run();
        let cocoa_sub = t.problem.primal_value(&t.w) - p_star;
        assert!(
            cocoa_sub <= admm_sub + 1e-9,
            "CoCoA+ subopt {cocoa_sub} should beat ADMM {admm_sub} at {rounds} rounds"
        );
    }

    #[test]
    #[should_panic]
    fn zero_rho_rejected() {
        let data = generate(&SynthConfig::new("t", 20, 4).seed(1));
        let p = Problem::new(data, Loss::Hinge, 0.1);
        let part = random_balanced(20, 2, 1);
        let mut cfg = AdmmConfig::new(2);
        cfg.rho = 0.0;
        Admm::new(p, part, cfg);
    }
}
