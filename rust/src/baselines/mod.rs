//! Competing methods from the paper's evaluation and related-work
//! discussion (§6): distributed mini-batch SGD (Fig. 2's third curve),
//! mini-batch SDCA, one-shot averaging, and the serial SDCA reference
//! used to estimate optima, plus consensus-ADMM (Forero et al. 2010).

pub mod admm;
pub mod minibatch_sdca;
pub mod minibatch_sgd;
pub mod one_shot;
pub mod serial_sdca;
