//! Competing methods from the paper's evaluation and related-work
//! discussion (§6): distributed mini-batch SGD (Fig. 2's third curve),
//! mini-batch SDCA, one-shot averaging, and the serial SDCA reference
//! used to estimate optima, plus consensus-ADMM (Forero et al. 2010).
//!
//! Every baseline implements the [`Method`](crate::driver::Method) trait,
//! so the [`Driver`](crate::driver::Driver) runs all of them — and the
//! CoCoA/CoCoA+ [`Trainer`](crate::coordinator::Trainer) — through one
//! loop with identical communication and simulated-time accounting. The
//! per-baseline `run()` helpers are thin wrappers that translate each
//! config's stopping fields into a [`StopPolicy`](crate::driver::StopPolicy).

pub mod admm;
pub mod minibatch_sdca;
pub mod minibatch_sgd;
pub mod one_shot;
pub mod serial_sdca;
