//! Seeded property-testing harness — a from-scratch stand-in for proptest
//! (unavailable offline). Generators draw from [`Pcg32`]; `forall` runs a
//! predicate over many generated cases and reports the seed of the first
//! failure so it can be replayed exactly.
//!
//! ```no_run
//! use cocoa::testing::prop::{forall, Gen};
//! forall("dot is symmetric", 50, |g| {
//!     let xs = g.vec_f64(10, -5.0, 5.0);
//!     let ys = g.vec_f64(10, -5.0, 5.0);
//!     let a = cocoa::linalg::dense::dot(&xs, &ys);
//!     let b = cocoa::linalg::dense::dot(&ys, &xs);
//!     assert!((a - b).abs() < 1e-12);
//! });
//! ```

use crate::util::rng::Pcg32;

/// A case generator handed to each property iteration.
pub struct Gen {
    pub rng: Pcg32,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_excl: usize) -> usize {
        assert!(hi_excl > lo);
        lo + self.rng.gen_range(hi_excl - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Log-uniform positive float (for λ, tolerances, …).
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.uniform(lo.ln(), hi.ln())).exp()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.gaussian_vec(n)
    }

    /// ±1 labels.
    pub fn labels(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| if self.bool() { 1.0 } else { -1.0 })
            .collect()
    }

    pub fn choose<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        &options[self.rng.gen_range(options.len())]
    }
}

/// Run `body` for `cases` generated cases. Panics (with the case seed in
/// the message) on the first failing case. Override the master seed with
/// `COCOA_PROP_SEED` to replay a failure.
pub fn forall(name: &str, cases: usize, mut body: impl FnMut(&mut Gen)) {
    let master: u64 = std::env::var("COCOA_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0C0_A000);
    for case in 0..cases {
        let case_seed = master
            .wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut gen = Gen {
            rng: Pcg32::new(case_seed, 777),
            case_seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut gen)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay with COCOA_PROP_SEED={master}, case seed {case_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("usize bounds", 100, |g| {
            let v = g.usize_in(3, 10);
            assert!((3..10).contains(&v));
        });
    }

    #[test]
    fn forall_reports_failures() {
        let result = std::panic::catch_unwind(|| {
            forall("always fails", 5, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"));
        assert!(msg.contains("replay"));
    }

    #[test]
    fn log_uniform_in_range() {
        forall("log uniform", 200, |g| {
            let v = g.f64_log(1e-6, 1e-1);
            assert!((1e-6..=1e-1).contains(&v));
        });
    }

    #[test]
    fn labels_are_plus_minus_one() {
        forall("labels", 20, |g| {
            let n = g.usize_in(1, 30);
            for y in g.labels(n) {
                assert!(y == 1.0 || y == -1.0);
            }
        });
    }
}
