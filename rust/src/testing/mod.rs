//! Mini property-testing harness (the offline registry has no proptest).

pub mod prop;
