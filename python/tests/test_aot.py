"""AOT pipeline invariants: the lowered HLO text parses, mentions no
Mosaic custom-calls (interpret=True requirement), and the manifest
signature matches what the lowering actually produced."""

import json
import os
import tempfile

import pytest

from compile import aot

SMALL = {"m": 16, "d": 8, "h": 32, "n": 64}


@pytest.fixture(scope="module")
def built():
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.build(td, SMALL)
        texts = {}
        for e in manifest["entries"]:
            with open(os.path.join(td, e["file"])) as f:
                texts[e["name"]] = f.read()
        yield manifest, texts


def test_manifest_structure(built):
    manifest, _ = built
    assert manifest["version"] == 1
    assert manifest["dtype"] == "f64"
    kinds = {e["kind"] for e in manifest["entries"]}
    assert kinds == {"local_sdca", "duality_gap"}
    for e in manifest["entries"]:
        assert e["loss"] == "hinge"
        assert e["file"].endswith(".hlo.txt")
        assert len(e["sha256"]) == 64


def test_hlo_text_is_parseable_hlo(built):
    _, texts = built
    for name, text in texts.items():
        assert "HloModule" in text, f"{name} does not look like HLO text"
        assert "ENTRY" in text
        # interpret=True must not leave TPU custom calls behind
        assert "tpu_custom_call" not in text, f"{name} contains Mosaic custom-call"
        assert "mosaic" not in text.lower()


def test_parameter_counts_match_manifest(built):
    manifest, texts = built
    for e in manifest["entries"]:
        text = texts[e["name"]]
        # every declared input appears as a parameter in the entry computation
        n_params = text.count("parameter(")
        assert n_params >= len(e["inputs"]), (
            f"{e['name']}: {n_params} parameters < {len(e['inputs'])} declared"
        )


def test_shapes_recorded(built):
    manifest, _ = built
    by_kind = {e["kind"]: e for e in manifest["entries"]}
    sdca = by_kind["local_sdca"]
    assert sdca["dims"] == {"m": SMALL["m"], "d": SMALL["d"], "h": SMALL["h"]}
    assert sdca["inputs"][0]["shape"] == [SMALL["m"], SMALL["d"]]
    assert sdca["inputs"][5]["dtype"] == "i32"
    gap = by_kind["duality_gap"]
    assert gap["dims"] == {"n": SMALL["n"], "d": SMALL["d"]}
    assert gap["outputs"][0]["shape"] == []


def test_build_is_deterministic():
    with tempfile.TemporaryDirectory() as t1, tempfile.TemporaryDirectory() as t2:
        m1 = aot.build(t1, SMALL)
        m2 = aot.build(t2, SMALL)
        h1 = [e["sha256"] for e in m1["entries"]]
        h2 = [e["sha256"] for e in m2["entries"]]
        assert h1 == h2


def test_manifest_json_roundtrip(built):
    manifest, _ = built
    text = json.dumps(manifest)
    assert json.loads(text) == manifest
