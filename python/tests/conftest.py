"""Shared pytest fixtures. x64 must be flipped before jax initializes."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def make_block(rng, m, d, n_pad=0, seed_offset=0):
    """A padded local block: (x, y, alpha, w, qi) with `n_pad` zero rows."""
    r = np.random.default_rng(1234 + seed_offset)
    x = r.normal(size=(m, d))
    # normalize rows to <= 1 like the paper
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    x = x / np.maximum(norms, 1e-12)
    if n_pad:
        x[m - n_pad:] = 0.0
    y = np.sign(r.normal(size=m))
    y[y == 0] = 1.0
    alpha = np.zeros(m)
    w = r.normal(size=d) * 0.1
    qi = (x * x).sum(axis=1)
    return x, y, alpha, w, qi
