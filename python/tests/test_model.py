"""L2 graph correctness: duality_gap vs the numpy oracle; semantic
properties of the certificates (weak duality, optimality at the SDCA fixed
point); local_sdca improves the padded-global dual objective."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from tests.conftest import make_block


def gap_inputs(n, d, n_pad=0, seed=0, alpha_mode="zero"):
    x, y, _, _, qi = make_block(None, n, d, n_pad=n_pad, seed_offset=seed)
    mask = np.ones(n)
    if n_pad:
        mask[n - n_pad:] = 0.0
    r = np.random.default_rng(seed + 100)
    if alpha_mode == "zero":
        alpha = np.zeros(n)
    else:
        alpha = y * r.uniform(0, 1, size=n) * mask
    return x, y, alpha, mask, qi


@pytest.mark.parametrize("n,d", [(16, 4), (100, 16), (256, 64)])
def test_duality_gap_matches_ref(n, d):
    x, y, alpha, mask, _ = gap_inputs(n, d, seed=n, alpha_mode="rand")
    lam = np.array([1e-2])
    p, dv, g, w = model.duality_gap(x, y, alpha, mask, lam)
    rp, rd, rg, rw = ref.ref_duality_gap(x, y, alpha, mask, lam[0])
    np.testing.assert_allclose(float(p), rp, rtol=1e-12)
    np.testing.assert_allclose(float(dv), rd, rtol=1e-12)
    np.testing.assert_allclose(float(g), rg, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(w), rw, atol=1e-12)


def test_weak_duality_nonneg_gap():
    for seed in range(5):
        x, y, alpha, mask, _ = gap_inputs(60, 8, seed=seed, alpha_mode="rand")
        lam = np.array([np.random.default_rng(seed).uniform(1e-4, 1e-1)])
        _, _, g, _ = model.duality_gap(x, y, alpha, mask, lam)
        assert float(g) >= -1e-12


def test_gap_with_padding_matches_unpadded():
    """Padding rows (mask=0, zero features, alpha=0) must not change the
    certificates of the embedded real problem."""
    n, d, pad = 50, 6, 14
    x, y, alpha, mask, _ = gap_inputs(n, d, seed=7, alpha_mode="rand")
    lam = np.array([5e-3])
    p0, d0, g0, w0 = model.duality_gap(x, y, alpha, mask, lam)

    xp = np.vstack([x, np.zeros((pad, d))])
    yp = np.concatenate([y, np.ones(pad)])
    ap = np.concatenate([alpha, np.zeros(pad)])
    mp = np.concatenate([mask, np.zeros(pad)])
    p1, d1, g1, w1 = model.duality_gap(xp, yp, ap, mp, lam)
    np.testing.assert_allclose(float(p0), float(p1), rtol=1e-12)
    np.testing.assert_allclose(float(d0), float(d1), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(w0), np.asarray(w1), atol=1e-14)


def test_gap_at_zero_alpha_bounded_by_one():
    """Paper Eq. (5)/Lemma 17: at alpha=0, P(0)-D(0) = (1/n) sum l_i(0) <= 1."""
    x, y, alpha, mask, _ = gap_inputs(80, 10, seed=3, alpha_mode="zero")
    lam = np.array([1e-3])
    _, _, g, _ = model.duality_gap(x, y, alpha, mask, lam)
    assert 0.0 <= float(g) <= 1.0 + 1e-12


def test_local_sdca_improves_global_dual():
    """Running the L2 local round on the whole data (K=1, sigma'=1) must
    increase D(alpha) = dual objective of the padded problem."""
    n, d, h = 64, 8, 600
    x, y, alpha, mask, qi = gap_inputs(n, d, seed=9, alpha_mode="zero")
    lam = 1e-2
    lam_arr = np.array([lam])
    w = np.zeros(d)
    _, d_before, _, _ = model.duality_gap(x, y, alpha, mask, lam_arr)
    idx = np.random.default_rng(11).integers(0, n, size=h).astype(np.int32)
    scal = np.array([lam * n, 1.0])
    da, dw = model.local_sdca(x, y, alpha, w, qi, idx, scal)
    alpha2 = alpha + np.asarray(da)
    _, d_after, _, _ = model.duality_gap(x, y, alpha2, mask, lam_arr)
    assert float(d_after) > float(d_before)


def test_local_sdca_many_rounds_shrinks_gap():
    """A miniature single-worker CoCoA loop entirely through the L2 graphs:
    gap must fall by orders of magnitude."""
    n, d, h = 48, 6, 300
    x, y, alpha, mask, qi = gap_inputs(n, d, seed=13, alpha_mode="zero")
    lam = 5e-2
    lam_arr = np.array([lam])
    w = np.zeros(d)
    r = np.random.default_rng(17)
    _, _, g0, _ = model.duality_gap(x, y, alpha, mask, lam_arr)
    for _ in range(12):
        idx = r.integers(0, n, size=h).astype(np.int32)
        scal = np.array([lam * n, 1.0])
        da, dw = model.local_sdca(x, y, alpha, w, qi, idx, scal)
        alpha = alpha + np.asarray(da)
        w = w + np.asarray(dw)
    _, _, g1, w_cert = model.duality_gap(x, y, alpha, mask, lam_arr)
    assert float(g1) < float(g0) * 1e-2, f"gap {float(g0)} -> {float(g1)}"
    # maintained w must agree with the certificate's recomputed w
    np.testing.assert_allclose(w, np.asarray(w_cert), atol=1e-9)
