"""L1 kernel correctness: Pallas kernels vs the pure-numpy oracle in
kernels/ref.py, swept over shapes, paddings, and parameter ranges
(hand-rolled hypothesis-style grids — no hypothesis offline)."""

import numpy as np
import pytest

from compile.kernels import matvec, ref, sdca
from tests.conftest import make_block


# ---------------------------------------------------------------- matvec

@pytest.mark.parametrize("m,d", [(1, 1), (3, 7), (16, 16), (100, 33),
                                 (128, 64), (130, 5), (257, 96)])
def test_matvec_matches_ref(rng, m, d):
    x = rng.normal(size=(m, d))
    w = rng.normal(size=d)
    got = np.asarray(matvec.matvec(x, w))
    want = ref.ref_matvec(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("m,d", [(1, 1), (3, 7), (16, 16), (100, 33),
                                 (128, 64), (130, 5), (257, 96)])
def test_matvec_t_matches_ref(rng, m, d):
    x = rng.normal(size=(m, d))
    u = rng.normal(size=m)
    got = np.asarray(matvec.matvec_t(x, u))
    want = ref.ref_matvec_t(x, u)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("block_rows", [1, 8, 64, 1024])
def test_matvec_block_size_invariance(rng, block_rows):
    x = rng.normal(size=(70, 12))
    w = rng.normal(size=12)
    got = np.asarray(matvec.matvec(x, w, block_rows=block_rows))
    np.testing.assert_allclose(got, ref.ref_matvec(x, w), rtol=1e-12)


@pytest.mark.parametrize("block_rows", [1, 8, 64, 1024])
def test_matvec_t_block_size_invariance(rng, block_rows):
    x = rng.normal(size=(70, 12))
    u = rng.normal(size=70)
    got = np.asarray(matvec.matvec_t(x, u, block_rows=block_rows))
    np.testing.assert_allclose(got, ref.ref_matvec_t(x, u), rtol=1e-12)


def test_matvec_f32_dtype(rng):
    x = rng.normal(size=(33, 9)).astype(np.float32)
    w = rng.normal(size=9).astype(np.float32)
    got = np.asarray(matvec.matvec(x, w))
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, ref.ref_matvec(x, w), rtol=1e-5)


def test_matvec_zero_matrix():
    x = np.zeros((10, 4))
    w = np.ones(4)
    np.testing.assert_array_equal(np.asarray(matvec.matvec(x, w)), np.zeros(10))


# ---------------------------------------------------------------- sdca

@pytest.mark.parametrize("m,d,h", [(4, 3, 10), (32, 8, 100), (64, 16, 300),
                                   (100, 7, 500)])
def test_sdca_matches_ref(m, d, h):
    x, y, alpha, w, qi = make_block(None, m, d, seed_offset=m)
    r = np.random.default_rng(m * 7 + 1)
    idx = r.integers(0, m, size=h).astype(np.int32)
    lam_n, sp = 0.05 * m, 4.0
    scal = np.array([lam_n, sp])
    da, dw = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    rda, rdw = ref.ref_local_sdca(x, y, alpha, w, qi, idx, lam_n, sp)
    np.testing.assert_allclose(np.asarray(da), rda, atol=1e-12)
    np.testing.assert_allclose(np.asarray(dw), rdw, atol=1e-12)


def test_sdca_with_padding_rows():
    m, d, h = 40, 8, 200
    x, y, alpha, w, qi = make_block(None, m, d, n_pad=10, seed_offset=3)
    r = np.random.default_rng(5)
    idx = r.integers(0, m, size=h).astype(np.int32)  # may hit pad rows
    scal = np.array([0.1 * m, 2.0])
    da, dw = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    rda, rdw = ref.ref_local_sdca(x, y, alpha, w, qi, idx, scal[0], scal[1])
    np.testing.assert_allclose(np.asarray(da), rda, atol=1e-12)
    np.testing.assert_allclose(np.asarray(dw), rdw, atol=1e-12)
    # pad rows never move
    assert np.all(np.asarray(da)[-10:] == 0.0)


@pytest.mark.parametrize("sp", [1.0, 2.0, 8.0])
@pytest.mark.parametrize("lam", [1e-1, 1e-3])
def test_sdca_parameter_sweep(sp, lam):
    m, d, h = 24, 6, 120
    x, y, alpha, w, qi = make_block(None, m, d, seed_offset=11)
    idx = np.random.default_rng(9).integers(0, m, size=h).astype(np.int32)
    scal = np.array([lam * m, sp])
    da, dw = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    rda, rdw = ref.ref_local_sdca(x, y, alpha, w, qi, idx, scal[0], scal[1])
    np.testing.assert_allclose(np.asarray(da), rda, atol=1e-12)
    np.testing.assert_allclose(np.asarray(dw), rdw, atol=1e-12)


def test_sdca_dual_feasibility():
    """After any number of steps, y*(alpha+delta) stays in [0,1] (hinge box)."""
    m, d, h = 30, 5, 400
    x, y, alpha, w, qi = make_block(None, m, d, seed_offset=21)
    # start from a nonzero feasible alpha
    r = np.random.default_rng(2)
    alpha = y * r.uniform(0, 1, size=m)
    idx = r.integers(0, m, size=h).astype(np.int32)
    scal = np.array([0.02 * m, 3.0])
    da, _ = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    b = y * (alpha + np.asarray(da))
    assert np.all(b >= -1e-12) and np.all(b <= 1 + 1e-12)


def test_sdca_nonzero_start_matches_ref():
    m, d, h = 26, 9, 150
    x, y, _, w, qi = make_block(None, m, d, seed_offset=31)
    r = np.random.default_rng(7)
    alpha = y * r.uniform(0, 1, size=m)
    idx = r.integers(0, m, size=h).astype(np.int32)
    scal = np.array([0.05 * m, 2.5])
    da, dw = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    rda, rdw = ref.ref_local_sdca(x, y, alpha, w, qi, idx, scal[0], scal[1])
    np.testing.assert_allclose(np.asarray(da), rda, atol=1e-12)
    np.testing.assert_allclose(np.asarray(dw), rdw, atol=1e-12)


def test_sdca_deterministic():
    m, d, h = 20, 4, 60
    x, y, alpha, w, qi = make_block(None, m, d, seed_offset=41)
    idx = np.random.default_rng(3).integers(0, m, size=h).astype(np.int32)
    scal = np.array([0.1 * m, 2.0])
    a1, w1 = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    a2, w2 = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_sdca_delta_w_identity():
    """delta_w must equal X^T delta_alpha/(lambda n) exactly."""
    m, d, h = 22, 6, 90
    x, y, alpha, w, qi = make_block(None, m, d, seed_offset=51)
    idx = np.random.default_rng(4).integers(0, m, size=h).astype(np.int32)
    lam_n = 0.07 * m
    scal = np.array([lam_n, 5.0])
    da, dw = sdca.sdca_local_update(x, y, alpha, w, qi, idx, scal)
    want = x.T @ np.asarray(da) / lam_n
    np.testing.assert_allclose(np.asarray(dw), want, atol=1e-12)
