"""AOT compilation: lower the L2 graphs to HLO *text* + a manifest.

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla_extension 0.5.1
behind the Rust `xla` crate rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

The manifest (artifacts/manifest.json) records, per artifact: logical name,
file, kind, loss, the monomorphic shapes, and the positional input/output
signature the Rust runtime packs literals against. Python runs exactly once
(`make artifacts`); nothing here is on the request path.
"""

import argparse
import hashlib
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402

# Default artifact shape set. m: padded rows per worker block; d: features;
# h: inner SDCA steps per round; n: padded global rows for the gap graph.
DEFAULT_SHAPES = {
    "m": 256,
    "d": 64,
    "h": 512,
    "n": 1024,
}

F64 = jnp.float64
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, however many outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_local_sdca(m: int, d: int, h: int):
    args = (
        spec((m, d), F64),   # x
        spec((m,), F64),     # y
        spec((m,), F64),     # alpha
        spec((d,), F64),     # w
        spec((m,), F64),     # qi
        spec((h,), I32),     # indices
        spec((2,), F64),     # scalars [lambda*n, sigma']
    )
    lowered = jax.jit(model.local_sdca).lower(*args)
    inputs = [
        {"name": "x", "shape": [m, d], "dtype": "f64"},
        {"name": "y", "shape": [m], "dtype": "f64"},
        {"name": "alpha", "shape": [m], "dtype": "f64"},
        {"name": "w", "shape": [d], "dtype": "f64"},
        {"name": "qi", "shape": [m], "dtype": "f64"},
        {"name": "indices", "shape": [h], "dtype": "i32"},
        {"name": "scalars", "shape": [2], "dtype": "f64"},
    ]
    outputs = [
        {"name": "delta_alpha", "shape": [m], "dtype": "f64"},
        {"name": "delta_w", "shape": [d], "dtype": "f64"},
    ]
    return lowered, inputs, outputs


def lower_duality_gap(n: int, d: int):
    args = (
        spec((n, d), F64),   # x
        spec((n,), F64),     # y
        spec((n,), F64),     # alpha
        spec((n,), F64),     # mask
        spec((1,), F64),     # lam
    )
    lowered = jax.jit(model.duality_gap).lower(*args)
    inputs = [
        {"name": "x", "shape": [n, d], "dtype": "f64"},
        {"name": "y", "shape": [n], "dtype": "f64"},
        {"name": "alpha", "shape": [n], "dtype": "f64"},
        {"name": "mask", "shape": [n], "dtype": "f64"},
        {"name": "lam", "shape": [1], "dtype": "f64"},
    ]
    outputs = [
        {"name": "primal", "shape": [], "dtype": "f64"},
        {"name": "dual", "shape": [], "dtype": "f64"},
        {"name": "gap", "shape": [], "dtype": "f64"},
        {"name": "w", "shape": [d], "dtype": "f64"},
    ]
    return lowered, inputs, outputs


def build(out_dir: str, shapes=None) -> dict:
    shapes = {**DEFAULT_SHAPES, **(shapes or {})}
    m, d, h, n = shapes["m"], shapes["d"], shapes["h"], shapes["n"]
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    jobs = [
        (
            f"local_sdca_hinge_m{m}_d{d}_h{h}",
            "local_sdca",
            lower_local_sdca(m, d, h),
            {"m": m, "d": d, "h": h},
        ),
        (
            f"duality_gap_hinge_n{n}_d{d}",
            "duality_gap",
            lower_duality_gap(n, d),
            {"n": n, "d": d},
        ),
    ]
    for name, kind, (lowered, inputs, outputs), dims in jobs:
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": kind,
                "loss": "hinge",
                "file": fname,
                "dims": dims,
                "inputs": inputs,
                "outputs": outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {"version": 1, "dtype": "f64", "entries": entries}
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--m", type=int, default=DEFAULT_SHAPES["m"])
    ap.add_argument("--d", type=int, default=DEFAULT_SHAPES["d"])
    ap.add_argument("--h", type=int, default=DEFAULT_SHAPES["h"])
    ap.add_argument("--n", type=int, default=DEFAULT_SHAPES["n"])
    args = ap.parse_args()
    build(args.out_dir, {"m": args.m, "d": args.d, "h": args.h, "n": args.n})


if __name__ == "__main__":
    main()
