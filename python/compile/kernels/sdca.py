"""L1 Pallas kernel: the LOCALSDCA block sweep (Algorithm 2) — the paper's
compute hot-spot, executed entirely out of a VMEM-resident local block.

One kernel invocation performs H sequential dual coordinate-ascent steps
over the worker's (m, d) data block for the hinge loss:

    for h in range(H):
        i     = indices[h]                       # Rust-supplied sequence
        xv    = x[i] . v                         # VMEM dot
        coef  = sigma' * ||x_i||^2 / (lambda n)
        b_new = clip(y_i(alpha_i+delta_i) + (1 - y_i xv)/coef, 0, 1)
        delta_i += y_i b_new - (alpha_i+delta_i)
        v += (sigma'/(lambda n)) * delta_step * x[i]

The coordinate sequence is an *input* (int32[H]) so the Rust coordinator
owns all randomness and the native / XLA trajectories are bit-comparable.

TPU adaptation note: the step recurrence is sequential (v depends on the
previous step), so unlike the matvec kernels there is no grid to tile —
the win on hardware is holding x, v, delta in VMEM for the whole sweep.
The ragged/padded rows (q_i = 0) are skipped by predication, not control
flow. interpret=True for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sdca_kernel(x_ref, y_ref, alpha_ref, w_ref, qi_ref, idx_ref, scal_ref,
                 dalpha_ref, v_ref):
    lam_n = scal_ref[0]
    sigma_p = scal_ref[1]
    h = idx_ref.shape[0]
    d = x_ref.shape[1]
    v_scale = sigma_p / lam_n

    # v starts at the shared w; delta at zero.
    v_ref[...] = w_ref[...]
    dalpha_ref[...] = jnp.zeros_like(dalpha_ref)

    def body(step, _):
        i = idx_ref[step]
        xi = pl.load(x_ref, (i, pl.dslice(0, d)))
        q = qi_ref[i]
        yi = y_ref[i]
        a_cur = alpha_ref[i] + dalpha_ref[i]
        xv = jnp.dot(xi, v_ref[...])
        # guard padded rows (q == 0) without branching
        coef = jnp.where(q > 0.0, sigma_p * q / lam_n, 1.0)
        b = yi * a_cur
        b_new = jnp.clip(b + (1.0 - yi * xv) / coef, 0.0, 1.0)
        delta = jnp.where(q > 0.0, yi * b_new - a_cur, 0.0)
        pl.store(dalpha_ref, (i,), dalpha_ref[i] + delta)
        v_ref[...] = v_ref[...] + (v_scale * delta) * xi
        return 0

    jax.lax.fori_loop(0, h, body, 0)


@jax.jit
def sdca_block(x, y, alpha, w, qi, indices, scalars):
    """Run H hinge-SDCA steps on a local block.

    Args:
      x: (m, d) local rows (zero rows = padding).
      y: (m,) labels.
      alpha: (m,) current local duals.
      w: (d,) shared primal vector.
      qi: (m,) squared row norms (0 marks padding).
      indices: (h,) int32 coordinate sequence.
      scalars: (2,) [lambda*n_global, sigma'].

    Returns:
      delta_alpha: (m,)
      v: (d,) final local primal image w + (sigma'/(lambda n)) X^T delta.
    """
    m, d = x.shape
    return pl.pallas_call(
        _sdca_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((m,), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ],
        interpret=True,
    )(x, y, alpha, w, qi, indices, scalars)


@functools.partial(jax.jit, static_argnames=())
def sdca_local_update(x, y, alpha, w, qi, indices, scalars):
    """L2-facing wrapper: returns (delta_alpha, delta_w) where
    delta_w = X^T delta_alpha/(lambda n) = (v - w)/sigma' (the identity the
    Rust solver uses too)."""
    delta_alpha, v = sdca_block(x, y, alpha, w, qi, indices, scalars)
    delta_w = (v - w) / scalars[1]
    return delta_alpha, delta_w
