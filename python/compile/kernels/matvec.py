"""L1 Pallas kernels: row-tiled matvec X @ w and transpose-matvec X^T u.

These are the duality-gap graph's compute. The BlockSpec tiling is the
TPU-minded schedule: row tiles of X stream HBM -> VMEM while w (resp. the
d-length accumulator) stays VMEM-resident; on a real TPU the dot is an MXU
contraction per tile. interpret=True everywhere (the CPU PJRT plugin
cannot execute Mosaic custom-calls), so these lower to plain HLO — the
structure, not the wallclock, is what carries to hardware (see
DESIGN.md "Hardware adaptation").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height: chosen so a (BM, d) f64 tile for the shipped artifact
# shapes (d <= 512) stays well under ~16 MiB of VMEM. See EXPERIMENTS.md
# #Perf for the footprint table.
DEFAULT_BLOCK_ROWS = 128


def _matvec_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = x_ref[...] @ w_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec(x, w, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """margins = X @ w via a row-tiled Pallas kernel.

    X: (m, d), w: (d,) -> (m,). Rows are tiled in blocks of `block_rows`;
    Pallas masks the ragged final block automatically.
    """
    m, d = x.shape
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x, w)


def _matvec_t_kernel(m, bm, x_ref, u_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # The final block may be ragged: Pallas pads out-of-range rows with
    # unspecified values (NaN in interpret mode), which an accumulating
    # kernel must not ingest — NaN·0 is still NaN, so mask both operands.
    rows = i * bm + jax.lax.iota(jnp.int32, bm)
    valid = rows < m
    u = jnp.where(valid, u_ref[...], 0.0)
    xb = jnp.where(valid[:, None], x_ref[...], 0.0)
    o_ref[...] += xb.T @ u


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec_t(x, u, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """X^T @ u via row-tiled accumulation.

    X: (m, d), u: (m,) -> (d,). The output block is revisited by every grid
    step (index_map constant), giving a sequential accumulate — the
    standard Pallas reduction idiom.
    """
    m, d = x.shape
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        functools.partial(_matvec_t_kernel, m, bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, u)
