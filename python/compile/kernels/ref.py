"""Pure-numpy reference oracles for the Pallas kernels (L1 correctness
anchors). Everything here is written as plainly as possible — explicit
Python loops where that is the clearest spec — and is what pytest pins the
kernels against.

The SDCA reference mirrors rust/src/solver/sdca.rs step for step: the
trajectory-identity tests across all three implementations (numpy oracle,
Pallas kernel, native Rust) consume the same coordinate index sequence.
"""

import numpy as np


def ref_matvec(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """margins = X @ w."""
    return x @ w


def ref_matvec_t(x: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Xᵀ @ u."""
    return x.T @ u


def hinge_coordinate_delta(alpha: float, y: float, xv: float, coef: float) -> float:
    """Closed-form maximizer of -l*(-(a+d)) - d*xv - coef/2 d^2 for hinge.

    Mirrors rust/src/loss/hinge.rs::coordinate_delta.
    """
    b = y * alpha
    b_unc = b + (1.0 - y * xv) / coef
    b_new = min(max(b_unc, 0.0), 1.0)
    return y * b_new - alpha


def ref_local_sdca(x, y, alpha, w, qi, indices, lam_n, sigma_prime):
    """LOCALSDCA (Algorithm 2) on the padded local block; hinge loss.

    Args:
      x: (m, d) local rows (zero rows = padding).
      y: (m,) labels (+/-1; value irrelevant on pad rows).
      alpha: (m,) current local duals.
      w: (d,) shared primal vector.
      qi: (m,) row squared norms (0 on pad rows).
      indices: (h,) int coordinate sequence.
      lam_n: scalar lambda * n_global.
      sigma_prime: scalar sigma'.

    Returns (delta_alpha (m,), delta_w (d,)).
    """
    x = np.asarray(x, dtype=np.float64)
    m, d = x.shape
    v = np.array(w, dtype=np.float64, copy=True)
    delta = np.zeros(m, dtype=np.float64)
    v_scale = sigma_prime / lam_n
    for i in np.asarray(indices, dtype=np.int64):
        q = float(qi[i])
        if q == 0.0:
            continue
        xv = float(x[i] @ v)
        coef = sigma_prime * q / lam_n
        dlt = hinge_coordinate_delta(float(alpha[i] + delta[i]), float(y[i]), xv, coef)
        if dlt != 0.0:
            delta[i] += dlt
            v += v_scale * dlt * x[i]
    delta_w = (v - np.asarray(w, dtype=np.float64)) / sigma_prime
    return delta, delta_w


def ref_duality_gap(x, y, alpha, mask, lam):
    """Hinge-SVM primal/dual/gap certificates on a padded block.

    w(alpha) = X^T alpha / (lam * n_eff) with n_eff = mask.sum().
    Returns (primal, dual, gap, w).
    """
    x = np.asarray(x, dtype=np.float64)
    mask = np.asarray(mask, dtype=np.float64)
    n_eff = mask.sum()
    w = (x.T @ (alpha * mask)) / (lam * n_eff)
    margins = x @ w
    losses = np.maximum(0.0, 1.0 - y * margins) * mask
    wsq = float(w @ w)
    primal = losses.sum() / n_eff + 0.5 * lam * wsq
    dual = float((y * alpha * mask).sum()) / n_eff - 0.5 * lam * wsq
    return primal, dual, primal - dual, w
