"""L2 — the JAX compute graphs AOT-compiled for the Rust coordinator.

Two graphs, both calling the L1 Pallas kernels:

* ``local_sdca``  — one worker's LOCALSDCA round on its padded local block
  (kernel: ``kernels.sdca``). This is what each worker executes per outer
  round in the XLA-backed configuration.
* ``duality_gap`` — the primal/dual/gap certificates of the global padded
  problem for the hinge SVM (kernels: ``kernels.matvec``). The leader runs
  this on its evaluation cadence.

Shapes are fixed at AOT time (PJRT executables are monomorphic); the Rust
side zero-pads blocks to the compiled (m, d) and marks padding with
q_i = 0 / mask = 0. Everything is f64 so native-Rust and XLA trajectories
agree to float-ulp levels (checked by tests on both sides).
"""

import jax
import jax.numpy as jnp

from compile.kernels import matvec as matvec_kernels
from compile.kernels import sdca as sdca_kernels


def local_sdca(x, y, alpha, w, qi, indices, scalars):
    """One CoCoA+ local round: H hinge-SDCA steps on the local block.

    Args:
      x: (m, d) padded local rows.
      y: (m,) labels.
      alpha: (m,) local duals.
      w: (d,) shared primal vector.
      qi: (m,) squared row norms, 0 on padding.
      indices: (h,) int32 coordinate sequence (Rust-generated).
      scalars: (2,) [lambda * n_global, sigma'].

    Returns (delta_alpha (m,), delta_w (d,)).
    """
    return sdca_kernels.sdca_local_update(x, y, alpha, w, qi, indices, scalars)


def duality_gap(x, y, alpha, mask, lam):
    """Hinge-SVM certificates on the (padded) global problem.

    w(alpha) = X^T(alpha*mask)/(lam*n_eff) is recomputed from alpha so the
    certificate is self-contained (no drift from an incrementally
    maintained w can hide in it).

    Args:
      x: (n, d) padded data.
      y: (n,) labels.
      alpha: (n,) dual iterate.
      mask: (n,) 1.0 for real rows, 0.0 for padding.
      lam: (1,) regularization parameter.

    Returns (primal, dual, gap, w) — scalars plus the mapped primal vector.
    """
    lam = lam[0]
    n_eff = jnp.sum(mask)
    w = matvec_kernels.matvec_t(x, alpha * mask) / (lam * n_eff)
    margins = matvec_kernels.matvec(x, w)
    losses = jnp.maximum(0.0, 1.0 - y * margins) * mask
    wsq = jnp.dot(w, w)
    primal = jnp.sum(losses) / n_eff + 0.5 * lam * wsq
    dual = jnp.sum(y * alpha * mask) / n_eff - 0.5 * lam * wsq
    return primal, dual, primal - dual, w
