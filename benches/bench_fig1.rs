//! Figure 1 regeneration bench: CoCoA vs CoCoA+ to a fixed duality gap on
//! the covtype analogue (K=4) and rcv1 analogue (K=8), reporting the
//! paper's two x-axes — communicated vectors and simulated elapsed time —
//! plus the wall-clock of regenerating each curve.

use cocoa::data::partition::random_balanced;
use cocoa::prelude::*;
use cocoa::util::bench::{black_box, Bench};

fn run_curve(data: &Dataset, k: usize, lambda: f64, plus: bool, rounds: usize) -> History {
    let part = random_balanced(data.n(), k, 42);
    let problem = Problem::new(data.clone(), Loss::Hinge, lambda);
    let solver = SolverSpec::SdcaEpochs { epochs: 1.0 };
    let cfg = if plus {
        CocoaConfig::cocoa_plus(k, Loss::Hinge, lambda, solver)
    } else {
        CocoaConfig::cocoa(k, Loss::Hinge, lambda, solver)
    }
    .with_rounds(rounds)
    .with_gap_tol(1e-3);
    Trainer::new(problem, part, cfg).run()
}

fn main() {
    let mut b = Bench::new("fig1").with_samples(3);
    let target = 1e-2;
    println!("Figure 1 — gap ≤ {target:.0e}: vectors & simulated seconds\n");
    println!(
        "{:<10} {:>3} {:>8} {:>8} | {:>11} {:>11} | {:>10} {:>10}",
        "dataset", "K", "λ", "method", "vectors", "sim t(s)", "", ""
    );
    for (ds, k) in [("covtype", 4usize), ("rcv1", 8)] {
        let data = cocoa::data::synth::paper_dataset(ds, 500.0, 42);
        for lambda in [1e-3, 1e-4] {
            for plus in [true, false] {
                let label = format!("{ds}_k{k}_l{lambda:.0e}_{}", if plus { "plus" } else { "avg" });
                let mut hit: Option<(usize, f64, usize)> = None;
                b.run(&label, || {
                    let h = run_curve(&data, k, lambda, plus, 150);
                    hit = h.time_to_gap(target);
                    black_box(h.final_gap())
                });
                let (vecs, t) = hit
                    .map(|(_, t, v)| (v.to_string(), format!("{t:.3}")))
                    .unwrap_or(("-".into(), "-".into()));
                println!(
                    "{:<10} {:>3} {:>8.0e} {:>8} | {:>11} {:>11} |",
                    ds,
                    k,
                    lambda,
                    if plus { "CoCoA+" } else { "CoCoA" },
                    vecs,
                    t
                );
            }
        }
    }
    b.report();
}
