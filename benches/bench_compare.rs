//! Snapshot comparator: diff two `util::bench` JSON reports and fail on
//! regressions past a threshold.
//!
//! ```text
//! cargo bench --bench bench_compare -- BENCH_9.json BENCH_10.json [--threshold 3.0]
//! ```
//!
//! The first path is the committed baseline (`BENCH_<previous pr>.json`
//! at the repo root), the second the fresh run (CI's `BENCH_JSON`
//! artifact). Relative paths that don't resolve against the current
//! directory are retried against the repo root, so the invocation above
//! works no matter where cargo puts the bench's working directory.
//! Exit codes: 0 = no regression, 1 = regression(s), 2 = usage error.

use cocoa::util::bench::{compare, load_baseline};
use std::path::{Path, PathBuf};

fn resolve(arg: &str) -> PathBuf {
    let direct = PathBuf::from(arg);
    if direct.exists() || direct.is_absolute() {
        return direct;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(arg)
}

fn main() {
    let mut paths: Vec<String> = Vec::new();
    let mut threshold = 1.5f64;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            // `cargo bench` appends --bench for libtest compatibility.
            "--bench" | "--" => {}
            "--threshold" => {
                threshold = match argv.next().and_then(|v| v.parse().ok()) {
                    Some(t) if t > 0.0 => t,
                    _ => {
                        eprintln!("--threshold needs a positive float");
                        std::process::exit(2);
                    }
                };
            }
            _ => paths.push(a),
        }
    }
    if paths.is_empty() {
        // A bare `cargo bench` runs every target with no args: nothing
        // to compare is a skip, not a failure.
        println!(
            "bench_compare: no snapshots given, skipping\n\
             usage: cargo bench --bench bench_compare -- <baseline.json> <current.json> \
             [--threshold 1.5]"
        );
        return;
    }
    if paths.len() != 2 {
        eprintln!(
            "usage: cargo bench --bench bench_compare -- <baseline.json> <current.json> \
             [--threshold 1.5]"
        );
        std::process::exit(2);
    }
    let (base_path, cur_path) = (resolve(&paths[0]), resolve(&paths[1]));
    let base = match load_baseline(&base_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("baseline {}: {e}", base_path.display());
            std::process::exit(2);
        }
    };
    let cur = match load_baseline(&cur_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("current {}: {e}", cur_path.display());
            std::process::exit(2);
        }
    };
    println!(
        "== bench compare: {} ({} cases) vs {} ({} cases), threshold {threshold}x ==",
        base_path.display(),
        base.cases.len(),
        cur_path.display(),
        cur.cases.len()
    );
    let cmp = compare(&base, &cur);
    print!("{}", cmp.render(threshold));
    let regs = cmp.regressions(threshold);
    if regs.is_empty() {
        println!("OK: no case slower than {threshold}x baseline");
    } else {
        eprintln!("FAIL: {} case(s) regressed past {threshold}x baseline", regs.len());
        std::process::exit(1);
    }
}
